"""Service fabric: registry lifecycle (register/resolve/epoch/TTL/member
expiry), ServicePool routing (rr / least-loaded / locality / weighted),
budgeted retries + deadlines + hedging, credit-based backpressure +
adaptive credits, deadline-aware admission control (Ret.OVERLOAD),
replica-death failover, registry-restart resync (epoch nonce),
sm→tcp tier failover with cached-view demotion, graceful close()
thread-join semantics, and the event-driven gen.result path."""
import queue
import threading
import time
import uuid

import numpy as np
import pytest

from conftest import poll_until
from repro.core.executor import Engine, RemoteError
from repro.core.types import Ret
from repro.fabric import (BudgetExhausted, CreditGate, EwmaWeighted,
                          RegistryClient, RegistryService, RetryPolicy,
                          ServiceInstance, ServicePool,
                          resolve_service_uris)
from repro.fabric.pool import Replica
from repro.serve.engine import Request
from repro.services import (AdmissionController, MembershipServer,
                            ServingGateway)


@pytest.fixture
def reg():
    """Registry on its own engine, fast sweeps for test-speed expiry."""
    with Engine("tcp://127.0.0.1:0") as e:
        svc = RegistryService(e, instance_ttl=0.6, sweep_interval=0.1)
        yield e, svc
        svc.close()


def _echo_engine(name):
    e = Engine("tcp://127.0.0.1:0")
    e.register("echo", lambda x, _n=name: (_n, x))
    return e


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_register_resolve_epoch(reg):
    reg_e, _ = reg
    with Engine("tcp://127.0.0.1:0") as cli_e:
        cli = RegistryClient(cli_e, reg_e.uri)
        e0 = cli.epoch()
        iid = cli.register("svc", "tcp://127.0.0.1:1111", capacity=4)
        assert cli.epoch() == e0 + 1
        view = cli.resolve("svc")
        assert [i["iid"] for i in view["instances"]] == [iid]
        assert view["instances"][0]["capacity"] == 4
        # load reports must NOT bump the epoch (cached views stay valid)
        cli.report("svc", iid, load=7.5)
        assert cli.epoch() == e0 + 1
        assert cli.resolve("svc")["instances"][0]["load"] == 7.5
        assert cli.services() == ["svc"]
        assert cli.deregister("svc", iid)
        assert cli.epoch() == e0 + 2
        assert cli.resolve("svc")["instances"] == []
        from repro.core.types import MercuryError
        with pytest.raises(MercuryError):
            resolve_service_uris(cli_e, reg_e.uri, "svc")


def test_registry_ttl_expires_silent_instance(reg):
    reg_e, _ = reg
    with Engine("tcp://127.0.0.1:0") as cli_e:
        cli = RegistryClient(cli_e, reg_e.uri)
        cli.register("svc", "tcp://127.0.0.1:1111")   # never reports again
        e1 = cli.epoch()
        poll_until(lambda: not cli.resolve("svc")["instances"],
                   timeout=5.0, interval=0.1, msg="silent instance reaped")
        assert cli.resolve("svc")["instances"] == []
        assert cli.epoch() > e1


def test_registry_reaps_instances_of_dead_members(reg):
    """An instance bound to a member_id dies with its member (via the
    MembershipServer.on_expire hook), even while it keeps reporting."""
    reg_e, reg_svc = reg
    ms = MembershipServer(reg_e, heartbeat_timeout=0.4, sweep_interval=0.1)
    ms.on_expire(reg_svc._members_expired)
    with Engine("tcp://127.0.0.1:0") as w:
        cli = RegistryClient(w, reg_e.uri)
        w.call(reg_e.uri, "mem.join", {"member_id": "w1", "uri": w.uri})
        iid = cli.register("svc", w.uri, member_id="w1")
        # member w1 never heartbeats; the instance DOES keep reporting,
        # so only the member-expiry path can remove it
        def _reaped():
            try:
                cli.report("svc", iid, load=0.0)
                return False
            except RemoteError:
                return True                    # NOENTRY: reaped
        poll_until(_reaped, timeout=5.0, interval=0.05,
                   msg="member-bound instance reaped")
        assert cli.resolve("svc")["instances"] == []
    ms.close()


# ---------------------------------------------------------------------------
# pool routing
# ---------------------------------------------------------------------------
def test_pool_round_robin_distributes(reg):
    reg_e, _ = reg
    a, b = _echo_engine("a"), _echo_engine("b")
    with a, b, Engine("tcp://127.0.0.1:0") as cli:
        ia = ServiceInstance(a, reg_e.uri, "svc", capacity=4,
                             report_interval=0.1)
        ib = ServiceInstance(b, reg_e.uri, "svc", capacity=4,
                             report_interval=0.1)
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="rr")
        hits = [pool.call("echo", i, timeout=10.0)[0] for i in range(8)]
        assert hits.count("a") == 4 and hits.count("b") == 4
        ia.close(), ib.close()


def test_pool_least_loaded_prefers_idle(reg):
    reg_e, _ = reg
    a, b = _echo_engine("a"), _echo_engine("b")
    with a, b, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        ia = rc.register("svc", a.uri, capacity=4, load=9.0)  # busy
        ib = rc.register("svc", b.uri, capacity=4, load=0.0)  # idle
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="least")
        hits = {pool.call("echo", i, timeout=10.0)[0] for i in range(6)}
        assert hits == {"b"}
        rc.deregister("svc", ia), rc.deregister("svc", ib)


def test_pool_locality_prefers_cheap_tier(reg):
    """Replica advertising a self:// tier must win over a tcp-only one
    for a co-located (same-process) client."""
    reg_e, _ = reg
    tag = uuid.uuid4().hex[:6]
    near = Engine([f"self://near-{tag}", "tcp://127.0.0.1:0"])
    far = _echo_engine("far")
    near.register("echo", lambda x: ("near", x))
    with near, far, Engine([f"self://cli-{tag}",
                            "tcp://127.0.0.1:0"]) as cli:
        rc = RegistryClient(cli, reg_e.uri)
        i1 = rc.register("svc", near.uri, capacity=4)
        i2 = rc.register("svc", far.uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="locality")
        tiers = sorted(r.stat()["tier"] for r in pool.replicas())
        assert tiers == ["self", "tcp"]
        hits = {pool.call("echo", i, timeout=10.0)[0] for i in range(6)}
        assert hits == {"near"}
        rc.deregister("svc", i1), rc.deregister("svc", i2)


# ---------------------------------------------------------------------------
# retries / deadlines / hedging / flow control
# ---------------------------------------------------------------------------
def test_pool_retries_around_dead_replica(reg):
    reg_e, _ = reg
    ok = _echo_engine("ok")
    with ok, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        dead = rc.register("svc", "tcp://127.0.0.1:1", capacity=4)
        live = rc.register("svc", ok.uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="rr",
                           policy=RetryPolicy(attempts=3, rpc_timeout=2.0,
                                              backoff_base=0.01))
        # every call must succeed even when ranked onto the dead one first
        assert all(pool.call("echo", i, timeout=15.0)[0] == "ok"
                   for i in range(6))
        rc.deregister("svc", dead), rc.deregister("svc", live)


def test_pool_deadline_bounds_slow_service(reg):
    reg_e, _ = reg
    slow = Engine("tcp://127.0.0.1:0")
    slow.register("nap", lambda x: time.sleep(3.0) or "late")
    with slow, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        iid = rc.register("svc", slow.uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc",
                           policy=RetryPolicy(attempts=2, rpc_timeout=0.3,
                                              backoff_base=0.01,
                                              jitter=0.0))
        t0 = time.monotonic()
        with pytest.raises(Exception):
            pool.call("nap", None, timeout=0.8)
        elapsed = time.monotonic() - t0
        # never exceeds the deadline by more than one rpc timeout
        assert elapsed < 0.8 + 0.3 + 0.3, elapsed
        rc.deregister("svc", iid)


def test_pool_hedged_request_beats_straggler(reg):
    reg_e, _ = reg
    slow = Engine("tcp://127.0.0.1:0")
    slow.register("work", lambda x: time.sleep(2.0) or "slow")
    fast = Engine("tcp://127.0.0.1:0")
    fast.register("work", lambda x: "fast")
    with slow, fast, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        i1 = rc.register("svc", slow.uri, capacity=4)
        i2 = rc.register("svc", fast.uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="rr",
                           policy=RetryPolicy(attempts=3, rpc_timeout=5.0,
                                              hedge_after=0.1))
        t0 = time.monotonic()
        outs = [pool.call("work", i, timeout=10.0) for i in range(4)]
        dt = time.monotonic() - t0
        assert all(o == "fast" for o in outs)   # hedge wins every time
        assert dt < 2.0, dt                     # never waited for slow
        rc.deregister("svc", i1), rc.deregister("svc", i2)


def test_pool_credit_backpressure(reg):
    reg_e, _ = reg
    release = threading.Event()
    srv = Engine("tcp://127.0.0.1:0")
    srv.register("hold", lambda x: release.wait(10.0) and "held")
    with srv, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        iid = rc.register("svc", srv.uri, capacity=2)
        pool = ServicePool(cli, reg_e.uri, "svc", credits_per_target=2,
                           policy=RetryPolicy(attempts=1, rpc_timeout=15.0))
        import concurrent.futures as cf
        with cf.ThreadPoolExecutor(2) as tp:
            f1 = tp.submit(pool.call, "hold", 1, 12.0)
            f2 = tp.submit(pool.call, "hold", 2, 12.0)
            deadline = time.time() + 5
            while time.time() < deadline:
                if pool.stats()["replicas"][0]["inflight"] == 2:
                    break
                time.sleep(0.02)
            st = pool.stats()["replicas"][0]
            assert st["inflight"] == 2          # both credits consumed
            # third call: saturated -> bounded wait -> backpressure error
            with pytest.raises(BudgetExhausted):
                pool.call("hold", 3, timeout=0.4)
            st = pool.stats()["replicas"][0]
            assert st["backpressured"] >= 1 and st["rejected"] >= 1
            release.set()
            assert f1.result(15) == "held" and f2.result(15) == "held"
        # all credits returned after completion
        assert pool.stats()["replicas"][0]["inflight"] == 0
        rc.deregister("svc", iid)


def test_pool_failover_on_replica_death(reg):
    """Kill a replica abruptly mid-run: no client-visible failure, and
    the TTL sweep (epoch bump) eventually drops it from the view."""
    reg_e, _ = reg
    a, b = _echo_engine("a"), _echo_engine("b")
    ia = ServiceInstance(a, reg_e.uri, "svc", capacity=4,
                         report_interval=0.1)
    ib = ServiceInstance(b, reg_e.uri, "svc", capacity=4,
                         report_interval=0.1)
    with b, Engine("tcp://127.0.0.1:0") as cli:
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="rr",
                           refresh_interval=0.1,
                           policy=RetryPolicy(attempts=4, rpc_timeout=1.0,
                                              backoff_base=0.01))
        assert len(pool.replicas()) == 2
        ia.close(deregister=False)     # heartbeats stop: simulated crash
        a.shutdown()
        # every call still succeeds (retries absorb the dead replica)
        assert all(pool.call("echo", i, timeout=15.0)[0] == "b"
                   for i in range(8))
        poll_until(lambda: (pool.refresh(force=True) or
                            len(pool.replicas()) == 1),
                   timeout=5.0, interval=0.1, msg="dead replica pruned")
        assert len(pool.replicas()) == 1       # epoch bump pruned the dead
        ib.close()


def test_pool_affine_calls_pin_replica(reg):
    """call_routed reports the serving instance; call_on pins follow-ups
    to it (the gen.submit/gen.result pattern: rids are replica-local)."""
    reg_e, _ = reg
    a, b = _echo_engine("a"), _echo_engine("b")
    with a, b, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        ids = {rc.register("svc", e.uri, capacity=4): n
               for e, n in ((a, "a"), (b, "b"))}
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="rr")
        for i in range(6):
            out, iid = pool.call_routed("echo", i, timeout=10.0)
            assert out[0] == ids[iid]          # winner reported truthfully
            # pinned follow-ups always land on the same instance
            assert all(pool.call_on(iid, "echo", j, timeout=10.0)[0]
                       == ids[iid] for j in range(3))
        from repro.fabric import PoolError
        with pytest.raises(BudgetExhausted) as ei:
            pool.call_on("no-such-iid", "echo", 0, timeout=2.0,
                         policy=RetryPolicy(attempts=2, rpc_timeout=0.5,
                                            backoff_base=0.01))
        assert isinstance(ei.value.cause, PoolError)
        for iid in ids:
            rc.deregister("svc", iid)


def test_pool_recovers_replica_after_transient_outage(reg):
    """A replica that was down (marked down / undemotable) must come back
    once reachable again — demotions are soft state, not a tombstone."""
    reg_e, _ = reg
    with Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        srv = _echo_engine("a")
        port_uri = srv.uri
        iid = rc.register("svc", port_uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", down_ttl=0.2,
                           policy=RetryPolicy(attempts=2, rpc_timeout=1.0,
                                              backoff_base=0.01))
        assert pool.call("echo", 1, timeout=10.0)[0] == "a"
        srv.shutdown()                 # transient outage begins
        with pytest.raises(Exception):
            pool.call("echo", 2, timeout=3.0)
        rep = pool.replicas()[0]
        assert not rep.is_up or rep.bad_schemes   # excluded right now
        # replica comes back on a NEW port; re-registers under same iid
        srv2 = _echo_engine("a2")
        rc.register("svc", srv2.uri, capacity=4, iid=iid)
        def _recovered():
            try:
                return pool.call("echo", 3, timeout=3.0)[0] == "a2"
            except Exception:
                return False
        poll_until(_recovered, timeout=5.0, interval=0.1,
                   msg="replica recovery (not tombstoned)")
        srv2.shutdown()
        rc.deregister("svc", iid)


# ---------------------------------------------------------------------------
# registry restart (epoch nonce), re-register epoch storms, replica locking
# ---------------------------------------------------------------------------
def test_reregister_same_uris_does_not_bump_epoch(reg):
    """The ServiceInstance report-loop recovery path re-registers under
    its old iid with unchanged uris; membership did not change, so the
    epoch must not move (a bump forces fab.resolve storms in every
    pool).  Changing the uris IS a membership change and must bump."""
    reg_e, _ = reg
    with Engine("tcp://127.0.0.1:0") as cli_e:
        cli = RegistryClient(cli_e, reg_e.uri)
        iid = cli.register("svc", "tcp://127.0.0.1:1111", capacity=2)
        e1 = cli.epoch()
        for _ in range(5):     # recovery re-registers: same iid, same uris
            cli.register("svc", "tcp://127.0.0.1:1111", capacity=2,
                         iid=iid)
        assert cli.epoch() == e1
        # load/capacity still refreshed by the re-register
        cli.register("svc", "tcp://127.0.0.1:1111", capacity=2, iid=iid,
                     load=4.5)
        assert cli.resolve("svc")["instances"][0]["load"] == 4.5
        assert cli.epoch() == e1
        # moved to a new address: that IS membership
        cli.register("svc", "tcp://127.0.0.1:2222", capacity=2, iid=iid)
        assert cli.epoch() == e1 + 1
        cli.deregister("svc", iid)


@pytest.mark.slow
def test_pool_survives_registry_restart():
    """Acceptance: a pool keeps routing through a registry kill/restart
    (epoch resets to 0 under a fresh nonce) and converges to the fresh
    view within one refresh interval instead of treating the reset epoch
    as a stale race forever."""
    reg_e = Engine("tcp://127.0.0.1:0")
    reg_svc = RegistryService(reg_e)
    port = int(reg_e.uri.rsplit(":", 1)[1])
    srv = _echo_engine("a")
    inst = ServiceInstance(srv, reg_e.uri, "svc", capacity=4,
                           report_interval=0.1)
    with srv, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        # pad the old registry's epoch well past anything the restarted
        # (reset-to-0) registry will reach during the test
        for i in range(5):
            rc.register("pad", f"tcp://127.0.0.1:{2000 + i}")
        pool = ServicePool(cli, reg_e.uri, "svc", refresh_interval=0.1,
                           policy=RetryPolicy(attempts=3, rpc_timeout=2.0,
                                              backoff_base=0.01))
        old_epoch, old_nonce = pool.epoch, pool._view_nonce
        assert old_epoch >= 6 and old_nonce is not None
        assert pool.call("echo", 1, timeout=10.0)[0] == "a"

        reg_svc.close()
        reg_e.shutdown()               # registry dies
        # stale cached view keeps the data path alive
        assert pool.call("echo", 2, timeout=10.0)[0] == "a"

        # restart on the SAME port: empty state, epoch 0, fresh nonce
        reg_e2 = Engine(f"tcp://127.0.0.1:{port}")
        reg_svc2 = RegistryService(reg_e2)
        try:
            # the instance's report loop re-registers itself (NOENTRY ->
            # register); wait for the fresh registry to list it
            rc2 = RegistryClient(cli, reg_e2.uri)
            poll_until(lambda: rc2.resolve("svc")["instances"],
                       timeout=10.0, interval=0.05,
                       msg="instance re-registration on the fresh registry")
            # pool must converge onto the fresh view (new nonce, LOWER
            # epoch) within ~one refresh interval
            poll_until(lambda: (pool.refresh() or
                                pool._view_nonce != old_nonce),
                       timeout=5.0, msg="pool resync off the dead "
                                        "registry's view")
            assert pool.epoch < old_epoch          # reset accepted
            assert pool.call("echo", 3, timeout=10.0)[0] == "a"
        finally:
            reg_svc2.close()
            reg_e2.shutdown()
    inst.close(deregister=False)


@pytest.mark.slow
def test_replica_mutators_are_race_free():
    """demote / reresolve / mark_down / record hammered from many
    threads: every transition atomic (the PR-3 locking fix), no replica
    state torn, no exception escapes."""
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        srv.register("echo", lambda x: x)
        rep = Replica("r1", [srv.uri], 4, 0.0, CreditGate(4))
        assert rep.resolve(cli)
        stop = time.monotonic() + 1.5
        errors = []

        def hammer(which):
            try:
                while time.monotonic() < stop:
                    if which == 0:
                        rep.demote(cli)
                    elif which == 1:
                        rep.reresolve(cli)
                    elif which == 2:
                        rep.mark_down(0.01)
                        _ = rep.is_up
                    else:
                        rep.record(0.001, ok=True)
                        rep.record(None, ok=False)
            except Exception as e:     # noqa: BLE001 — surfaced below
                errors.append(repr(e))

        threads = [threading.Thread(target=hammer, args=(i % 4,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=10)
        assert not errors, errors
        # post-storm state is coherent: recoverable and callable
        assert rep.reresolve(cli)
        assert rep.is_up


# ---------------------------------------------------------------------------
# weighted balancing + adaptive credits
# ---------------------------------------------------------------------------
def _fake_rep(iid, ema, inflight, load=0.0, capacity=1):
    rep = Replica(iid, [f"tcp://127.0.0.1:{9000 + hash(iid) % 100}"],
                  capacity, load, CreditGate(max(inflight, 1) + 1))
    rep.ema_latency = ema
    for _ in range(inflight):
        rep.gate.try_acquire()
    return rep


def test_weighted_balancer_ranks_by_expected_wait():
    b = EwmaWeighted()
    fast_idle = _fake_rep("fast", 0.01, 0)
    fast_busy = _fake_rep("busy", 0.01, 3)
    slow_idle = _fake_rep("slow", 0.10, 0)
    ranked = b.rank([slow_idle, fast_busy, fast_idle])
    assert ranked[0] is fast_idle
    # 10ms x 4 in flight beats 100ms idle: the busy-fast replica still
    # wins over the slow one (0.04 < 0.10 expected wait)
    assert ranked[1] is fast_busy and ranked[2] is slow_idle
    # capacity normalizes: same latency+occupancy, 4x capacity -> first
    big = _fake_rep("big", 0.10, 0, capacity=4)
    assert b.rank([slow_idle, big])[0] is big
    # piggybacked server load counts even with zero local in-flight
    loaded = _fake_rep("loaded", 0.01, 0, load=9.0)
    assert b.rank([loaded, fast_idle])[0] is fast_idle


def test_weighted_balancer_probes_unsampled_replicas():
    """A replica with no latency sample must rank with the best observed
    EWMA (occupancy-scaled), not sink to the bottom — otherwise a
    recovered replica is never probed and never gets a sample."""
    b = EwmaWeighted()
    sampled = _fake_rep("sampled", 0.05, 2)
    unsampled = _fake_rep("new", 0.0, 0)
    assert b.rank([sampled, unsampled])[0] is unsampled


def test_pool_adaptive_credits_grow_on_fast_replica(reg):
    """Default pool gates are adaptive: completions under the latency
    target grow the limit past the initial credits_per_target.  The
    target is pinned explicitly — every completion counts as fast — so
    the test exercises the record->gate->growth wiring, not the latency
    jitter of a loaded CI box (the control law itself is pinned by
    tests/test_fabric_flow.py)."""
    reg_e, _ = reg
    srv = _echo_engine("a")
    with srv, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        iid = rc.register("svc", srv.uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", credits_per_target=2,
                           credit_max=16, credit_target_latency=30.0)
        for i in range(40):
            assert pool.call("echo", i, timeout=10.0)[0] == "a"
        st = pool.stats()["replicas"][0]
        assert st["limit"] > 2 and st["grown"] >= 1
        assert st["credits"] <= 16
        rc.deregister("svc", iid)


# ---------------------------------------------------------------------------
# deadline budget propagation + admission control (Ret.OVERLOAD)
# ---------------------------------------------------------------------------
def test_deadline_budget_rides_request_header():
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        seen = {}

        def probe(_x, handle):
            seen["budget"] = handle.remaining_budget()
            return "ok"
        srv.register("probe", probe, pass_handle=True)
        assert cli.call(srv.uri, "probe", None, timeout=5.0) == "ok"
        assert 4.0 < seen["budget"] <= 5.0
        # deadline= form propagates the *remaining* budget
        assert cli.call(srv.uri, "probe", None,
                        deadline=time.monotonic() + 2.0) == "ok"
        assert 1.0 < seen["budget"] <= 2.0
        # no timeout -> no budget -> admission never sheds
        fut = cli.call_async(srv.uri, "probe", None, timeout=None)
        assert fut.result(10.0) == "ok"
        assert seen["budget"] is None


def test_gateway_sheds_overload_fast(reg):
    """A gateway whose backlog x EWMA service time exceeds the caller's
    budget sheds with Ret.OVERLOAD in sub-RPC time instead of queueing
    doomed work; generous budgets are still admitted."""
    reg_e, _ = reg
    serve = FakeServe()
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        gw = ServingGateway(srv, serve)
        for _ in range(3):             # past min_samples: 500ms/request
            gw.admission.observe(0.5)
        t0 = time.monotonic()
        with pytest.raises(RemoteError) as ei:
            cli.call(srv.uri, "gen.submit", {"tokens": [1]}, timeout=0.2)
        assert ei.value.ret == Ret.OVERLOAD
        assert time.monotonic() - t0 < 0.19, "shed must be a fast-fail"
        # same request with headroom is admitted
        out = cli.call(srv.uri, "gen.submit", {"tokens": [1]}, timeout=5.0)
        assert "rid" in out
        st = cli.call(srv.uri, "gen.stats", {}, timeout=5.0)
        assert st["shed"] == 1 and st["admitted"] >= 1
        gw.close()


def test_pool_reroutes_overload_to_other_replica(reg):
    """OVERLOAD is retryable-on-another-replica with NO backoff: a pool
    facing one overloaded and one healthy gateway completes every call
    on the healthy one, within the original deadline."""
    reg_e, _ = reg
    slow_serve, fast_serve = FakeServe(), FakeServe()
    engines = [Engine("tcp://127.0.0.1:0") for _ in range(2)]
    gws = [ServingGateway(engines[0], slow_serve, registry=reg_e.uri,
                          service="gen", report_interval=0.1),
           ServingGateway(engines[1], fast_serve, registry=reg_e.uri,
                          service="gen", report_interval=0.1)]
    for _ in range(3):                 # replica 0 "takes 30s per request"
        gws[0].admission.observe(30.0)
    with Engine("tcp://127.0.0.1:0") as cli:
        pool = ServicePool(cli, reg_e.uri, "gen", balancer="rr",
                           refresh_interval=0.1,
                           policy=RetryPolicy(attempts=3, rpc_timeout=5.0,
                                              backoff_base=0.2))
        assert len(pool.replicas()) == 2
        t0 = time.monotonic()
        outs = [pool.call("gen.generate", {"tokens": [1], "max_new": 2},
                          timeout=5.0) for _ in range(6)]
        dt = time.monotonic() - t0
        assert all(o["done"] for o in outs)
        # rr alternates, so ~3 calls hit the overloaded replica first and
        # were shed + rerouted; fast_rets skips the 0.2s backoff, so the
        # whole batch finishes far inside the per-call deadline
        shed = cli.call(engines[0].uri, "gen.stats", {},
                        timeout=5.0)["shed"]
        assert shed >= 1
        assert dt < 5.0, dt
    for gw, e in zip(gws, engines):
        gw.close()
        e.shutdown()


def test_admission_tracks_pure_service_time():
    """The shedding estimate uses the pure-service EWMA; queue wait is
    priced only via the backlog term (feeding submit→done turnaround
    back into the EWMA would double-count queueing right after a burst
    and over-shed until the EWMA re-converged)."""
    adm = AdmissionController(min_samples=1)
    for _ in range(4):                 # 50ms of work behind a ~1s queue
        adm.observe(0.05, turnaround_s=1.0)
    st = adm.stats()
    assert 40 < st["ema_service_ms"] < 60
    assert st["ema_turnaround_ms"] > 500
    # 4 backlog / 2 slots -> 2 waves + own service: ~150ms, NOT ~3s —
    # a caller with a 500ms budget is admitted post-burst
    assert adm.estimate_wait(backlog=4, parallelism=2) < 0.2
    adm.admit(0.5, backlog=4, parallelism=2)   # must not raise


def test_gateway_admission_excludes_queue_wait():
    """Requests held in the gateway queue must not inflate the service
    EWMA: t_admit (slot entry) is the measurement origin, t_submit only
    feeds the separate turnaround EWMA."""
    gate = threading.Event()
    serve = FakeServe(auto=False, gate=gate)
    with Engine("tcp://127.0.0.1:0") as e:
        gw = ServingGateway(e, serve)
        try:
            with Engine("tcp://127.0.0.1:0") as cli:
                cli.call(e.uri, "gen.submit", {"tokens": [1]}, timeout=5.0)
            time.sleep(0.5)            # queue wait: gate still closed
            gate.set()                 # admit: slot occupancy starts
            deadline = time.time() + 5
            while time.time() < deadline and not serve.parked:
                time.sleep(0.01)
            assert serve.parked
            time.sleep(0.25)           # service time
            req = serve.parked[0]
            req.done_event.set()
            req._fire_done()
            st = gw.admission.stats()
            # service ~= 0.25s (plus step-loop poll slack), turnaround
            # additionally carries the ~0.5s queue wait
            assert st["admission_samples"] == 1
            assert st["ema_service_ms"] < 550
            assert st["ema_turnaround_ms"] > 650
            assert st["ema_turnaround_ms"] > st["ema_service_ms"] + 300
        finally:
            gw.close()


# ---------------------------------------------------------------------------
# tier failover (na/multi + pool demotion)
# ---------------------------------------------------------------------------
def test_multi_lookup_falls_back_past_stale_sm():
    """An address set whose sm tier is unreachable must resolve tcp."""
    tag = uuid.uuid4().hex[:6]
    live = _echo_engine("live")
    with live, Engine([f"sm://mf-cli-{tag}", "tcp://127.0.0.1:0"]) as cli:
        addr = cli.lookup(f"sm://ghost-{tag};{live.uri}")
        assert addr.uri.startswith("tcp://")
        assert cli.call(addr, "echo", 1, timeout=10.0)[0] == "live"


def test_pool_demotes_tier_when_sm_dies_midrun(reg):
    """A replica resolved at the sm tier whose segment goes away must be
    demoted to tcp in the pool's cached view, transparently."""
    reg_e, _ = reg
    tag = uuid.uuid4().hex[:6]
    sm_half = Engine(f"sm://dm-{tag}")
    tcp_half = _echo_engine("tcp-half")
    sm_half.register("echo", lambda x: ("sm-half", x))
    with tcp_half, Engine([f"sm://dmc-{tag}",
                           "tcp://127.0.0.1:0"]) as cli:
        rc = RegistryClient(cli, reg_e.uri)
        iid = rc.register("svc", f"{sm_half.uri};{tcp_half.uri}",
                          capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="locality",
                           policy=RetryPolicy(attempts=3, rpc_timeout=2.0,
                                              backoff_base=0.01))
        rep = pool.replicas()[0]
        assert rep.stat()["tier"] == "sm"
        assert pool.call("echo", 1, timeout=10.0)[0] == "sm-half"
        sm_half.shutdown()             # sm segment vanishes mid-run
        out = pool.call("echo", 2, timeout=15.0)
        assert out[0] == "tcp-half"    # transparent fallback
        assert rep.stat()["tier"] == "tcp" and "sm" in rep.bad_schemes
        rc.deregister("svc", iid)


# ---------------------------------------------------------------------------
# graceful close semantics + event-driven gen.result
# ---------------------------------------------------------------------------
def test_membership_close_joins_sweeper():
    with Engine("tcp://127.0.0.1:0") as e:
        ms = MembershipServer(e, sweep_interval=0.1)
        assert ms._sweeper.is_alive()
        ms.close()
        assert not ms._sweeper.is_alive()
        ms.close()                     # idempotent


class FakeServe:
    """Minimal ServeEngine stand-in: completes each request with one
    token per step — lets gateway plumbing be tested without a model.
    Stamps ``t_submit``/``t_admit`` like the real engine (the admission
    EWMA's measurement origins); an optional ``gate`` event holds
    requests in the queue until set, creating real queue wait."""

    def __init__(self, n_slots=2, auto=True, gate=None):
        self.queue = queue.Queue()
        self.work = threading.Event()
        self.n_slots = n_slots
        self.auto = auto
        self.gate = gate               # None = admit immediately
        self.parked = []               # auto=False: admitted, not finished
        self._rid = 0
        self._lock = threading.Lock()

    def submit(self, tokens, max_new=32, temperature=0.0, eos_id=-1,
               frontend=None, on_token=None, session_id=None):
        with self._lock:
            self._rid += 1
            req = Request(self._rid, np.asarray(tokens, np.int32), max_new)
        req.t_submit = time.monotonic()
        self.queue.put(req)
        self.work.set()
        return req

    def pending(self):
        return self.queue.qsize()

    def step(self):
        if self.gate is not None and not self.gate.is_set():
            return 0
        n = 0
        while True:
            try:
                req = self.queue.get_nowait()
            except queue.Empty:
                return n
            req.t_admit = time.monotonic()
            if self.auto:
                req.out_tokens.append(7)
                req.done_event.set()
                req._fire_done()
                n += 1
            else:
                self.parked.append(req)   # test completes them by hand

    def stats(self):
        return {"active_slots": 0, "n_slots": self.n_slots,
                "queued": self.queue.qsize(), "max_len": 64,
                "occupancy": 0.0, "pinned_sessions": 0,
                "prefix_hits": 0, "prefix_misses": 0}


def test_gateway_close_joins_step_loop():
    with Engine("tcp://127.0.0.1:0") as e:
        gw = ServingGateway(e, FakeServe())
        assert gw._thread.is_alive()
        gw.close()
        assert not gw._thread.is_alive()
        gw.close()                     # idempotent


def test_gen_result_wait_is_event_driven():
    """Waiting gen.result handlers must not park handler-pool threads:
    with every pool thread's worth of waiters outstanding, an unrelated
    RPC still gets through, and completion wakes all waiters."""
    serve = FakeServe(auto=False)
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        gw = ServingGateway(srv, serve)
        rid = cli.call(srv.uri, "gen.submit", {"tokens": [1, 2]})["rid"]
        poll_until(lambda: serve.parked, timeout=5.0, interval=0.01,
                   msg="request admitted and parked")
        req = serve.parked[0]                  # admitted, unfinished
        cbs_before = len(req._done_cbs)
        waiters = [cli.call_async(srv.uri, "gen.result",
                                  {"rid": rid, "wait": True,
                                   "timeout": 20.0}, timeout=30.0)
                   for _ in range(4)]          # = srv handler_threads
        # each parked waiter registers a done callback; wait until all
        # four are event-parked (not thread-parked) before probing
        poll_until(lambda: len(req._done_cbs) >= cbs_before + 4,
                   timeout=5.0, interval=0.01, msg="waiters parked")
        # old busy/parked design: all 4 pool threads blocked -> this hangs
        stats = cli.call(srv.uri, "gen.stats", {}, timeout=2.0)
        assert stats["n_slots"] == 2
        req.out_tokens.append(9)
        req.done_event.set()
        req._fire_done()
        outs = [w.result(timeout=10) for w in waiters]
        assert all(o["done"] and o["tokens"] == [9] for o in outs)
        gw.close()


def test_gen_result_wait_times_out_with_partial_tokens():
    serve = FakeServe(auto=False)
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        gw = ServingGateway(srv, serve)
        rid = cli.call(srv.uri, "gen.submit", {"tokens": [1]})["rid"]
        t0 = time.monotonic()
        out = cli.call(srv.uri, "gen.result",
                       {"rid": rid, "wait": True, "timeout": 0.3},
                       timeout=10.0)
        assert not out["done"] and time.monotonic() - t0 < 5.0
        gw.close()


def test_gateway_self_registers_and_routes_through_pool(reg):
    reg_e, _ = reg
    serves = [FakeServe(), FakeServe()]
    engines = [Engine("tcp://127.0.0.1:0") for _ in serves]
    gws = [ServingGateway(e, s, registry=reg_e.uri, service="gen",
                          report_interval=0.1)
           for e, s in zip(engines, serves)]
    with Engine("tcp://127.0.0.1:0") as cli:
        pool = ServicePool(cli, reg_e.uri, "gen", balancer="rr",
                           refresh_interval=0.1,
                           policy=RetryPolicy(attempts=4, rpc_timeout=5.0,
                                              backoff_base=0.01))
        assert len(pool.replicas()) == 2
        outs = [pool.call("gen.generate", {"tokens": [1, 2], "max_new": 4},
                          timeout=15.0) for _ in range(4)]
        assert all(o["done"] for o in outs)
        # capacity was piggybacked from n_slots
        assert all(r.capacity == 2 for r in pool.replicas())
        # kill one replica: calls keep succeeding, view shrinks on expiry
        gws[0].instance.close(deregister=False)
        gws[0].stop()
        engines[0].shutdown()
        assert all(pool.call("gen.generate",
                             {"tokens": [3], "max_new": 2},
                             timeout=15.0)["done"] for _ in range(4))
    gws[1].close()
    engines[1].shutdown()


# ---------------------------------------------------------------------------
# checkpoint / datafeed resolvable by name
# ---------------------------------------------------------------------------
def test_checkpoint_resolvable_by_name(reg):
    from repro.services import CheckpointClient, CheckpointServer
    reg_e, _ = reg
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli_e:
        cs = CheckpointServer(srv, registry=reg_e.uri)
        cli = CheckpointClient(cli_e, registry=reg_e.uri)
        tree = {"w": np.arange(100, dtype=np.float32)}
        assert cli.save("m", 1, tree)["ok"]
        out, step = cli.restore("m", {"w": np.zeros(100, np.float32)})
        assert step == 1
        np.testing.assert_array_equal(out["w"], tree["w"])
        cs.close()


def test_datafeed_resolvable_by_name(reg):
    from repro.data.pipeline import SyntheticSource
    from repro.services import DataFeedClient, DataFeedServer
    reg_e, _ = reg
    src = SyntheticSource(vocab=100, seq_len=16, batch_per_host=2)
    with Engine("tcp://127.0.0.1:0") as fe, \
            Engine("tcp://127.0.0.1:0") as tr:
        fs = DataFeedServer(fe, src, registry=reg_e.uri)
        cli = DataFeedClient(tr, registry=reg_e.uri)
        b = cli.get(3)
        np.testing.assert_array_equal(b["tokens"],
                                      src.batch_at(3)["tokens"])
        fs.close()


def test_services_read_is_token_cached_and_evicts_on_epoch_bump(reg):
    """``fab.services`` carries the authoritative ``(nonce, epoch)``
    token: the client caches it under that token (not merely TTL) and an
    epoch bump evicts — a long TTL must NOT serve the stale service
    list once the registry's token has advanced."""
    reg_e, _ = reg
    with Engine("tcp://127.0.0.1:0") as ce, \
            Engine("tcp://127.0.0.1:0") as we:
        cli = RegistryClient(ce, reg_e.uri, cache_ttl=30.0)
        writer = RegistryClient(we, reg_e.uri)
        writer.register("alpha", "tcp://127.0.0.1:1111")
        assert cli.services() == ["alpha"]
        tok = cli.cache.stats()["token"]
        # the token came from the fab.services response itself
        assert tok["nonce"] is not None and tok["epoch"] >= 0
        assert cli.cache.stats()["entries"] >= 1
        ev0 = cli.cache.stats()["evictions"]

        writer.register("beta", "tcp://127.0.0.1:2222")   # epoch bump
        # a cheap epoch poll reveals the bump and evicts the cached list
        # (the 30s TTL alone could never explain the refreshed read)
        cli.epoch(fresh=True)
        assert cli.cache.stats()["evictions"] > ev0
        assert cli.services() == ["alpha", "beta"]
