"""Service fabric: registry lifecycle (register/resolve/epoch/TTL/member
expiry), ServicePool routing (rr / least-loaded / locality), budgeted
retries + deadlines + hedging, credit-based backpressure, replica-death
failover, sm→tcp tier failover with cached-view demotion, graceful
close() thread-join semantics, and the event-driven gen.result path."""
import queue
import threading
import time
import uuid

import numpy as np
import pytest

from repro.core.executor import Engine, RemoteError
from repro.fabric import (BudgetExhausted, RegistryClient, RegistryService,
                          RetryPolicy, ServiceInstance, ServicePool,
                          resolve_service_uris)
from repro.serve.engine import Request
from repro.services import MembershipServer, ServingGateway


@pytest.fixture
def reg():
    """Registry on its own engine, fast sweeps for test-speed expiry."""
    with Engine("tcp://127.0.0.1:0") as e:
        svc = RegistryService(e, instance_ttl=0.6, sweep_interval=0.1)
        yield e, svc
        svc.close()


def _echo_engine(name):
    e = Engine("tcp://127.0.0.1:0")
    e.register("echo", lambda x, _n=name: (_n, x))
    return e


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_register_resolve_epoch(reg):
    reg_e, _ = reg
    with Engine("tcp://127.0.0.1:0") as cli_e:
        cli = RegistryClient(cli_e, reg_e.uri)
        e0 = cli.epoch()
        iid = cli.register("svc", "tcp://127.0.0.1:1111", capacity=4)
        assert cli.epoch() == e0 + 1
        view = cli.resolve("svc")
        assert [i["iid"] for i in view["instances"]] == [iid]
        assert view["instances"][0]["capacity"] == 4
        # load reports must NOT bump the epoch (cached views stay valid)
        cli.report("svc", iid, load=7.5)
        assert cli.epoch() == e0 + 1
        assert cli.resolve("svc")["instances"][0]["load"] == 7.5
        assert cli.services() == ["svc"]
        assert cli.deregister("svc", iid)
        assert cli.epoch() == e0 + 2
        assert cli.resolve("svc")["instances"] == []
        from repro.core.types import MercuryError
        with pytest.raises(MercuryError):
            resolve_service_uris(cli_e, reg_e.uri, "svc")


def test_registry_ttl_expires_silent_instance(reg):
    reg_e, _ = reg
    with Engine("tcp://127.0.0.1:0") as cli_e:
        cli = RegistryClient(cli_e, reg_e.uri)
        cli.register("svc", "tcp://127.0.0.1:1111")   # never reports again
        e1 = cli.epoch()
        deadline = time.time() + 5
        while time.time() < deadline:
            if not cli.resolve("svc")["instances"]:
                break
            time.sleep(0.1)
        assert cli.resolve("svc")["instances"] == []
        assert cli.epoch() > e1


def test_registry_reaps_instances_of_dead_members(reg):
    """An instance bound to a member_id dies with its member (via the
    MembershipServer.on_expire hook), even while it keeps reporting."""
    reg_e, reg_svc = reg
    ms = MembershipServer(reg_e, heartbeat_timeout=0.4, sweep_interval=0.1)
    ms.on_expire(reg_svc._members_expired)
    with Engine("tcp://127.0.0.1:0") as w:
        cli = RegistryClient(w, reg_e.uri)
        w.call(reg_e.uri, "mem.join", {"member_id": "w1", "uri": w.uri})
        iid = cli.register("svc", w.uri, member_id="w1")
        # member w1 never heartbeats; the instance DOES keep reporting,
        # so only the member-expiry path can remove it
        deadline = time.time() + 5
        gone = False
        while time.time() < deadline and not gone:
            try:
                cli.report("svc", iid, load=0.0)
            except RemoteError:
                gone = True                    # NOENTRY: reaped
            time.sleep(0.05)
        assert gone
        assert cli.resolve("svc")["instances"] == []
    ms.close()


# ---------------------------------------------------------------------------
# pool routing
# ---------------------------------------------------------------------------
def test_pool_round_robin_distributes(reg):
    reg_e, _ = reg
    a, b = _echo_engine("a"), _echo_engine("b")
    with a, b, Engine("tcp://127.0.0.1:0") as cli:
        ia = ServiceInstance(a, reg_e.uri, "svc", capacity=4,
                             report_interval=0.1)
        ib = ServiceInstance(b, reg_e.uri, "svc", capacity=4,
                             report_interval=0.1)
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="rr")
        hits = [pool.call("echo", i, timeout=10.0)[0] for i in range(8)]
        assert hits.count("a") == 4 and hits.count("b") == 4
        ia.close(), ib.close()


def test_pool_least_loaded_prefers_idle(reg):
    reg_e, _ = reg
    a, b = _echo_engine("a"), _echo_engine("b")
    with a, b, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        ia = rc.register("svc", a.uri, capacity=4, load=9.0)  # busy
        ib = rc.register("svc", b.uri, capacity=4, load=0.0)  # idle
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="least")
        hits = {pool.call("echo", i, timeout=10.0)[0] for i in range(6)}
        assert hits == {"b"}
        rc.deregister("svc", ia), rc.deregister("svc", ib)


def test_pool_locality_prefers_cheap_tier(reg):
    """Replica advertising a self:// tier must win over a tcp-only one
    for a co-located (same-process) client."""
    reg_e, _ = reg
    tag = uuid.uuid4().hex[:6]
    near = Engine([f"self://near-{tag}", "tcp://127.0.0.1:0"])
    far = _echo_engine("far")
    near.register("echo", lambda x: ("near", x))
    with near, far, Engine([f"self://cli-{tag}",
                            "tcp://127.0.0.1:0"]) as cli:
        rc = RegistryClient(cli, reg_e.uri)
        i1 = rc.register("svc", near.uri, capacity=4)
        i2 = rc.register("svc", far.uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="locality")
        tiers = sorted(r.stat()["tier"] for r in pool.replicas())
        assert tiers == ["self", "tcp"]
        hits = {pool.call("echo", i, timeout=10.0)[0] for i in range(6)}
        assert hits == {"near"}
        rc.deregister("svc", i1), rc.deregister("svc", i2)


# ---------------------------------------------------------------------------
# retries / deadlines / hedging / flow control
# ---------------------------------------------------------------------------
def test_pool_retries_around_dead_replica(reg):
    reg_e, _ = reg
    ok = _echo_engine("ok")
    with ok, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        dead = rc.register("svc", "tcp://127.0.0.1:1", capacity=4)
        live = rc.register("svc", ok.uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="rr",
                           policy=RetryPolicy(attempts=3, rpc_timeout=2.0,
                                              backoff_base=0.01))
        # every call must succeed even when ranked onto the dead one first
        assert all(pool.call("echo", i, timeout=15.0)[0] == "ok"
                   for i in range(6))
        rc.deregister("svc", dead), rc.deregister("svc", live)


def test_pool_deadline_bounds_slow_service(reg):
    reg_e, _ = reg
    slow = Engine("tcp://127.0.0.1:0")
    slow.register("nap", lambda x: time.sleep(3.0) or "late")
    with slow, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        iid = rc.register("svc", slow.uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc",
                           policy=RetryPolicy(attempts=2, rpc_timeout=0.3,
                                              backoff_base=0.01,
                                              jitter=0.0))
        t0 = time.monotonic()
        with pytest.raises(Exception):
            pool.call("nap", None, timeout=0.8)
        elapsed = time.monotonic() - t0
        # never exceeds the deadline by more than one rpc timeout
        assert elapsed < 0.8 + 0.3 + 0.3, elapsed
        rc.deregister("svc", iid)


def test_pool_hedged_request_beats_straggler(reg):
    reg_e, _ = reg
    slow = Engine("tcp://127.0.0.1:0")
    slow.register("work", lambda x: time.sleep(2.0) or "slow")
    fast = Engine("tcp://127.0.0.1:0")
    fast.register("work", lambda x: "fast")
    with slow, fast, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        i1 = rc.register("svc", slow.uri, capacity=4)
        i2 = rc.register("svc", fast.uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="rr",
                           policy=RetryPolicy(attempts=3, rpc_timeout=5.0,
                                              hedge_after=0.1))
        t0 = time.monotonic()
        outs = [pool.call("work", i, timeout=10.0) for i in range(4)]
        dt = time.monotonic() - t0
        assert all(o == "fast" for o in outs)   # hedge wins every time
        assert dt < 2.0, dt                     # never waited for slow
        rc.deregister("svc", i1), rc.deregister("svc", i2)


def test_pool_credit_backpressure(reg):
    reg_e, _ = reg
    release = threading.Event()
    srv = Engine("tcp://127.0.0.1:0")
    srv.register("hold", lambda x: release.wait(10.0) and "held")
    with srv, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        iid = rc.register("svc", srv.uri, capacity=2)
        pool = ServicePool(cli, reg_e.uri, "svc", credits_per_target=2,
                           policy=RetryPolicy(attempts=1, rpc_timeout=15.0))
        import concurrent.futures as cf
        with cf.ThreadPoolExecutor(2) as tp:
            f1 = tp.submit(pool.call, "hold", 1, 12.0)
            f2 = tp.submit(pool.call, "hold", 2, 12.0)
            deadline = time.time() + 5
            while time.time() < deadline:
                if pool.stats()["replicas"][0]["inflight"] == 2:
                    break
                time.sleep(0.02)
            st = pool.stats()["replicas"][0]
            assert st["inflight"] == 2          # both credits consumed
            # third call: saturated -> bounded wait -> backpressure error
            with pytest.raises(BudgetExhausted):
                pool.call("hold", 3, timeout=0.4)
            st = pool.stats()["replicas"][0]
            assert st["backpressured"] >= 1 and st["rejected"] >= 1
            release.set()
            assert f1.result(15) == "held" and f2.result(15) == "held"
        # all credits returned after completion
        assert pool.stats()["replicas"][0]["inflight"] == 0
        rc.deregister("svc", iid)


def test_pool_failover_on_replica_death(reg):
    """Kill a replica abruptly mid-run: no client-visible failure, and
    the TTL sweep (epoch bump) eventually drops it from the view."""
    reg_e, _ = reg
    a, b = _echo_engine("a"), _echo_engine("b")
    ia = ServiceInstance(a, reg_e.uri, "svc", capacity=4,
                         report_interval=0.1)
    ib = ServiceInstance(b, reg_e.uri, "svc", capacity=4,
                         report_interval=0.1)
    with b, Engine("tcp://127.0.0.1:0") as cli:
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="rr",
                           refresh_interval=0.1,
                           policy=RetryPolicy(attempts=4, rpc_timeout=1.0,
                                              backoff_base=0.01))
        assert len(pool.replicas()) == 2
        ia.close(deregister=False)     # heartbeats stop: simulated crash
        a.shutdown()
        # every call still succeeds (retries absorb the dead replica)
        assert all(pool.call("echo", i, timeout=15.0)[0] == "b"
                   for i in range(8))
        deadline = time.time() + 5
        while time.time() < deadline:
            pool.refresh(force=True)
            if len(pool.replicas()) == 1:
                break
            time.sleep(0.1)
        assert len(pool.replicas()) == 1       # epoch bump pruned the dead
        ib.close()


def test_pool_affine_calls_pin_replica(reg):
    """call_routed reports the serving instance; call_on pins follow-ups
    to it (the gen.submit/gen.result pattern: rids are replica-local)."""
    reg_e, _ = reg
    a, b = _echo_engine("a"), _echo_engine("b")
    with a, b, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        ids = {rc.register("svc", e.uri, capacity=4): n
               for e, n in ((a, "a"), (b, "b"))}
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="rr")
        for i in range(6):
            out, iid = pool.call_routed("echo", i, timeout=10.0)
            assert out[0] == ids[iid]          # winner reported truthfully
            # pinned follow-ups always land on the same instance
            assert all(pool.call_on(iid, "echo", j, timeout=10.0)[0]
                       == ids[iid] for j in range(3))
        from repro.fabric import PoolError
        with pytest.raises(BudgetExhausted) as ei:
            pool.call_on("no-such-iid", "echo", 0, timeout=2.0,
                         policy=RetryPolicy(attempts=2, rpc_timeout=0.5,
                                            backoff_base=0.01))
        assert isinstance(ei.value.cause, PoolError)
        for iid in ids:
            rc.deregister("svc", iid)


def test_pool_recovers_replica_after_transient_outage(reg):
    """A replica that was down (marked down / undemotable) must come back
    once reachable again — demotions are soft state, not a tombstone."""
    reg_e, _ = reg
    with Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        srv = _echo_engine("a")
        port_uri = srv.uri
        iid = rc.register("svc", port_uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", down_ttl=0.2,
                           policy=RetryPolicy(attempts=2, rpc_timeout=1.0,
                                              backoff_base=0.01))
        assert pool.call("echo", 1, timeout=10.0)[0] == "a"
        srv.shutdown()                 # transient outage begins
        with pytest.raises(Exception):
            pool.call("echo", 2, timeout=3.0)
        rep = pool.replicas()[0]
        assert not rep.is_up or rep.bad_schemes   # excluded right now
        # replica comes back on a NEW port; re-registers under same iid
        srv2 = _echo_engine("a2")
        rc.register("svc", srv2.uri, capacity=4, iid=iid)
        deadline = time.time() + 5
        ok = False
        while time.time() < deadline and not ok:
            try:
                ok = pool.call("echo", 3, timeout=3.0)[0] == "a2"
            except Exception:
                time.sleep(0.1)
        assert ok                      # recovered, not tombstoned
        srv2.shutdown()
        rc.deregister("svc", iid)


# ---------------------------------------------------------------------------
# tier failover (na/multi + pool demotion)
# ---------------------------------------------------------------------------
def test_multi_lookup_falls_back_past_stale_sm():
    """An address set whose sm tier is unreachable must resolve tcp."""
    tag = uuid.uuid4().hex[:6]
    live = _echo_engine("live")
    with live, Engine([f"sm://mf-cli-{tag}", "tcp://127.0.0.1:0"]) as cli:
        addr = cli.lookup(f"sm://ghost-{tag};{live.uri}")
        assert addr.uri.startswith("tcp://")
        assert cli.call(addr, "echo", 1, timeout=10.0)[0] == "live"


def test_pool_demotes_tier_when_sm_dies_midrun(reg):
    """A replica resolved at the sm tier whose segment goes away must be
    demoted to tcp in the pool's cached view, transparently."""
    reg_e, _ = reg
    tag = uuid.uuid4().hex[:6]
    sm_half = Engine(f"sm://dm-{tag}")
    tcp_half = _echo_engine("tcp-half")
    sm_half.register("echo", lambda x: ("sm-half", x))
    with tcp_half, Engine([f"sm://dmc-{tag}",
                           "tcp://127.0.0.1:0"]) as cli:
        rc = RegistryClient(cli, reg_e.uri)
        iid = rc.register("svc", f"{sm_half.uri};{tcp_half.uri}",
                          capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="locality",
                           policy=RetryPolicy(attempts=3, rpc_timeout=2.0,
                                              backoff_base=0.01))
        rep = pool.replicas()[0]
        assert rep.stat()["tier"] == "sm"
        assert pool.call("echo", 1, timeout=10.0)[0] == "sm-half"
        sm_half.shutdown()             # sm segment vanishes mid-run
        out = pool.call("echo", 2, timeout=15.0)
        assert out[0] == "tcp-half"    # transparent fallback
        assert rep.stat()["tier"] == "tcp" and "sm" in rep.bad_schemes
        rc.deregister("svc", iid)


# ---------------------------------------------------------------------------
# graceful close semantics + event-driven gen.result
# ---------------------------------------------------------------------------
def test_membership_close_joins_sweeper():
    with Engine("tcp://127.0.0.1:0") as e:
        ms = MembershipServer(e, sweep_interval=0.1)
        assert ms._sweeper.is_alive()
        ms.close()
        assert not ms._sweeper.is_alive()
        ms.close()                     # idempotent


class FakeServe:
    """Minimal ServeEngine stand-in: completes each request with one
    token per step — lets gateway plumbing be tested without a model."""

    def __init__(self, n_slots=2, auto=True):
        self.queue = queue.Queue()
        self.work = threading.Event()
        self.n_slots = n_slots
        self.auto = auto
        self.parked = []               # auto=False: admitted, not finished
        self._rid = 0
        self._lock = threading.Lock()

    def submit(self, tokens, max_new=32, temperature=0.0, eos_id=-1,
               frontend=None):
        with self._lock:
            self._rid += 1
            req = Request(self._rid, np.asarray(tokens, np.int32), max_new)
        self.queue.put(req)
        self.work.set()
        return req

    def step(self):
        n = 0
        while True:
            try:
                req = self.queue.get_nowait()
            except queue.Empty:
                return n
            if self.auto:
                req.out_tokens.append(7)
                req.done_event.set()
                req._fire_done()
                n += 1
            else:
                self.parked.append(req)   # test completes them by hand

    def stats(self):
        return {"active_slots": 0, "n_slots": self.n_slots,
                "queued": self.queue.qsize(), "max_len": 64}


def test_gateway_close_joins_step_loop():
    with Engine("tcp://127.0.0.1:0") as e:
        gw = ServingGateway(e, FakeServe())
        assert gw._thread.is_alive()
        gw.close()
        assert not gw._thread.is_alive()
        gw.close()                     # idempotent


def test_gen_result_wait_is_event_driven():
    """Waiting gen.result handlers must not park handler-pool threads:
    with every pool thread's worth of waiters outstanding, an unrelated
    RPC still gets through, and completion wakes all waiters."""
    serve = FakeServe(auto=False)
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        gw = ServingGateway(srv, serve)
        rid = cli.call(srv.uri, "gen.submit", {"tokens": [1, 2]})["rid"]
        deadline = time.time() + 5
        while not serve.parked and time.time() < deadline:
            time.sleep(0.01)
        req = serve.parked[0]                  # admitted, unfinished
        waiters = [cli.call_async(srv.uri, "gen.result",
                                  {"rid": rid, "wait": True,
                                   "timeout": 20.0}, timeout=30.0)
                   for _ in range(4)]          # = srv handler_threads
        time.sleep(0.2)
        # old busy/parked design: all 4 pool threads blocked -> this hangs
        stats = cli.call(srv.uri, "gen.stats", {}, timeout=2.0)
        assert stats["n_slots"] == 2
        req.out_tokens.append(9)
        req.done_event.set()
        req._fire_done()
        outs = [w.result(timeout=10) for w in waiters]
        assert all(o["done"] and o["tokens"] == [9] for o in outs)
        gw.close()


def test_gen_result_wait_times_out_with_partial_tokens():
    serve = FakeServe(auto=False)
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        gw = ServingGateway(srv, serve)
        rid = cli.call(srv.uri, "gen.submit", {"tokens": [1]})["rid"]
        t0 = time.monotonic()
        out = cli.call(srv.uri, "gen.result",
                       {"rid": rid, "wait": True, "timeout": 0.3},
                       timeout=10.0)
        assert not out["done"] and time.monotonic() - t0 < 5.0
        gw.close()


def test_gateway_self_registers_and_routes_through_pool(reg):
    reg_e, _ = reg
    serves = [FakeServe(), FakeServe()]
    engines = [Engine("tcp://127.0.0.1:0") for _ in serves]
    gws = [ServingGateway(e, s, registry=reg_e.uri, service="gen",
                          report_interval=0.1)
           for e, s in zip(engines, serves)]
    with Engine("tcp://127.0.0.1:0") as cli:
        pool = ServicePool(cli, reg_e.uri, "gen", balancer="rr",
                           refresh_interval=0.1,
                           policy=RetryPolicy(attempts=4, rpc_timeout=5.0,
                                              backoff_base=0.01))
        assert len(pool.replicas()) == 2
        outs = [pool.call("gen.generate", {"tokens": [1, 2], "max_new": 4},
                          timeout=15.0) for _ in range(4)]
        assert all(o["done"] for o in outs)
        # capacity was piggybacked from n_slots
        assert all(r.capacity == 2 for r in pool.replicas())
        # kill one replica: calls keep succeeding, view shrinks on expiry
        gws[0].instance.close(deregister=False)
        gws[0].stop()
        engines[0].shutdown()
        assert all(pool.call("gen.generate",
                             {"tokens": [3], "max_new": 2},
                             timeout=15.0)["done"] for _ in range(4))
    gws[1].close()
    engines[1].shutdown()


# ---------------------------------------------------------------------------
# checkpoint / datafeed resolvable by name
# ---------------------------------------------------------------------------
def test_checkpoint_resolvable_by_name(reg):
    from repro.services import CheckpointClient, CheckpointServer
    reg_e, _ = reg
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli_e:
        cs = CheckpointServer(srv, registry=reg_e.uri)
        cli = CheckpointClient(cli_e, registry=reg_e.uri)
        tree = {"w": np.arange(100, dtype=np.float32)}
        assert cli.save("m", 1, tree)["ok"]
        out, step = cli.restore("m", {"w": np.zeros(100, np.float32)})
        assert step == 1
        np.testing.assert_array_equal(out["w"], tree["w"])
        cs.close()


def test_datafeed_resolvable_by_name(reg):
    from repro.data.pipeline import SyntheticSource
    from repro.services import DataFeedClient, DataFeedServer
    reg_e, _ = reg
    src = SyntheticSource(vocab=100, seq_len=16, batch_per_host=2)
    with Engine("tcp://127.0.0.1:0") as fe, \
            Engine("tcp://127.0.0.1:0") as tr:
        fs = DataFeedServer(fe, src, registry=reg_e.uri)
        cli = DataFeedClient(tr, registry=reg_e.uri)
        b = cli.get(3)
        np.testing.assert_array_equal(b["tokens"],
                                      src.batch_at(3)["tokens"])
        fs.close()
