"""Property tests (tests/proptest.py style) for the fabric's
retry/deadline budget: for random latency schedules, a call never
exceeds its deadline by more than one RPC timeout (the clamped design in
fact never exceeds the deadline at all) and never issues more than the
budgeted attempts."""
import numpy as np
import pytest

from proptest import cases
from repro.fabric.policy import (BudgetExhausted, DeadlineExceeded,
                                 NonRetryable, RetryPolicy,
                                 call_with_budget)


class SimClock:
    def __init__(self, t0: float = 0.0):
        self.t = t0

    def __call__(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        assert dt >= 0
        self.t += dt


def _draw_policy(rng) -> RetryPolicy:
    return RetryPolicy(
        attempts=int(rng.integers(1, 6)),
        rpc_timeout=float(rng.uniform(0.05, 2.0)),
        backoff_base=float(rng.uniform(0.001, 0.2)),
        backoff_factor=float(rng.uniform(1.0, 3.0)),
        backoff_max=float(rng.uniform(0.2, 1.0)),
        jitter=float(rng.uniform(0.0, 1.0)))


@cases(n=200, seed=11)
def test_budget_and_deadline_invariants(rng):
    """Random latency schedule + random success point: the driver must
    (a) issue <= policy.attempts attempts, (b) finish by the deadline —
    strictly tighter than the deadline + one-rpc-timeout contract, and
    (c) never sleep backwards."""
    policy = _draw_policy(rng)
    clock = SimClock(float(rng.uniform(0, 100)))
    deadline = clock.t + float(rng.uniform(0.01, 3.0))
    # per-attempt service latency; attempt i succeeds iff i == success_at
    schedule = rng.uniform(0.0, 3.0, size=policy.attempts + 2)
    success_at = int(rng.integers(0, policy.attempts + 2))
    issued = []

    def attempt(idx, timeout):
        issued.append(idx)
        assert timeout > 0
        # timeout is clamped to both the rpc cap and the deadline
        assert timeout <= policy.rpc_timeout + 1e-12
        assert clock.t + timeout <= deadline + 1e-9
        lat = float(schedule[idx])
        if lat >= timeout:            # attempt times out at the transport
            clock.sleep(timeout)
            raise TimeoutError(f"attempt {idx} timed out")
        clock.sleep(lat)
        if idx == success_at:
            return f"ok@{idx}"
        raise ConnectionError(f"attempt {idx} transient failure")

    try:
        out = call_with_budget(policy, deadline, attempt, clock=clock,
                               sleep=clock.sleep, rand=rng.random)
        assert out == f"ok@{success_at}"
    except (BudgetExhausted, DeadlineExceeded):
        pass
    # (a) the attempt budget is an invariant, hedges or not
    assert len(issued) <= policy.attempts, issued
    assert issued == sorted(set(issued))       # each attempt once, in order
    # (b) tight bound: the clamped design never overshoots the deadline
    assert clock.t <= deadline + 1e-9
    # ... which trivially satisfies the documented public contract:
    assert clock.t <= deadline + policy.rpc_timeout + 1e-9


@cases(n=100, seed=23)
def test_backoff_is_bounded_and_jittered(rng):
    policy = _draw_policy(rng)
    for attempt in range(1, policy.attempts + 1):
        r = float(rng.random())
        b = policy.backoff(attempt, r)
        raw = min(policy.backoff_base *
                  (policy.backoff_factor ** (attempt - 1)),
                  policy.backoff_max)
        assert 0.0 <= b <= raw + 1e-12
        assert b >= raw * (1.0 - policy.jitter) - 1e-12


@cases(n=50, seed=37)
def test_nonretryable_aborts_immediately(rng):
    policy = _draw_policy(rng).with_(attempts=int(rng.integers(2, 6)))
    clock = SimClock()
    calls = []

    class AppFault(Exception):
        pass

    def attempt(idx, timeout):
        calls.append(idx)
        raise NonRetryable(AppFault("handler ran and faulted"))

    with pytest.raises(AppFault):
        call_with_budget(policy, clock.t + 10.0, attempt, clock=clock,
                         sleep=clock.sleep, rand=rng.random)
    assert calls == [0]               # no retry after a non-retryable


def test_expired_deadline_fails_fast_without_issuing():
    clock = SimClock(5.0)
    calls = []

    def attempt(idx, timeout):
        calls.append(idx)
        return "nope"

    with pytest.raises(DeadlineExceeded):
        call_with_budget(RetryPolicy(attempts=3), 5.0, attempt,
                         clock=clock, sleep=clock.sleep, rand=lambda: 0.5)
    assert calls == []


def test_budget_exhausted_carries_last_error():
    clock = SimClock()

    def attempt(idx, timeout):
        clock.sleep(0.01)
        raise ConnectionError(f"fail {idx}")

    with pytest.raises(BudgetExhausted) as ei:
        call_with_budget(RetryPolicy(attempts=3, backoff_base=0.01,
                                     jitter=0.0),
                         clock.t + 10.0, attempt, clock=clock,
                         sleep=clock.sleep, rand=lambda: 0.0)
    assert isinstance(ei.value.cause, ConnectionError)
    assert "fail 2" in str(ei.value.cause)
