"""Telemetry plane: the metrics registry (counters/gauges/log-bucket
histograms + the ``fab.metrics`` RPC) and wire-propagated distributed
tracing — header propagation, retry/hedge attempt spans, the quorum
write-proxy hop, the self-tier local-dispatch fast path, and
cross-process span-tree reassembly via ``dbg.trace``."""
import os
import subprocess
import sys
import time

import pytest

from repro.core.executor import Engine
from repro.core.types import MercuryError, Ret
from repro.fabric import (RegistryClient, RegistryService, RetryPolicy,
                          ServiceInstance, ServicePool)
from repro.telemetry import metrics, trace
from repro.telemetry.metrics import MetricsRegistry

LEASE = 0.5
GOSSIP = 0.12


def _wait(pred, timeout=8.0, interval=0.03, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(interval)
    raise AssertionError(f"timed out waiting for {msg}")


@pytest.fixture
def traced():
    """Force 100% head sampling for the test, restore defaults after."""
    prev_sample, prev_enabled = trace.sample_rate(), trace.is_enabled()
    trace.configure(sample=1.0, enabled=True)
    trace.clear()
    yield
    trace.configure(sample=prev_sample, enabled=prev_enabled)
    trace.clear()


@pytest.fixture
def reg():
    with Engine("tcp://127.0.0.1:0") as e:
        svc = RegistryService(e, instance_ttl=5.0, sweep_interval=0.2)
        yield e, svc
        svc.close()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_gauge_histogram_basics():
    r = MetricsRegistry()
    c = r.counter("reqs")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert r.counter("reqs") is c            # idempotent getter

    g = r.gauge("load")
    g.set(2.5)
    assert r.gauge("load").value == 2.5
    live = r.gauge("live", fn=lambda: 7)
    assert live.value == 7.0
    bad = r.gauge("bad", fn=lambda: 1 / 0)
    assert bad.value == 0.0                  # callback failure -> fallback

    h = r.histogram("lat_ms")
    for v in (0.5, 3.0, 3.5, 900.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 4
    assert snap["max"] == 900.0
    assert snap["buckets"]["le_1"] == 1      # 0.5
    assert snap["buckets"]["le_4"] == 2      # 3.0, 3.5
    assert snap["buckets"]["le_1024"] == 1   # 900
    assert h.quantile(0.5) == 4.0
    assert h.quantile(1.0) == 1024.0


def test_labels_and_snapshot_shape():
    r = MetricsRegistry()
    r.counter("hits", service="gen").inc(2)
    r.counter("hits", service="ckpt").inc(1)
    snap = r.snapshot()
    assert snap["counters"]["hits{service=gen}"] == 2
    assert snap["counters"]["hits{service=ckpt}"] == 1
    assert set(snap) == {"counters", "gauges", "histograms"}


def test_fab_metrics_rpc_served_by_every_engine():
    metrics.counter("test.telemetry.probe").inc(3)
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        out = cli.call(srv.uri, "fab.metrics", {})
        assert out["pid"] == os.getpid()
        assert out["metrics"]["counters"]["test.telemetry.probe"] >= 3


# ---------------------------------------------------------------------------
# tracer mechanics
# ---------------------------------------------------------------------------
def test_sampling_modes(traced):
    # sampled root records; its children record
    root = trace.start_trace("op")
    assert root.recorded and root.ctx.sampled
    child = trace.start_span("step", root.ctx)
    child.finish("OK")
    root.finish("OK")
    assert len(trace.spans_for(root.ctx.trace_hex)) == 2

    # unsampled root: context still propagates, nothing records
    trace.configure(sample=0.0)
    root = trace.start_trace("op")
    assert not root.recorded and root.ctx is not None
    child = trace.start_span("step", root.ctx)
    assert not child.recorded
    assert child.ctx.trace_id == root.ctx.trace_id
    child.finish("OK")
    root.finish("OK")
    assert trace.spans_for(root.ctx.trace_hex) == []

    # disabled: no context at all
    trace.configure(enabled=False)
    assert trace.start_trace("op") is trace.NULL_SPAN
    assert trace.start_span("step", None) is trace.NULL_SPAN


def test_ring_is_bounded(traced):
    trace.configure(ring=8)
    for _ in range(50):
        trace.start_trace("x").finish()
    assert len(trace.export()["spans"]) == 8
    trace.configure(ring=4096)


def test_build_tree_dedups_and_joins(traced):
    root = trace.start_trace("root")
    a = trace.start_span("a", root.ctx)
    b = trace.start_span("b", a.ctx)
    b.finish()
    a.finish()
    root.finish()
    spans = trace.spans_for(root.ctx.trace_hex)
    roots, children = trace.build_tree(spans + spans)   # union may dup
    assert len(roots) == 1 and roots[0]["name"] == "root"
    tree = trace.format_tree(spans)
    assert tree.splitlines()[0].startswith("root")
    assert "    b" in tree                              # depth 2 indent


# ---------------------------------------------------------------------------
# wire propagation
# ---------------------------------------------------------------------------
def test_server_span_rides_the_wire(traced):
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        srv.register("echo", lambda x: x)
        root = trace.start_trace("client.op")
        with trace.use(root.ctx):
            assert cli.call(srv.uri, "echo", 42) == 42
        root.finish("OK")
        spans = trace.spans_for(root.ctx.trace_hex)
        srv_spans = [s for s in spans if s["name"] == "rpc.echo"]
        assert len(srv_spans) == 1
        s = srv_spans[0]
        assert s["parent"] == f"{root.ctx.span_id:016x}"
        assert s["tags"]["engine"] == srv.uri
        assert s["tags"]["local"] is False
        assert s["status"] == "OK"
        roots, _ = trace.build_tree(spans)
        assert len(roots) == 1


def test_local_dispatch_span(traced):
    """The PR-6 self-tier fast path hands the context object across
    directly — the server span still appears, tagged local=True."""
    with Engine(None) as e:
        e.register("echo", lambda x: x + 1)
        root = trace.start_trace("client.op")
        with trace.use(root.ctx):
            assert e.call(e.uri, "echo", 1) == 2
        root.finish("OK")
        spans = trace.spans_for(root.ctx.trace_hex)
        srv = [s for s in spans if s["name"] == "rpc.echo"]
        assert len(srv) == 1 and srv[0]["tags"]["local"] is True
        roots, _ = trace.build_tree(spans)
        assert len(roots) == 1


def test_unsampled_requests_record_nothing(traced):
    trace.configure(sample=0.0)
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        srv.register("echo", lambda x: x)
        root = trace.start_trace("client.op")
        with trace.use(root.ctx):
            cli.call(srv.uri, "echo", 1)
        root.finish("OK")
        assert trace.spans_for(root.ctx.trace_hex) == []


# ---------------------------------------------------------------------------
# pool: retry / hedge attempt spans
# ---------------------------------------------------------------------------
def test_retry_yields_one_connected_trace(traced, reg):
    """A replica that sheds the first call (AGAIN) forces a retry: the
    trace must show one root pool span with two attempt children, the
    first AGAIN and the second OK, each with its server span below."""
    reg_e, _ = reg
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] == 1:
            raise MercuryError(Ret.AGAIN, "warming up")
        return x * 2

    srv = Engine("tcp://127.0.0.1:0")
    srv.register("work", flaky)
    with srv, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        rc.register("svc", srv.uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc",
                           policy=RetryPolicy(attempts=3, rpc_timeout=5.0,
                                              backoff_base=0.01, jitter=0.0))
        assert pool.call("work", 21, timeout=10.0) == 42

        # span ring is fed from done-callbacks; settle briefly
        _wait(lambda: any(s["name"] == "pool.svc.work"
                          for s in trace.export()["spans"]),
              msg="pool root span")
        root_span = [s for s in trace.export()["spans"]
                     if s["name"] == "pool.svc.work"][0]
        spans = trace.spans_for(root_span["trace"])
        attempts = sorted((s for s in spans if s["name"] == "attempt.work"),
                          key=lambda s: s["tags"]["n"])
        assert [a["status"] for a in attempts] == ["AGAIN", "OK"]
        servers = [s for s in spans if s["name"] == "rpc.work"]
        assert sorted(s["status"] for s in servers) == ["AGAIN", "OK"]
        assert root_span["status"] == "OK"
        assert root_span["tags"]["attempts"] == 2
        roots, _ = trace.build_tree(spans)
        assert len(roots) == 1 and roots[0]["span"] == root_span["span"]


def test_hedge_loser_span_closes_canceled(traced, reg):
    reg_e, _ = reg
    slow = Engine("tcp://127.0.0.1:0")
    slow.register("work", lambda x: time.sleep(2.0) or "slow")
    fast = Engine("tcp://127.0.0.1:0")
    fast.register("work", lambda x: "fast")
    with slow, fast, Engine("tcp://127.0.0.1:0") as cli:
        rc = RegistryClient(cli, reg_e.uri)
        rc.register("svc", slow.uri, capacity=4)
        rc.register("svc", fast.uri, capacity=4)
        pool = ServicePool(cli, reg_e.uri, "svc", balancer="rr",
                           policy=RetryPolicy(attempts=3, rpc_timeout=5.0,
                                              hedge_after=0.05))
        # rr alternates the primary: within two calls one of them hedges
        # from the slow replica to the fast one
        outs = [pool.call("work", i, timeout=10.0) for i in range(2)]
        assert all(o == "fast" for o in outs)
        _wait(lambda: any(s["status"] == "CANCELED"
                          for s in trace.export()["spans"]),
              msg="canceled hedge-loser span")
        hedged = [s for s in trace.export()["spans"]
                  if s["name"] == "attempt.work" and s["tags"]["hedge"]]
        assert hedged, "no hedge attempt span recorded"
        trace_id = hedged[0]["trace"]
        spans = trace.spans_for(trace_id)
        statuses = sorted(s["status"] for s in spans
                          if s["name"] == "attempt.work")
        assert statuses == ["CANCELED", "OK"]
        roots, _ = trace.build_tree(spans)
        assert len(roots) == 1


# ---------------------------------------------------------------------------
# quorum write-proxy hop
# ---------------------------------------------------------------------------
def test_write_proxy_hop_joins_the_trace(traced):
    """A write sent to a follower is proxied to the leaseholder; the
    trace shows client root -> follower server span -> proxy span ->
    leader server span, one connected tree."""
    engines = [Engine("tcp://127.0.0.1:0") for _ in range(3)]
    peers = [e.uri for e in engines]
    regs = [RegistryService(e, peers=peers, lease_ttl=LEASE,
                            gossip_interval=GOSSIP, sweep_interval=0.2,
                            instance_ttl=5.0)
            for e in engines]
    try:
        _wait(lambda: regs[0].is_leader, msg="leader election")
        with Engine("tcp://127.0.0.1:0") as cli:
            follower = RegistryClient(cli, peers[1])
            root = trace.start_trace("client.write")
            with trace.use(root.ctx):
                follower.register("svc", "tcp://127.0.0.1:1111", capacity=1)
            root.finish("OK")
            spans = trace.spans_for(root.ctx.trace_hex)
            names = [s["name"] for s in spans]
            assert names.count("rpc.fab.register") == 2   # follower+leader
            proxies = [s for s in spans
                       if s["name"] == "proxy.fab.register"]
            assert len(proxies) == 1
            assert proxies[0]["tags"]["leader"] == regs[0].self_uri
            roots, children = trace.build_tree(spans)
            assert len(roots) == 1 and roots[0]["name"] == "client.write"
            # leader's server span hangs below the proxy span
            below_proxy = children.get(proxies[0]["span"], [])
            assert [s["name"] for s in below_proxy] == ["rpc.fab.register"]
    finally:
        for r in regs:
            r.close()
        for e in engines:
            e.shutdown()


# ---------------------------------------------------------------------------
# cross-process reassembly via dbg.trace
# ---------------------------------------------------------------------------
_WORKER_SRC = r"""
import sys, time
from repro.core.executor import Engine
e = Engine("tcp://127.0.0.1:0")
e.register("work", lambda x: x * 2)
print(e.uri, flush=True)
sys.stdin.readline()
e.shutdown()
"""


def test_dbg_trace_reassembles_across_processes(traced, tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.Popen([sys.executable, "-c", _WORKER_SRC],
                            stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                            text=True, env=env,
                            cwd=os.path.dirname(os.path.dirname(
                                os.path.abspath(__file__))))
    try:
        uri = proc.stdout.readline().strip()
        assert uri.startswith("tcp://"), uri
        with Engine("tcp://127.0.0.1:0") as cli:
            root = trace.start_trace("client.op")
            with trace.use(root.ctx):
                assert cli.call(uri, "work", 21, timeout=20.0) == 42
            root.finish("OK")
            remote = cli.call(uri, "dbg.trace",
                              {"trace_id": root.ctx.trace_hex},
                              timeout=20.0)
        assert remote["pid"] != os.getpid()
        spans = trace.spans_for(root.ctx.trace_hex) + remote["spans"]
        roots, _ = trace.build_tree(spans)
        assert len(roots) == 1 and roots[0]["name"] == "client.op"
        assert len({s["pid"] for s in spans}) == 2
        srv = [s for s in spans if s["name"] == "rpc.work"]
        assert len(srv) == 1 and srv[0]["pid"] == remote["pid"]
    finally:
        try:
            proc.stdin.close()
        except Exception:
            pass
        proc.wait(timeout=10.0)
