"""Bulk layer: one-sided transfers, multi-segment offset resolution,
permissions, descriptor wire format."""
import numpy as np
import pytest

from repro.core.bulk import BulkDescriptor, BulkOpType
from repro.core.executor import Engine
from repro.core.types import MercuryError

from proptest import cases, draw_descriptor, draw_truncation


@pytest.fixture
def pair():
    with Engine("tcp://127.0.0.1:0") as a, Engine("tcp://127.0.0.1:0") as b:
        yield a, b


def test_descriptor_roundtrip():
    with Engine(None) as e:
        h = e.expose([np.arange(10, dtype=np.float32),
                      np.arange(5, dtype=np.int64)])
        d = h.descriptor()
        d2 = BulkDescriptor.from_bytes(d.to_bytes())
        assert d2.owner_uri == d.owner_uri
        assert [s.size for s in d2.segments] == [40, 40]


def test_pull_and_push(pair):
    a, b = pair
    src = np.arange(500_000, dtype=np.float32)
    ha = a.expose([src])
    dst = np.zeros_like(src)
    hb = b.expose([dst])
    b.pull(a.uri, ha.descriptor(), hb)
    np.testing.assert_array_equal(dst, src)

    dst2 = np.zeros_like(src)
    ha2 = a.expose([dst2], read=False, write=True)
    b.push(a.uri, ha2.descriptor(), hb)          # push dst (== src) to a
    np.testing.assert_array_equal(dst2, src)


@cases(10)
def test_multisegment_offsets(rng):
    # segment-crossing (offset, size) windows must resolve exactly
    with Engine(None) as e:
        segs = [np.asarray(rng.integers(0, 255, size=int(rng.integers(3, 40))),
                           dtype=np.uint8) for _ in range(3)]
        flat = np.concatenate(segs)
        h = e.expose(segs)
        total = flat.size
        off = int(rng.integers(0, total - 1))
        size = int(rng.integers(1, total - off))
        dst = np.zeros(size, dtype=np.uint8)
        hd = e.expose([dst])
        e.pull(e.uri, h.descriptor(), hd, remote_offset=off, size=size,
               chunk_size=7)
        np.testing.assert_array_equal(dst, flat[off:off + size])


def test_permission_enforced(pair):
    a, b = pair
    secret = np.arange(10, dtype=np.float32)
    ha = a.expose([secret], read=False, write=False)
    dst = np.zeros_like(secret)
    hb = b.expose([dst])
    with pytest.raises(MercuryError):
        b.pull(a.uri, ha.descriptor(), hb)


def test_pipelined_chunks_complete(pair):
    a, b = pair
    src = np.arange(1_000_000, dtype=np.uint8)
    ha = a.expose([src])
    dst = np.zeros_like(src)
    hb = b.expose([dst])
    b.pull(a.uri, ha.descriptor(), hb, chunk_size=64 * 1024, max_inflight=8)
    np.testing.assert_array_equal(dst, src)


# ---------------------------------------------------------------------------
# Descriptor wire-format properties (Hypothesis-style, see proptest.py)
# ---------------------------------------------------------------------------
@cases(80)
def test_descriptor_roundtrip_property(rng):
    """∀ descriptors: from_bytes(to_bytes(d)) preserves every field."""
    d = draw_descriptor(rng)
    d2 = BulkDescriptor.from_bytes(d.to_bytes())
    assert d2.owner_uri == d.owner_uri
    assert d2.read_allowed == d.read_allowed
    assert d2.write_allowed == d.write_allowed
    assert [(s.key, s.size) for s in d2.segments] == \
        [(s.key, s.size) for s in d.segments]
    assert d2.size == d.size


@cases(80)
def test_descriptor_truncated_raises(rng):
    """∀ strict prefixes of a descriptor encoding: from_bytes must raise
    (struct underflow), never return a silently mangled descriptor."""
    import struct as _struct
    d = draw_descriptor(rng)
    data = d.to_bytes()
    cut = draw_truncation(rng, data)
    if len(cut) == len(data):
        return
    with pytest.raises((MercuryError, _struct.error, ValueError)):
        BulkDescriptor.from_bytes(cut)


def test_descriptor_accepts_memoryview():
    d = BulkDescriptor("tcp://h:1", [])
    assert BulkDescriptor.from_bytes(memoryview(d.to_bytes())).segments == []
