"""Per-architecture smoke + consistency tests (reduced configs, CPU).

For every assigned arch: one train step runs, outputs have the right
shapes, loss is finite and non-NaN; the incremental decode path matches a
fresh full prefill bit-for-bit (within f32 tolerance)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import Model, unzip

ARCHS = configs.names()
KEY = jax.random.PRNGKey(7)
RNG = jax.random.PRNGKey(0)


def make_batch(cfg, B, S, with_targets=True):
    F = cfg.frontend_seq if cfg.family == "vlm" else 0
    toks = jax.random.randint(KEY, (B, S - F), 0, cfg.vocab)
    b = {"tokens": toks}
    if cfg.family == "vlm":
        b["frontend"] = jax.random.normal(KEY, (B, F, cfg.frontend_dim)) * .1
        if with_targets:
            pad = jnp.full((B, F), -1, jnp.int32)
            b["targets"] = jnp.concatenate(
                [pad, jax.random.randint(KEY, (B, S - F), 0, cfg.vocab)], 1)
    else:
        if cfg.family in ("encdec", "audio"):
            b["frontend"] = jax.random.normal(
                KEY, (B, cfg.frontend_seq, cfg.frontend_dim)) * .1
        if with_targets:
            b["targets"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.reduced(arch)
    m = Model(cfg)
    params, axes = unzip(m.init(RNG))
    batch = make_batch(cfg, 2, 64)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(
            lambda p, b: m.loss_fn(p, b, impl="xla", remat="block"),
            has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), arch
    # all grads finite, at least one nonzero
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in leaves)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves)
    # params and axes trees are parallel (Axes leaves are natural leaves)
    p_leaves = jax.tree_util.tree_leaves(params)
    a_leaves = jax.tree_util.tree_leaves(axes)
    assert len(p_leaves) == len(a_leaves)
    for p, a in zip(p_leaves, a_leaves):
        assert p.ndim == len(a), (p.shape, a)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = configs.reduced(arch).replace(compute_dtype="float32")
    m = Model(cfg)
    params, _ = unzip(m.init(RNG))
    B, S, EXT = 2, 48, 4
    F = cfg.frontend_seq if cfg.family == "vlm" else 0
    all_text = jax.random.randint(KEY, (B, S + EXT - F), 0, cfg.vocab)
    fe = None
    if cfg.family == "vlm":
        fe = jax.random.normal(KEY, (B, F, cfg.frontend_dim)) * 0.1
    elif cfg.family in ("encdec", "audio"):
        fe = jax.random.normal(KEY, (B, cfg.frontend_seq, cfg.frontend_dim)) * .1

    def mk(n):
        b = {"tokens": all_text[:, :n]}
        if fe is not None:
            b["frontend"] = fe
        return b

    pf = jax.jit(lambda p, b: m.prefill(p, b, cache_len=S + 8, impl="xla"))
    lg, cache = pf(params, mk(S - F))
    want, _ = pf(params, mk(S + EXT - F))
    step = jax.jit(lambda p, c, t, pos: m.decode_step(p, c, t, pos,
                                                      impl="xla"))
    for i in range(EXT):
        pos = S + i
        lg, cache = step(params, cache, all_text[:, pos - F][..., None],
                         jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-1.3b",
                                  "recurrentgemma-9b"])
def test_vector_pos_decode_matches_scalar(arch):
    """Continuous-batching (vector pos) decode == lockstep (scalar pos)."""
    cfg = configs.reduced(arch).replace(compute_dtype="float32")
    m = Model(cfg)
    params, _ = unzip(m.init(RNG))
    B, S = 2, 32
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    _, cache = jax.jit(lambda p, b: m.prefill(p, b, cache_len=S + 4,
                                              impl="xla"))(
        params, {"tokens": toks[:, :S]})
    lg_s, _ = m.decode_step(params, cache, toks[:, S:S + 1], jnp.int32(S),
                            impl="xla")
    lg_v, _ = m.decode_step(params, cache, toks[:, S:S + 1],
                            jnp.full((B,), S, jnp.int32), impl="xla")
    np.testing.assert_allclose(np.asarray(lg_v), np.asarray(lg_s),
                               rtol=2e-4, atol=2e-4)


def test_long_context_flags():
    longs = [a for a in ARCHS if "long_500k" in configs.shapes_for(a)]
    assert sorted(longs) == ["gemma3-12b", "mamba2-1.3b",
                             "recurrentgemma-9b"]


def test_param_counts_match_published():
    expect = {  # billions, loose band vs published sizes
        "granite-moe-3b-a800m": (2.5, 4.0),
        "deepseek-moe-16b": (15.0, 18.0),
        "gemma3-12b": (10.0, 13.5),
        "qwen1.5-0.5b": (0.4, 0.65),
        "nemotron-4-340b": (320.0, 360.0),
        "command-r-35b": (28.0, 38.0),
        "recurrentgemma-9b": (7.5, 10.0),
        "mamba2-1.3b": (1.2, 1.5),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get(arch).param_count() / 1e9
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    g = configs.get("granite-moe-3b-a800m")
    assert g.active_param_count() < 0.35 * g.param_count()
    d = configs.get("deepseek-moe-16b")
    assert 2.0e9 < d.active_param_count() < 3.5e9
