"""Replicated control plane (DESIGN.md §8): deterministic leader lease,
delta-gossip replication to followers (with full-snapshot fallback),
follower write proxying, client endpoint failover, leaseholder kill
mid-run (pools converge to a survivor within one refresh interval with
zero client-visible resolution errors), restart resync (a restarted
replica adopts the acting leader's snapshot before it may reclaim the
lease), and the membership plane folded into the registry quorum
(members survive leaseholder death; expiry reaps fire exactly once from
the new leader)."""
import threading
import time

import pytest

from conftest import poll_until
from repro.core.executor import Engine
from repro.core.types import MercuryError, Ret
from repro.fabric import (PeerTracker, RegistryClient, RegistryService,
                          ReplicatedTable, RetryPolicy, ServiceInstance,
                          ServicePool, parse_registry_uris)
from repro.services import MembershipClient, MembershipServer

LEASE = 0.5
GOSSIP = 0.12


def _wait(pred, timeout=8.0, interval=0.03, msg="condition"):
    poll_until(pred, timeout=timeout, interval=interval, msg=msg)


def _mk_cluster(n=3, instance_ttl=5.0):
    engines = [Engine("tcp://127.0.0.1:0") for _ in range(n)]
    peers = [e.uri for e in engines]
    regs = [RegistryService(e, peers=peers, lease_ttl=LEASE,
                            gossip_interval=GOSSIP, sweep_interval=0.1,
                            instance_ttl=instance_ttl)
            for e in engines]
    return engines, peers, regs


@pytest.fixture
def cluster():
    engines, peers, regs = _mk_cluster()
    # cold start: rank 0 self-elects after its boot grace (one lease)
    _wait(lambda: regs[0].is_leader, msg="rank-0 leadership")
    yield engines, peers, regs
    for r in regs:
        r.close()
    for e in engines:
        try:
            e.shutdown()
        except Exception:
            pass


def _echo_engine(name):
    e = Engine("tcp://127.0.0.1:0")
    e.register("echo", lambda x, _n=name: (_n, x))
    return e


# ---------------------------------------------------------------------------
# lease bookkeeping (pure)
# ---------------------------------------------------------------------------
def test_peer_tracker_lease_and_grace():
    t = [0.0]
    tr = PeerTracker(["a", "b", "c"], "b", lease_ttl=1.0,
                     clock=lambda: t[0])
    # boot grace: a (optimistically alive) leads; self is deferred
    assert tr.in_grace() and tr.leader_uri() == "a"
    t[0] = 1.5                      # grace over, a's lease expired
    assert not tr.in_grace()
    assert tr.leader_uri() == "b"   # we are the best live peer
    tr.note("a")                    # a came back
    assert tr.leader_uri() == "a"
    t[0] = 3.0                      # a silent past the lease again
    assert tr.leader_uri() == "b"
    stats = {p["uri"]: p for p in tr.peer_stats()}
    assert stats["b"]["self"] and not stats["a"]["alive"]


def test_peer_tracker_grace_with_all_peers_dead():
    t = [0.0]
    tr = PeerTracker(["a", "b"], "a", lease_ttl=1.0, clock=lambda: t[0])
    t[0] = 0.5
    # in grace, nobody heard, self deferred: leadership unknowable
    assert tr.leader_uri() == "b"   # b still within its optimistic lease
    tr.mark_synced()                # adopted a snapshot: grace over early
    assert tr.leader_uri() == "a"


def test_parse_registry_uris_rejects_empty():
    with pytest.raises(ValueError):
        parse_registry_uris("  , ,")
    assert parse_registry_uris("a;b,c") == ["a;b", "c"]


# ---------------------------------------------------------------------------
# gossip replication
# ---------------------------------------------------------------------------
def test_cluster_elects_lowest_rank_and_agrees(cluster):
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        for uri in peers:
            st = cli.call(uri, "fab.status", {}, timeout=5.0)
            assert st["leader"] == peers[0], st
        assert regs[0].is_leader
        assert not regs[1].is_leader and not regs[2].is_leader
        roles = [cli.call(u, "fab.status", {}, timeout=5.0)["role"]
                 for u in peers]
        assert roles == ["leader", "follower", "follower"]


def test_register_replicates_to_follower_reads(cluster):
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        lead = RegistryClient(cli, peers[0])
        iid = lead.register("svc", "tcp://127.0.0.1:1111", capacity=4)
        # followers serve the mirrored view (reads never proxy)
        for uri in peers[1:]:
            follower = RegistryClient(cli, uri)
            _wait(lambda f=follower: [i["iid"] for i in
                                      f.resolve("svc")["instances"]] == [iid],
                  msg="gossip replication to follower")
            e, n = follower.epoch_info()
            le, ln = lead.epoch_info()
            assert (e, n) == (le, ln)   # same stream: nonce + epoch match


def test_follower_proxies_writes_to_leaseholder(cluster):
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        fol = RegistryClient(cli, peers[2])      # follower endpoint only
        iid = fol.register("svc", "tcp://127.0.0.1:2222", capacity=1)
        # the write landed on the leader's authoritative table
        assert any(i["iid"] == iid for i in
                   RegistryClient(cli, peers[0]).resolve("svc")["instances"])
        # load reports proxy too, and application errors pass through:
        fol.report("svc", iid, load=3.0)
        with pytest.raises(MercuryError) as ei:
            fol.report("svc", "nonexistent-iid", load=0.0)
        assert ei.value.ret == Ret.NOENTRY
        assert fol.deregister("svc", iid)


def test_registry_client_rotates_past_dead_endpoint(cluster):
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        dead = "tcp://127.0.0.1:1"               # nothing listens there
        c = RegistryClient(cli, [dead] + peers, timeout=5.0)
        iid = c.register("svc", "tcp://127.0.0.1:3333")
        assert c.resolve("svc")["instances"][0]["iid"] == iid
        # sticky: after one failover the live endpoint is preferred
        assert c.registry != dead


def test_registration_during_cold_boot_succeeds():
    """A write racing the quorum's cold start (every replica still in
    boot grace → AGAIN everywhere) must succeed once the lease settles:
    RegistryClient re-probes within its timeout budget instead of
    surfacing the transient — real launchers can't spin on is_leader."""
    engines, peers, regs = _mk_cluster()
    try:
        with Engine("tcp://127.0.0.1:0") as cli:
            c = RegistryClient(cli, peers, timeout=8.0)
            iid = c.register("svc", "tcp://127.0.0.1:6666")   # no wait
            # the sticky client may read a FOLLOWER's mirror, which is
            # documented to lag the proxied write by ≤ one gossip round
            _wait(lambda: [i["iid"] for i in
                           c.resolve("svc")["instances"]] == [iid],
                  msg="registration visible after cold boot")
    finally:
        for r in regs:
            r.close()
        for e in engines:
            e.shutdown()


def test_follower_hosted_membership_reaps_via_leader(cluster):
    """A MembershipServer co-hosted on a FOLLOWER node: its expiries are
    resolved against the follower's mirror and forwarded to the
    leaseholder as deregisters — the member-bound instance dies with its
    member even though it keeps reporting."""
    engines, peers, regs = cluster
    ms = MembershipServer(engines[2], heartbeat_timeout=0.4,
                          sweep_interval=0.1)
    ms.on_expire(regs[2]._members_expired)
    with Engine("tcp://127.0.0.1:0") as w:
        cli = RegistryClient(w, peers)
        w.call(peers[2], "mem.join", {"member_id": "w1", "uri": w.uri})
        iid = cli.register("svc", w.uri, member_id="w1")
        # member w1 never heartbeats; the instance DOES keep reporting,
        # so only the (forwarded) member-expiry path can remove it
        def _reaped():
            try:
                cli.report("svc", iid, load=0.0)
                return False
            except MercuryError as e:
                return e.ret == Ret.NOENTRY
        _wait(_reaped, interval=0.05,
              msg="member-bound instance reaped with its member")
        assert cli.resolve("svc")["instances"] == []
    ms.close()


# ---------------------------------------------------------------------------
# leaseholder kill mid-run (the ISSUE acceptance scenario)
# ---------------------------------------------------------------------------
def test_leader_kill_pools_converge_with_zero_resolution_errors(cluster):
    """Kill the leaseholder under routed load: every pool call keeps
    succeeding (client endpoint failover + follower read-serving), the
    next-ranked replica takes the lease, and the pool's view resyncs
    onto the survivor's fresh stream within one refresh interval."""
    engines, peers, regs = cluster
    srv_a, srv_b = _echo_engine("a"), _echo_engine("b")
    with srv_a, srv_b, Engine("tcp://127.0.0.1:0") as cli:
        insts = [ServiceInstance(s, peers, "svc", capacity=4,
                                 report_interval=0.1)
                 for s in (srv_a, srv_b)]
        refresh = 0.2
        pool = ServicePool(cli, peers, "svc", refresh_interval=refresh,
                           policy=RetryPolicy(attempts=3, rpc_timeout=2.0,
                                              backoff_base=0.01))
        assert len(pool.replicas()) == 2
        errors, stop = [], threading.Event()
        ok = [0]

        def drive():
            i = 0
            while not stop.is_set():
                try:
                    pool.call("echo", i, timeout=5.0)
                    ok[0] += 1           # int += is GIL-atomic enough here
                except Exception as e:   # noqa: BLE001 — surfaced below
                    errors.append(repr(e))
                i += 1

        # daemons: a failed assertion above must not leave live driver
        # threads blocking interpreter exit (that reads as a CI hang)
        threads = [threading.Thread(target=drive, daemon=True)
                   for _ in range(4)]
        for t in threads:
            t.start()
        _wait(lambda: ok[0] >= 20, msg="drivers routing before the kill")

        regs[0].close()                  # kill the leaseholder abruptly
        engines[0].shutdown()
        t_kill = time.monotonic()

        # pools fail over to a surviving replica within ~one refresh
        # interval: the control plane answers again immediately
        _wait(lambda: pool.registry.epoch_info() is not None,
              timeout=refresh + 2.0, msg="client failover")
        # the lease moves to the next-ranked survivor...
        _wait(lambda: regs[1].is_leader, msg="rank-1 takeover")
        takeover_s = time.monotonic() - t_kill
        # ...and the pool resyncs onto the new stream (nonce change).
        # The survivor's nonce is read inside the predicate: a lease
        # flap around the kill can mint a transient stream that is
        # replaced by the post-kill takeover — comparing against a
        # one-shot capture would wait on a nonce that no longer exists.
        _wait(lambda: (pool.refresh(force=True) or
                       (regs[1].is_leader
                        and pool._view_nonce == regs[1].nonce)),
              msg="pool resync onto survivor stream")
        resynced = ok[0]                 # keep routing on the new stream
        _wait(lambda: ok[0] >= resynced + 10,
              msg="routed calls succeeding on the new stream")
        stop.set()
        for t in threads:
            t.join(timeout=10)
        assert not errors, f"client-visible failures: {errors[:3]}"
        assert takeover_s < LEASE + 2.0
        # registrations survived the failover (mirror promoted, not lost)
        assert len(pool.replicas()) == 2
        for inst in insts:
            inst.close()


# ---------------------------------------------------------------------------
# restart resync
# ---------------------------------------------------------------------------
def test_restarted_follower_resyncs_from_leader(cluster):
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        RegistryClient(cli, peers[0]).register("svc", "tcp://127.0.0.1:4444")
        port = int(peers[2].rsplit(":", 1)[1])
        regs[2].close()
        engines[2].shutdown()
        # restart rank 2 on the same configured uri: empty table, boot
        # grace, adopts the acting leader's snapshot
        engines[2] = Engine(f"tcp://127.0.0.1:{port}")
        regs[2] = RegistryService(engines[2], peers=peers, lease_ttl=LEASE,
                                  gossip_interval=GOSSIP,
                                  sweep_interval=0.1, instance_ttl=5.0)
        fol = RegistryClient(cli, peers[2])
        _wait(lambda: fol.resolve("svc")["instances"],
              msg="restarted follower resync")
        assert fol.epoch_info() == RegistryClient(cli,
                                                  peers[0]).epoch_info()
        assert not regs[2].is_leader


def test_restarted_leader_resyncs_before_reclaiming_lease(cluster):
    """Kill rank 0; rank 1 takes over and keeps accepting writes.  A
    restarted rank 0 must adopt rank 1's snapshot BEFORE reclaiming the
    lease — registrations written during its absence survive."""
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        port = int(peers[0].rsplit(":", 1)[1])
        regs[0].close()
        engines[0].shutdown()
        _wait(lambda: regs[1].is_leader, msg="rank-1 takeover")
        # a write accepted by the acting leader while rank 0 is down
        iid = RegistryClient(cli, peers[1:]).register(
            "svc", "tcp://127.0.0.1:5555", capacity=2)

        engines[0] = Engine(f"tcp://127.0.0.1:{port}")
        regs[0] = RegistryService(engines[0], peers=peers, lease_ttl=LEASE,
                                  gossip_interval=GOSSIP,
                                  sweep_interval=0.1, instance_ttl=5.0)
        # rank 0 resyncs, then reclaims the lease; rank 1 steps down
        _wait(lambda: regs[0].is_leader, msg="rank-0 reclaim")
        _wait(lambda: not regs[1].is_leader, msg="rank-1 step-down")
        view = RegistryClient(cli, peers[0]).resolve("svc")
        assert [i["iid"] for i in view["instances"]] == [iid], \
            "write during the leader's absence was lost"
        # all replicas converge onto the reclaimed stream
        for uri in peers:
            _wait(lambda u=uri: (RegistryClient(cli, u).epoch_info()
                                 == (regs[0].epoch, regs[0].nonce)),
                  msg="stream convergence after reclaim")


# ---------------------------------------------------------------------------
# client read cache vs the replicated control plane (DESIGN.md §9)
# ---------------------------------------------------------------------------
def test_read_cache_invalidation_across_failover(cluster):
    """Each invalidation trigger — epoch bump, TTL expiry, nonce change
    (leaseholder failover) — must evict the client read cache, and no
    read after the failover may be served from the dead leader's epoch
    stream."""
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli, \
            Engine("tcp://127.0.0.1:0") as cli2:
        # 60s TTL: within this test only *token* invalidation can evict
        cached = RegistryClient(cli, peers, cache_ttl=60.0)
        writer = RegistryClient(cli2, peers)   # its writes are invisible
                                               # to `cached`'s token
        iids = ["aaaaaaaaaaaa", "bbbbbbbbbbbb", "cccccccccccc"]
        writer.register("svc", "tcp://127.0.0.1:4441", iid=iids[0])
        _wait(lambda: cached.resolve("svc", fresh=True)["instances"],
              msg="initial view")
        assert len(cached.resolve("svc")["instances"]) == 1   # cached now

        def keepalive(known):
            # same-iid/same-uris re-register refreshes the instance TTL
            # stamp without bumping the epoch (see RegistryService)
            for i, iid in enumerate(iids[:known]):
                writer.register("svc", f"tcp://127.0.0.1:444{i + 1}",
                                iid=iid)

        # --- epoch bump (another client's write) evicts via the poll
        writer.register("svc", "tcp://127.0.0.1:4442", iid=iids[1])

        def sees_two():
            keepalive(2)
            cached.epoch_info(fresh=True)      # observe the authority
            return len(cached.resolve("svc")["instances"]) == 2

        _wait(sees_two, msg="epoch-bump eviction")

        # --- TTL expiry evicts with no token feed at all
        short = RegistryClient(cli, peers, cache_ttl=0.15)
        _wait(lambda: len(short.resolve("svc", fresh=True)["instances"]) == 2,
              msg="short-ttl warm view")
        writer.register("svc", "tcp://127.0.0.1:4443", iid=iids[2])

        def ttl_sees_three():
            keepalive(3)
            # no fresh=, no observe: only TTL lapse explains a refetch
            return len(short.resolve("svc")["instances"]) == 3
        _wait(ttl_sees_three, msg="TTL-expiry eviction")

        # --- leaseholder kill: nonce change must evict, and no read
        # may come from the dead leader's stream afterwards
        regs[0].close()
        engines[0].shutdown()
        _wait(lambda: regs[1].is_leader, msg="rank-1 takeover")

        def resynced():
            try:
                keepalive(3)
                _, nonce = cached.epoch_info(fresh=True)
            except MercuryError:
                return False                   # failing over between replicas
            if nonce != regs[1].nonce:
                return False                   # survivors still converging
            view = cached.resolve("svc")       # served under the new token
            return (view.get("nonce") == regs[1].nonce
                    and len(view["instances"]) == 3)

        _wait(resynced, msg="cache resync onto survivor stream")
        # the cache token itself moved onto the survivor's stream, and
        # every cached entry from the dead leader's nonce is gone
        assert cached.cache.stats()["token"]["nonce"] == regs[1].nonce
        assert cached.resolve("svc")["nonce"] == regs[1].nonce


# ---------------------------------------------------------------------------
# ReplicatedTable: version stamps, deltas, tombstone horizon (pure)
# ---------------------------------------------------------------------------
def test_replicated_table_delta_roundtrip():
    lock = threading.RLock()
    leader = ReplicatedTable("t", lock, tombstone_ttl=60.0)
    mirror = ReplicatedTable("t", threading.RLock(), tombstone_ttl=60.0)
    now = time.monotonic()
    leader.put("a", {"x": 1})
    leader.put("b", {"x": 2})
    mirror.install(leader.snapshot(now), now)
    assert (mirror.epoch, len(mirror)) == (2, 2)

    base = mirror.epoch
    leader.put("c", {"x": 3})
    leader.delete("a")
    # soft update: no epoch bump, rides the soft channel only
    assert leader.update("b", x=20)
    assert leader.epoch == 4
    d = leader.delta_since(base, now)
    assert [e["k"] for e in d["put"]] == ["c"]
    assert d["del"] == [["a", 4]]
    assert mirror.apply_delta(d, now)
    mirror.apply_soft(leader.take_soft(now), now)
    assert mirror.epoch == 4
    assert mirror.get("a") is None
    assert mirror.get("b")["x"] == 20 and mirror.get("c")["x"] == 3
    # idle: nothing to ship (heartbeats with unchanged values are free)
    assert leader.update("b", x=20) and leader.take_soft(now) == []
    d2 = leader.delta_since(mirror.epoch, now)
    assert d2["put"] == [] and d2["del"] == []


def test_replicated_table_horizon_forces_snapshot():
    t = ReplicatedTable("t", threading.RLock(), tombstone_ttl=0.05)
    t.put("a", {"x": 1})
    t.put("b", {"x": 2})
    base = t.epoch
    t.delete("a")
    now = time.monotonic()
    assert t.delta_since(base, now)["del"] == [["a", 3]]
    # tombstone GC'd once its TTL passes: the horizon moves and the
    # behind-horizon delta must force a snapshot
    poll_until(lambda: t.delta_since(base, time.monotonic()) is None,
               timeout=2.0, interval=0.01, msg="tombstone horizon move")
    now = time.monotonic()
    assert t.delta_since(t.epoch, now) is not None   # at-horizon is fine
    # a gapped delta (base past the mirror's epoch) is refused
    m = ReplicatedTable("t", threading.RLock())
    assert not m.apply_delta({"base": 7, "epoch": 9, "put": [], "del": []},
                             now)


# ---------------------------------------------------------------------------
# membership folded into the quorum
# ---------------------------------------------------------------------------
def _mk_member_cluster(n=3, heartbeat_timeout=0.6):
    engines = [Engine("tcp://127.0.0.1:0") for _ in range(n)]
    peers = [e.uri for e in engines]
    regs = [RegistryService(e, peers=peers, lease_ttl=LEASE,
                            gossip_interval=GOSSIP, sweep_interval=0.1,
                            instance_ttl=5.0, serve_membership=True,
                            heartbeat_timeout=heartbeat_timeout)
            for e in engines]
    return engines, peers, regs


@pytest.fixture
def member_cluster():
    engines, peers, regs = _mk_member_cluster()
    _wait(lambda: regs[0].is_leader, msg="rank-0 leadership")
    yield engines, peers, regs
    for r in regs:
        r.close()
    for e in engines:
        try:
            e.shutdown()
        except Exception:
            pass


def test_membership_served_by_quorum(member_cluster):
    """mem.* wire API against the quorum: joins land on the leader's
    replicated member table (proxied from a follower endpoint), views
    are served by followers from their mirror, and the member table
    shares the instance table's gossip stream (same nonce)."""
    engines, peers, regs = member_cluster
    with Engine("tcp://127.0.0.1:0") as w:
        # write via a FOLLOWER endpoint: proxied one hop to the leader
        view = w.call(peers[2], "mem.join",
                      {"member_id": "m1", "uri": w.uri,
                       "meta": {"role": "trainer"}}, timeout=5.0)
        assert view["members"] == ["m1"]
        assert regs[0].membership.table.get("m1")["meta"] == \
            {"role": "trainer"}
        # follower-served reads: the mirror carries the member
        for i in (1, 2):
            _wait(lambda i=i: (engines and regs[i].membership.table
                               .get("m1") is not None),
                  msg=f"member replication to follower {i}")
            v = w.call(peers[i], "mem.view", {}, timeout=5.0)
            assert v["members"] == ["m1"]
            assert v["nonce"] == regs[0].nonce
        # heartbeat via a follower refreshes the leader's stamp (retry
        # until the clock has visibly advanced past the join stamp)
        before = regs[0].membership.table.get("m1")["last"]

        def _refreshed():
            w.call(peers[1], "mem.heartbeat",
                   {"member_id": "m1", "uri": w.uri}, timeout=5.0)
            return regs[0].membership.table.get("m1")["last"] > before
        _wait(_refreshed, interval=0.02,
              msg="follower-proxied heartbeat refreshing leader stamp")


@pytest.mark.slow
def test_leaseholder_kill_members_survive_reaps_fire_once(member_cluster):
    """The ISSUE acceptance scenario: kill the leaseholder under active
    member heartbeats.  Heartbeating members are never mass-expired on
    takeover; a member that stopped heartbeating before the kill is
    expired by the NEW leader, its on_expire reap fires exactly once
    (and only there), and its bound instance is reaped from the
    replicated instance table."""
    engines, peers, regs = member_cluster
    fires = []
    for i, r in enumerate(regs):
        r.membership.on_expire(
            lambda dead, i=i: fires.append((i, sorted(dead))))
    with Engine("tcp://127.0.0.1:0") as w:
        cli = RegistryClient(w, peers)
        live = MembershipClient(w, peers, "live", 0.1)
        live.join({"zone": "a"})
        # "doomed" joins but never heartbeats; an instance is bound to it
        w.call(peers[0], "mem.join", {"member_id": "doomed",
                                      "uri": "tcp://x"}, timeout=5.0)
        iid = cli.register("svc", "tcp://127.0.0.1:7777",
                           member_id="doomed")
        _wait(lambda: regs[1].membership.table.get("doomed") is not None,
              msg="member replication before the kill")

        regs[0].close()                   # kill the leaseholder abruptly
        engines[0].shutdown()
        _wait(lambda: regs[1].is_leader, msg="rank-1 takeover")
        # doomed expires on the NEW leader (takeover refreshed liveness,
        # so expiry lands one heartbeat_timeout after takeover, not 0)
        _wait(lambda: any("doomed" in d for _, d in fires),
              msg="expiry reap from the new leader")
        # ...and its bound instance is reaped from the instance table
        _wait(lambda: cli.resolve("svc")["instances"] == [],
              msg="member-bound instance reap after failover")
        # observation window (not a wait): absence of duplicate fires
        # can only be asserted over elapsed sweep periods
        time.sleep(3 * 0.6)
        doomed_fires = [(i, d) for i, d in fires if "doomed" in d]
        assert len(doomed_fires) == 1, f"reap fired {doomed_fires}"
        assert doomed_fires[0][0] == 1, "reap must fire on the new leader"
        # the heartbeating member was never expired anywhere
        assert not any("live" in d for _, d in fires), \
            f"heartbeating member mass-expired: {fires}"
        assert "live" in live.current_view()["members"]
        live.leave()


def test_membership_nonce_resync_in_driver_path(member_cluster):
    """The training-driver path across a control-plane failover: the
    MembershipClient's on_change fires on the nonce change (epochs are
    only comparable within one stream), the view still carries every
    live member, and heartbeats keep landing via the survivors."""
    engines, peers, regs = member_cluster
    changes = []
    with Engine("tcp://127.0.0.1:0") as w:
        c = MembershipClient(w, peers, "trainer-0", 0.1,
                             on_change=lambda v: changes.append(dict(v)))
        first = c.join({"role": "trainer"})
        nonce0 = first["nonce"]
        assert nonce0 == regs[0].nonce
        regs[0].close()
        engines[0].shutdown()
        _wait(lambda: regs[1].is_leader, msg="rank-1 takeover")
        _wait(lambda: any(v.get("nonce") not in (None, nonce0)
                          for v in changes),
              msg="driver observes the nonce change")
        resynced = next(v for v in changes
                        if v.get("nonce") not in (None, nonce0))
        assert "trainer-0" in resynced["members"], \
            "member lost across failover"
        c.leave()


def test_behind_horizon_follower_resynced_by_snapshot():
    """A follower whose acked epoch predates the leader's tombstone
    horizon cannot be caught up by delta (deletions were GC'd): the
    leader must fall back to a full snapshot, after which the follower
    converges again."""
    # private cluster: a huge instance TTL so the reporter-less test
    # instance can never be expired while the assertions run
    engines, peers, regs = _mk_cluster(instance_ttl=3600.0)
    try:
        _wait(lambda: regs[0].is_leader, msg="rank-0 leadership")
        with Engine("tcp://127.0.0.1:0") as cli:
            lead = RegistryClient(cli, peers[0])
            iid = lead.register("svc", "tcp://127.0.0.1:8888")
            _wait(lambda: regs[1].epoch == regs[0].epoch,
                  msg="initial convergence")
            # churn through registrations whose tombstones are GC'd at
            # once (their deletion history is gone immediately)
            regs[0].table.tombstone_ttl = 0.0
            for i in range(3):
                tmp = lead.register("tmp", f"tcp://127.0.0.1:{9100 + i}")
                lead.deregister("tmp", tmp)
            with regs[0].core._lock:
                snaps_before = regs[0].core.stats["snapshot_pushes"]

            # force a pre-horizon ack for peer 1 until a leader tick
            # consumes it (the follower's own heartbeats race us and may
            # re-ack the true epoch in between): that tick must take the
            # snapshot path, not the delta path
            def forced_snapshot_pushed():
                with regs[0].core._lock:
                    if (regs[0].core.stats["snapshot_pushes"]
                            > snaps_before):
                        return True
                    regs[0].core._acks[peers[1]] = {
                        "nonce": regs[0].nonce,
                        "epochs": {"instances": 0}}
                    return False

            _wait(forced_snapshot_pushed, msg="snapshot fallback push")
            _wait(lambda: (regs[1].epoch, regs[1].nonce)
                  == (regs[0].epoch, regs[0].nonce),
                  msg="follower reconvergence after snapshot")
            view = RegistryClient(cli, peers[1]).resolve("svc")
            assert [i_["iid"] for i_ in view["instances"]] == [iid]
            assert RegistryClient(cli, peers[1]).resolve("tmp")[
                "instances"] == []
    finally:
        for r in regs:
            r.close()
        for e in engines:
            try:
                e.shutdown()
            except Exception:
                pass


@pytest.mark.slow
def test_idle_quorum_gossips_heartbeats_not_state(cluster):
    """Delta gossip's reason to exist: an idle quorum (registered
    instances, no churn) must exchange bare heartbeats — zero delta or
    snapshot pushes — instead of shipping the table every round."""
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        lead = RegistryClient(cli, peers[0])
        for i in range(10):
            lead.register("svc", f"tcp://127.0.0.1:{9300 + i}")
        _wait(lambda: all(r.epoch == regs[0].epoch for r in regs),
              msg="convergence")
        # measure over gossip ROUNDS, not wall time: wait out 3 rounds
        # to drain in-flight pushes, then observe a 10-round window
        drained = regs[0].core.stats["rounds"] + 3
        _wait(lambda: regs[0].core.stats["rounds"] >= drained,
              msg="in-flight gossip drained")
        s0 = dict(regs[0].core.stats)
        _wait(lambda: regs[0].core.stats["rounds"] >= s0["rounds"] + 10,
              msg="10-round idle window")
        s1 = dict(regs[0].core.stats)
        assert s1["rounds"] > s0["rounds"]
        assert s1["delta_pushes"] == s0["delta_pushes"]
        assert s1["snapshot_pushes"] == s0["snapshot_pushes"]
        assert s1["heartbeat_pushes"] > s0["heartbeat_pushes"]


def test_fab_status_reports_tables_gossip_and_acks(cluster):
    """fab.status (docs/OPERATIONS.md): per-table entry counts/epochs,
    delta-vs-snapshot gossip counters, and per-peer acked replication
    state."""
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        lead = RegistryClient(cli, peers[0])
        lead.register("svc", "tcp://127.0.0.1:9500")
        _wait(lambda: regs[1].epoch == regs[0].epoch, msg="convergence")
        st = lead.status()
        assert st["role"] == "leader"
        assert st["tables"]["instances"]["entries"] == 1
        assert st["tables"]["instances"]["epoch"] == regs[0].epoch
        g = st["gossip"]
        assert g["rounds"] > 0
        assert g["delta_pushes"] + g["snapshot_pushes"] \
            + g["pull_snapshots"] + g["pull_deltas"] > 0
        _wait(lambda: any(
            p.get("acked", {}).get("instances") == regs[0].epoch
            for p in lead.status()["peers"]),
            msg="peer acks visible in fab.status")
        acked = [p for p in lead.status()["peers"] if "acked" in p]
        assert acked and all("acked_nonce" in p for p in acked)
        # follower status: mirrored tables, role, and the same stream
        fst = RegistryClient(cli, peers[1]).status()
        assert fst["role"] == "follower"
        assert fst["nonce"] == st["nonce"]


def test_register_member_rebind_is_versioned():
    """A same-uris re-register that changes the member binding is a
    membership change: it must bump the epoch (ride the versioned,
    retransmitted stream), while a same-everything re-register (the
    report-loop recovery path) must not."""
    with Engine("tcp://127.0.0.1:0") as e, \
            Engine("tcp://127.0.0.1:0") as w:
        svc = RegistryService(e, sweep_interval=0.1, instance_ttl=5.0)
        cli = RegistryClient(w, e.uri)
        iid = cli.register("svc", w.uri, member_id="a")
        e1 = cli.epoch()
        cli.register("svc", w.uri, iid=iid, member_id="a")   # recovery
        assert cli.epoch() == e1, "same-everything re-register bumped"
        cli.register("svc", w.uri, iid=iid, member_id="b")   # rebind
        assert cli.epoch() == e1 + 1, "member rebind must be versioned"
        assert svc.table.get(f"svc\x1f{iid}")["member_id"] == "b"
        svc.close()


@pytest.mark.slow
def test_full_gossip_refreshes_mirrored_soft_state():
    """--full-gossip compatibility: converged followers must keep
    adopting the leader's equal-epoch periodic snapshots — that is how
    mirrored loads stay fresh between membership changes."""
    engines = [Engine("tcp://127.0.0.1:0") for _ in range(2)]
    peers = [e.uri for e in engines]
    regs = [RegistryService(e, peers=peers, lease_ttl=LEASE,
                            gossip_interval=GOSSIP, sweep_interval=0.1,
                            instance_ttl=3600.0, delta_gossip=False)
            for e in engines]
    try:
        _wait(lambda: regs[0].is_leader, msg="leadership")
        with Engine("tcp://127.0.0.1:0") as cli:
            lead = RegistryClient(cli, peers[0])
            iid = lead.register("svc", "tcp://127.0.0.1:9700")
            _wait(lambda: regs[1].epoch == regs[0].epoch,
                  msg="convergence")
            lead.report("svc", iid, load=7.5)     # soft: no epoch bump
            fol = RegistryClient(cli, peers[1])
            _wait(lambda: [i["load"] for i in
                           fol.resolve("svc")["instances"]] == [7.5],
                  msg="mirrored load refresh under full-state gossip")
    finally:
        for r in regs:
            r.close()
        for e in engines:
            try:
                e.shutdown()
            except Exception:
                pass
