"""Replicated registry control plane (DESIGN.md §8): deterministic
leader lease, gossip replication to followers, follower write proxying,
client endpoint failover, leaseholder kill mid-run (pools converge to a
survivor within one refresh interval with zero client-visible resolution
errors), and restart resync (a restarted replica adopts the acting
leader's snapshot before it may reclaim the lease)."""
import threading
import time

import pytest

from repro.core.executor import Engine
from repro.core.types import MercuryError, Ret
from repro.fabric import (PeerTracker, RegistryClient, RegistryService,
                          RetryPolicy, ServiceInstance, ServicePool,
                          parse_registry_uris)
from repro.services import MembershipServer

LEASE = 0.5
GOSSIP = 0.12


def _wait(pred, timeout=8.0, interval=0.03, msg="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(interval)
    raise AssertionError(f"timed out waiting for {msg}")


def _mk_cluster(n=3, instance_ttl=5.0):
    engines = [Engine("tcp://127.0.0.1:0") for _ in range(n)]
    peers = [e.uri for e in engines]
    regs = [RegistryService(e, peers=peers, lease_ttl=LEASE,
                            gossip_interval=GOSSIP, sweep_interval=0.1,
                            instance_ttl=instance_ttl)
            for e in engines]
    return engines, peers, regs


@pytest.fixture
def cluster():
    engines, peers, regs = _mk_cluster()
    # cold start: rank 0 self-elects after its boot grace (one lease)
    _wait(lambda: regs[0].is_leader, msg="rank-0 leadership")
    yield engines, peers, regs
    for r in regs:
        r.close()
    for e in engines:
        try:
            e.shutdown()
        except Exception:
            pass


def _echo_engine(name):
    e = Engine("tcp://127.0.0.1:0")
    e.register("echo", lambda x, _n=name: (_n, x))
    return e


# ---------------------------------------------------------------------------
# lease bookkeeping (pure)
# ---------------------------------------------------------------------------
def test_peer_tracker_lease_and_grace():
    t = [0.0]
    tr = PeerTracker(["a", "b", "c"], "b", lease_ttl=1.0,
                     clock=lambda: t[0])
    # boot grace: a (optimistically alive) leads; self is deferred
    assert tr.in_grace() and tr.leader_uri() == "a"
    t[0] = 1.5                      # grace over, a's lease expired
    assert not tr.in_grace()
    assert tr.leader_uri() == "b"   # we are the best live peer
    tr.note("a")                    # a came back
    assert tr.leader_uri() == "a"
    t[0] = 3.0                      # a silent past the lease again
    assert tr.leader_uri() == "b"
    stats = {p["uri"]: p for p in tr.peer_stats()}
    assert stats["b"]["self"] and not stats["a"]["alive"]


def test_peer_tracker_grace_with_all_peers_dead():
    t = [0.0]
    tr = PeerTracker(["a", "b"], "a", lease_ttl=1.0, clock=lambda: t[0])
    t[0] = 0.5
    # in grace, nobody heard, self deferred: leadership unknowable
    assert tr.leader_uri() == "b"   # b still within its optimistic lease
    tr.mark_synced()                # adopted a snapshot: grace over early
    assert tr.leader_uri() == "a"


def test_parse_registry_uris_rejects_empty():
    with pytest.raises(ValueError):
        parse_registry_uris("  , ,")
    assert parse_registry_uris("a;b,c") == ["a;b", "c"]


# ---------------------------------------------------------------------------
# gossip replication
# ---------------------------------------------------------------------------
def test_cluster_elects_lowest_rank_and_agrees(cluster):
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        for uri in peers:
            st = cli.call(uri, "fab.status", {}, timeout=5.0)
            assert st["leader"] == peers[0], st
        assert regs[0].is_leader
        assert not regs[1].is_leader and not regs[2].is_leader
        roles = [cli.call(u, "fab.status", {}, timeout=5.0)["role"]
                 for u in peers]
        assert roles == ["leader", "follower", "follower"]


def test_register_replicates_to_follower_reads(cluster):
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        lead = RegistryClient(cli, peers[0])
        iid = lead.register("svc", "tcp://127.0.0.1:1111", capacity=4)
        # followers serve the mirrored view (reads never proxy)
        for uri in peers[1:]:
            follower = RegistryClient(cli, uri)
            _wait(lambda f=follower: [i["iid"] for i in
                                      f.resolve("svc")["instances"]] == [iid],
                  msg="gossip replication to follower")
            e, n = follower.epoch_info()
            le, ln = lead.epoch_info()
            assert (e, n) == (le, ln)   # same stream: nonce + epoch match


def test_follower_proxies_writes_to_leaseholder(cluster):
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        fol = RegistryClient(cli, peers[2])      # follower endpoint only
        iid = fol.register("svc", "tcp://127.0.0.1:2222", capacity=1)
        # the write landed on the leader's authoritative table
        assert any(i["iid"] == iid for i in
                   RegistryClient(cli, peers[0]).resolve("svc")["instances"])
        # load reports proxy too, and application errors pass through:
        fol.report("svc", iid, load=3.0)
        with pytest.raises(MercuryError) as ei:
            fol.report("svc", "nonexistent-iid", load=0.0)
        assert ei.value.ret == Ret.NOENTRY
        assert fol.deregister("svc", iid)


def test_registry_client_rotates_past_dead_endpoint(cluster):
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        dead = "tcp://127.0.0.1:1"               # nothing listens there
        c = RegistryClient(cli, [dead] + peers, timeout=5.0)
        iid = c.register("svc", "tcp://127.0.0.1:3333")
        assert c.resolve("svc")["instances"][0]["iid"] == iid
        # sticky: after one failover the live endpoint is preferred
        assert c.registry != dead


def test_registration_during_cold_boot_succeeds():
    """A write racing the quorum's cold start (every replica still in
    boot grace → AGAIN everywhere) must succeed once the lease settles:
    RegistryClient re-probes within its timeout budget instead of
    surfacing the transient — real launchers can't spin on is_leader."""
    engines, peers, regs = _mk_cluster()
    try:
        with Engine("tcp://127.0.0.1:0") as cli:
            c = RegistryClient(cli, peers, timeout=8.0)
            iid = c.register("svc", "tcp://127.0.0.1:6666")   # no wait
            assert [i["iid"] for i in
                    c.resolve("svc")["instances"]] == [iid]
    finally:
        for r in regs:
            r.close()
        for e in engines:
            e.shutdown()


def test_follower_hosted_membership_reaps_via_leader(cluster):
    """A MembershipServer co-hosted on a FOLLOWER node: its expiries are
    resolved against the follower's mirror and forwarded to the
    leaseholder as deregisters — the member-bound instance dies with its
    member even though it keeps reporting."""
    engines, peers, regs = cluster
    ms = MembershipServer(engines[2], heartbeat_timeout=0.4,
                          sweep_interval=0.1)
    ms.on_expire(regs[2]._members_expired)
    with Engine("tcp://127.0.0.1:0") as w:
        cli = RegistryClient(w, peers)
        w.call(peers[2], "mem.join", {"member_id": "w1", "uri": w.uri})
        iid = cli.register("svc", w.uri, member_id="w1")
        # member w1 never heartbeats; the instance DOES keep reporting,
        # so only the (forwarded) member-expiry path can remove it
        gone = False
        deadline = time.time() + 8
        while time.time() < deadline and not gone:
            try:
                cli.report("svc", iid, load=0.0)
            except MercuryError as e:
                gone = e.ret == Ret.NOENTRY
            time.sleep(0.05)
        assert gone, "member-bound instance survived its member"
        assert cli.resolve("svc")["instances"] == []
    ms.close()


# ---------------------------------------------------------------------------
# leaseholder kill mid-run (the ISSUE acceptance scenario)
# ---------------------------------------------------------------------------
def test_leader_kill_pools_converge_with_zero_resolution_errors(cluster):
    """Kill the leaseholder under routed load: every pool call keeps
    succeeding (client endpoint failover + follower read-serving), the
    next-ranked replica takes the lease, and the pool's view resyncs
    onto the survivor's fresh stream within one refresh interval."""
    engines, peers, regs = cluster
    srv_a, srv_b = _echo_engine("a"), _echo_engine("b")
    with srv_a, srv_b, Engine("tcp://127.0.0.1:0") as cli:
        insts = [ServiceInstance(s, peers, "svc", capacity=4,
                                 report_interval=0.1)
                 for s in (srv_a, srv_b)]
        refresh = 0.2
        pool = ServicePool(cli, peers, "svc", refresh_interval=refresh,
                           policy=RetryPolicy(attempts=3, rpc_timeout=2.0,
                                              backoff_base=0.01))
        assert len(pool.replicas()) == 2
        errors, stop = [], threading.Event()

        def drive():
            i = 0
            while not stop.is_set():
                try:
                    pool.call("echo", i, timeout=5.0)
                except Exception as e:   # noqa: BLE001 — surfaced below
                    errors.append(repr(e))
                i += 1

        threads = [threading.Thread(target=drive) for _ in range(4)]
        for t in threads:
            t.start()
        time.sleep(0.5)

        regs[0].close()                  # kill the leaseholder abruptly
        engines[0].shutdown()
        t_kill = time.monotonic()

        # pools fail over to a surviving replica within ~one refresh
        # interval: the control plane answers again immediately
        _wait(lambda: pool.registry.epoch_info() is not None,
              timeout=refresh + 2.0, msg="client failover")
        # the lease moves to the next-ranked survivor...
        _wait(lambda: regs[1].is_leader, msg="rank-1 takeover")
        takeover_s = time.monotonic() - t_kill
        # ...and the pool resyncs onto the new stream (nonce change)
        new_nonce = regs[1].nonce
        _wait(lambda: (pool.refresh(force=True) or
                       pool._view_nonce == new_nonce),
              msg="pool resync onto survivor stream")
        time.sleep(0.3)                  # keep routing on the new stream
        stop.set()
        for t in threads:
            t.join(timeout=10)
        assert not errors, f"client-visible failures: {errors[:3]}"
        assert takeover_s < LEASE + 2.0
        # registrations survived the failover (mirror promoted, not lost)
        assert len(pool.replicas()) == 2
        for inst in insts:
            inst.close()


# ---------------------------------------------------------------------------
# restart resync
# ---------------------------------------------------------------------------
def test_restarted_follower_resyncs_from_leader(cluster):
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        RegistryClient(cli, peers[0]).register("svc", "tcp://127.0.0.1:4444")
        port = int(peers[2].rsplit(":", 1)[1])
        regs[2].close()
        engines[2].shutdown()
        # restart rank 2 on the same configured uri: empty table, boot
        # grace, adopts the acting leader's snapshot
        engines[2] = Engine(f"tcp://127.0.0.1:{port}")
        regs[2] = RegistryService(engines[2], peers=peers, lease_ttl=LEASE,
                                  gossip_interval=GOSSIP,
                                  sweep_interval=0.1, instance_ttl=5.0)
        fol = RegistryClient(cli, peers[2])
        _wait(lambda: fol.resolve("svc")["instances"],
              msg="restarted follower resync")
        assert fol.epoch_info() == RegistryClient(cli,
                                                  peers[0]).epoch_info()
        assert not regs[2].is_leader


def test_restarted_leader_resyncs_before_reclaiming_lease(cluster):
    """Kill rank 0; rank 1 takes over and keeps accepting writes.  A
    restarted rank 0 must adopt rank 1's snapshot BEFORE reclaiming the
    lease — registrations written during its absence survive."""
    engines, peers, regs = cluster
    with Engine("tcp://127.0.0.1:0") as cli:
        port = int(peers[0].rsplit(":", 1)[1])
        regs[0].close()
        engines[0].shutdown()
        _wait(lambda: regs[1].is_leader, msg="rank-1 takeover")
        # a write accepted by the acting leader while rank 0 is down
        iid = RegistryClient(cli, peers[1:]).register(
            "svc", "tcp://127.0.0.1:5555", capacity=2)

        engines[0] = Engine(f"tcp://127.0.0.1:{port}")
        regs[0] = RegistryService(engines[0], peers=peers, lease_ttl=LEASE,
                                  gossip_interval=GOSSIP,
                                  sweep_interval=0.1, instance_ttl=5.0)
        # rank 0 resyncs, then reclaims the lease; rank 1 steps down
        _wait(lambda: regs[0].is_leader, msg="rank-0 reclaim")
        _wait(lambda: not regs[1].is_leader, msg="rank-1 step-down")
        view = RegistryClient(cli, peers[0]).resolve("svc")
        assert [i["iid"] for i in view["instances"]] == [iid], \
            "write during the leader's absence was lost"
        # all replicas converge onto the reclaimed stream
        for uri in peers:
            _wait(lambda u=uri: (RegistryClient(cli, u).epoch_info()
                                 == (regs[0].epoch, regs[0].nonce)),
                  msg="stream convergence after reclaim")
