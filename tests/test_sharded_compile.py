"""Sharded-compile tests: the dry-run machinery on a small real device
mesh (8 host devices in a subprocess), covering train/prefill/decode
lowering for a dense and a MoE arch, plus the mesh constructors."""
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as PS
    from repro import configs
    from repro.configs.base import ParallelConfig
    from repro.models import Model, unzip
    from repro.models.moe import padded_experts
    from repro.distrib import tree_shardings
    from repro.train import optim
    from repro.train.step import init_state, make_train_step

    mesh = jax.make_mesh((2, 4), ("data", "model"))

    import dataclasses
    for arch in ["qwen1.5-0.5b", "granite-moe-3b-a800m"]:
        cfg = configs.reduced(arch).replace(compute_dtype="float32")
        if cfg.moe.num_experts:
            # capacity is per token-shard under SPMD; compare dropless so
            # sharded == local exactly
            cfg = cfg.replace(moe=dataclasses.replace(
                cfg.moe, capacity_factor=16.0))
        e_pad = padded_experts(cfg, 4) if cfg.moe.num_experts else None
        model = Model(cfg, e_pad=e_pad)
        ocfg = optim.OptConfig(lr=1e-3, warmup=0, decay_steps=10)
        par = ParallelConfig(remat="block")

        state, axes = init_state(model, ocfg, jax.random.PRNGKey(0))
        sh = tree_shardings(
            jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
            axes, mesh)
        state = jax.device_put(state, sh)
        batch = {
            "tokens": jnp.zeros((8, 32), jnp.int32),
            "targets": jnp.zeros((8, 32), jnp.int32),
        }
        bsh = {k: NamedSharding(mesh, PS("data")) for k in batch}
        batch = jax.device_put(batch, bsh)

        with mesh:
            step = jax.jit(make_train_step(model, ocfg, par, mesh),
                           in_shardings=(sh, bsh), out_shardings=(sh, None))
            state2, metrics = step(state, batch)
            assert np.isfinite(float(metrics["loss"])), arch

            # sharded-vs-single-device parity of the loss
            from repro.train.step import make_moe_spmd
            spmd = make_moe_spmd(cfg, par, mesh)
            loss_sh, _ = jax.jit(
                lambda p, b: model.loss_fn(p, b, spmd=spmd, impl="xla",
                                           remat="none"))(state["params"],
                                                          batch)
        loss_local, _ = model.loss_fn(
            jax.tree_util.tree_map(np.asarray, state["params"]),
            jax.tree_util.tree_map(np.asarray, batch),
            impl="xla", remat="none")
        np.testing.assert_allclose(float(loss_sh), float(loss_local),
                                   rtol=2e-4)
        print(f"TRAIN_OK {arch} {float(metrics['loss']):.4f}")

    # decode lowering with a sequence-sharded cache
    cfg = configs.reduced("qwen1.5-0.5b")
    model = Model(cfg)
    params, paxes = unzip(model.init(jax.random.PRNGKey(0)))
    psh = tree_shardings(
        jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
        paxes, mesh)
    cache_p = jax.eval_shape(lambda: model.cache_specs(8, 64, jnp.bfloat16))
    cache_sds, caxes = unzip(cache_p)
    csh = tree_shardings(cache_sds, caxes, mesh)
    tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    with mesh:
        lowered = jax.jit(
            lambda p, c, t, s: model.decode_step(p, c, t, s, impl="xla"),
            in_shardings=(psh, csh, NamedSharding(mesh, PS("data")), None),
            out_shardings=(None, csh)).lower(
                jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
                cache_sds, tok, pos)
        compiled = lowered.compile()
        assert compiled.memory_analysis() is not None
    print("DECODE_LOWER_OK")

    from repro.launch.mesh import make_local_mesh
    m2 = make_local_mesh(model_axis=2)
    assert m2.shape == {"data": 4, "model": 2}
    print("MESH_OK")
""")


def test_sharded_train_and_decode():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=900, cwd=".")
    out = r.stdout + r.stderr
    assert "TRAIN_OK qwen1.5-0.5b" in r.stdout, out
    assert "TRAIN_OK granite-moe-3b-a800m" in r.stdout, out
    assert "DECODE_LOWER_OK" in r.stdout, out
    assert "MESH_OK" in r.stdout, out


def test_production_mesh_shapes():
    # AbstractMesh mirrors make_production_mesh without touching devices
    from repro.distrib.sharding import abstract_mesh
    single = abstract_mesh((16, 16), ("data", "model"))
    multi = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert single.size == 256 and multi.size == 512
    assert tuple(multi.axis_names) == ("pod", "data", "model")
