"""RPC core semantics over both NA plugins: round trips, error paths,
origin/target symmetry, concurrency, fire-and-forget."""
import threading
import time

import numpy as np
import pytest

from repro.core.executor import Engine, RemoteError
from repro.core.types import Ret


@pytest.fixture(params=["self", "tcp", "sm"])
def engines(request):
    if request.param == "self":
        with Engine(None) as e:
            yield e, e
    elif request.param == "sm":
        import uuid
        tag = uuid.uuid4().hex[:8]
        with Engine(f"sm://rpc-a-{tag}") as a, \
                Engine(f"sm://rpc-b-{tag}") as b:
            yield a, b
    else:
        with Engine("tcp://127.0.0.1:0") as a, \
                Engine("tcp://127.0.0.1:0") as b:
            yield a, b


def test_echo(engines):
    srv, cli = engines
    srv.register("echo", lambda x: x)
    v = {"a": [1, 2.5, "x"], "arr": np.arange(4)}
    out = cli.call(srv.uri, "echo", v)
    assert out["a"] == v["a"]
    np.testing.assert_array_equal(out["arr"], v["arr"])


def test_unregistered_rpc_is_noentry(engines):
    srv, cli = engines
    with pytest.raises(RemoteError) as ei:
        cli.call(srv.uri, "nope", 1, timeout=5.0)
    assert ei.value.ret == Ret.NOENTRY


def test_handler_fault_propagates(engines):
    srv, cli = engines

    def bad(_):
        raise ValueError("boom")

    srv.register("bad", bad)
    with pytest.raises(RemoteError) as ei:
        cli.call(srv.uri, "bad", None, timeout=5.0)
    assert ei.value.ret == Ret.FAULT
    assert "boom" in str(ei.value)


def test_timeout(engines):
    srv, cli = engines
    srv.register("slow", lambda x: time.sleep(3.0) or x)
    t0 = time.time()
    with pytest.raises(RemoteError) as ei:
        cli.call(srv.uri, "slow", None, timeout=0.3)
    assert ei.value.ret == Ret.TIMEOUT
    assert time.time() - t0 < 2.0


def test_notify_fire_and_forget(engines):
    srv, cli = engines
    got = threading.Event()
    srv.register("note", lambda x: got.set(), no_response=True)
    cli.notify(srv.uri, "note", {"x": 1})
    assert got.wait(5.0)


def test_concurrent_calls(engines):
    srv, cli = engines
    srv.register("sq", lambda x: x * x)
    futs = [cli.call_async(srv.uri, "sq", i) for i in range(32)]
    assert [f.result(timeout=10) for f in futs] == [i * i for i in range(32)]


def test_origin_target_symmetry():
    """Paper C4: both endpoints serve and call simultaneously."""
    with Engine("tcp://127.0.0.1:0") as a, Engine("tcp://127.0.0.1:0") as b:
        a.register("ping_a", lambda x: ("a", x))
        b.register("ping_b", lambda x: ("b", x))
        assert a.call(b.uri, "ping_b", 1) == ("b", 1)
        assert b.call(a.uri, "ping_a", 2) == ("a", 2)

        # and a handler on b that itself calls back into a (service chain)
        def chained(x):
            return b.call(a.uri, "ping_a", x)[1] + 1

        b.register("chain", chained)
        assert a.call(b.uri, "chain", 10) == 11


def test_large_eager_payload(engines):
    srv, cli = engines
    srv.register("blob", lambda x: np.asarray(x).sum())
    a = np.ones(200_000, dtype=np.float64)      # 1.6 MB inline
    assert cli.call(srv.uri, "blob", a, timeout=30) == 200_000.0


# ---------------------------------------------------------------------------
# Self-tier fast path (DESIGN.md §9)
# ---------------------------------------------------------------------------
def test_local_dispatch_value_isolation():
    """Default self-tier calls keep wire semantics: handler mutations of
    the request never alias the caller's object, and the response is
    likewise isolated."""
    with Engine(None) as e:
        state = {}

        def grab(v):
            state["got"] = v
            v["mutated"] = True
            return {"r": [1, 2]}

        e.register("grab", grab)
        arg = {"x": 1}
        out = e.call(e.uri, "grab", arg)
        assert "mutated" not in arg            # request deep-copied
        assert state["got"] is not arg
        out["r"].append(3)
        assert e.call(e.uri, "grab", {"x": 2})["r"] == [1, 2]


def test_local_dispatch_zero_copy_opt_out():
    """checksum=False + copy_local=False: the handler receives the very
    object the caller passed, and the caller receives the very object
    the handler returned — no serialization, no copy."""
    with Engine(None, checksum=False, copy_local=False) as e:
        seen = {}
        e.register("id", lambda v: seen.setdefault("v", v))
        arg = {"big": list(range(100))}
        out = e.call(e.uri, "id", arg)
        assert seen["v"] is arg
        assert out is arg


def test_local_cancel_after_delivery_settles_once():
    """Handle.cancel() racing (or trailing) a locally-delivered response
    must settle the future exactly once, with the winner's verdict."""
    with Engine(None) as e:
        e.register("ok", lambda v: v)
        fut = e.call_async(e.uri, "ok", 7, timeout=5.0)
        assert fut.result(timeout=5.0) == 7
        fut.cancel_call()                      # after delivery: no-op
        assert fut.result(timeout=1.0) == 7    # verdict unchanged

        # and a cancel that genuinely wins: handler parked on an event
        hold = threading.Event()
        e.register("park", lambda v: hold.wait(5.0) or v)
        fut2 = e.call_async(e.uri, "park", 1, timeout=10.0)
        fut2.cancel_call()
        with pytest.raises(RemoteError) as ei:
            fut2.result(timeout=5.0)
        assert ei.value.ret == Ret.CANCELED
        hold.set()                             # unpark; late respond is a no-op
        time.sleep(0.1)
        with pytest.raises(RemoteError):
            fut2.result(timeout=1.0)           # still CANCELED, settled once


def test_local_cancel_storm_settles_every_future():
    """Many concurrent cancels racing live local responses: every future
    settles (success or CANCELED), none hangs, none settles twice."""
    with Engine(None) as e:
        e.register("tick", lambda v: v + 1)
        errors = []

        def storm(i):
            try:
                fut = e.call_async(e.uri, "tick", i, timeout=5.0)
                if i % 2:
                    fut.cancel_call()
                try:
                    out = fut.result(timeout=5.0)
                    assert out == i + 1
                except RemoteError as err:
                    assert err.ret == Ret.CANCELED
            except Exception as err:            # noqa: BLE001
                errors.append(err)

        threads = [threading.Thread(target=storm, args=(i,))
                   for i in range(64)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=10.0)
        assert not errors


# ---------------------------------------------------------------------------
# Wire-format cross-version compatibility (v5 trace fields, v4 peers)
# ---------------------------------------------------------------------------
def test_v5_request_header_roundtrips_trace_context():
    from repro.core.types import (REQUEST_HEADER_SIZE, Flags, RequestHeader)
    tid = bytes(range(16))
    hdr = RequestHeader(rpc_id=7, cookie=9, flags=Flags.CHECKSUM,
                        payload_len=3, payload_crc=0xAB, budget_ms=1500,
                        trace_id=tid, span_id=0x1234, trace_flags=1)
    raw = hdr.pack()
    assert len(raw) == REQUEST_HEADER_SIZE == 64
    out = RequestHeader.unpack(raw)
    assert out == hdr
    assert out.wire_size == REQUEST_HEADER_SIZE


def test_v4_request_header_decodes_cleanly():
    """A v4 peer's 36-byte header (no trace fields) decodes with zeroed
    trace context and the right body offset (wire_size, not the v5
    constant)."""
    from repro.core.types import (REQUEST_HEADER_SIZE_V4, ZERO_TRACE_ID,
                                  Flags, RequestHeader)
    v4 = RequestHeader(rpc_id=7, cookie=9, flags=Flags.NONE,
                       payload_len=5, budget_ms=250, version=4)
    raw = v4.pack()
    assert len(raw) == REQUEST_HEADER_SIZE_V4 == 36
    out = RequestHeader.unpack(raw + b"hello")
    assert out.version == 4
    assert out.wire_size == REQUEST_HEADER_SIZE_V4
    assert out.trace_id == ZERO_TRACE_ID
    assert out.span_id == 0 and out.trace_flags == 0
    assert (out.rpc_id, out.cookie, out.payload_len, out.budget_ms) \
        == (7, 9, 5, 250)


def test_unknown_request_version_rejected():
    from repro.core.types import MercuryError, RequestHeader
    bad = bytearray(RequestHeader(rpc_id=1, cookie=2).pack())
    bad[4] = 6                                   # future version byte
    with pytest.raises(MercuryError) as ei:
        RequestHeader.unpack(bytes(bad))
    assert ei.value.ret == Ret.PROTOCOL_ERROR


def test_response_header_echoes_requester_version():
    """Responses are byte-identical across v4/v5 (no trace fields): only
    the version byte differs, echoed from the request, so a v4 peer's
    responses neither grow nor get rejected."""
    from repro.core.types import (RESPONSE_HEADER_SIZE, ResponseHeader)
    r5 = ResponseHeader(cookie=3, ret=Ret.SUCCESS, payload_len=2)
    r4 = ResponseHeader(cookie=3, ret=Ret.SUCCESS, payload_len=2, version=4)
    assert len(r5.pack()) == len(r4.pack()) == RESPONSE_HEADER_SIZE == 24
    assert r5.pack()[5:] == r4.pack()[5:]        # only the version differs
    assert ResponseHeader.unpack(r4.pack()).version == 4
    assert ResponseHeader.unpack(r5.pack()).version == 5
    from repro.core.types import MercuryError
    bad = bytearray(r5.pack())
    bad[4] = 3                                   # pre-compat version
    with pytest.raises(MercuryError):
        ResponseHeader.unpack(bytes(bad))


def test_trace_context_not_packed_when_untraced():
    """An untraced request carries all-zero trace fields (the common
    case): no id allocation, no flag bits."""
    from repro.core.types import RequestHeader, ZERO_TRACE_ID
    raw = RequestHeader(rpc_id=1, cookie=2).pack()
    out = RequestHeader.unpack(raw)
    assert out.trace_id == ZERO_TRACE_ID
    assert out.span_id == 0 and out.trace_flags == 0
