"""RPC core semantics over both NA plugins: round trips, error paths,
origin/target symmetry, concurrency, fire-and-forget."""
import threading
import time

import numpy as np
import pytest

from repro.core.executor import Engine, RemoteError
from repro.core.types import Ret


@pytest.fixture(params=["self", "tcp", "sm"])
def engines(request):
    if request.param == "self":
        with Engine(None) as e:
            yield e, e
    elif request.param == "sm":
        import uuid
        tag = uuid.uuid4().hex[:8]
        with Engine(f"sm://rpc-a-{tag}") as a, \
                Engine(f"sm://rpc-b-{tag}") as b:
            yield a, b
    else:
        with Engine("tcp://127.0.0.1:0") as a, \
                Engine("tcp://127.0.0.1:0") as b:
            yield a, b


def test_echo(engines):
    srv, cli = engines
    srv.register("echo", lambda x: x)
    v = {"a": [1, 2.5, "x"], "arr": np.arange(4)}
    out = cli.call(srv.uri, "echo", v)
    assert out["a"] == v["a"]
    np.testing.assert_array_equal(out["arr"], v["arr"])


def test_unregistered_rpc_is_noentry(engines):
    srv, cli = engines
    with pytest.raises(RemoteError) as ei:
        cli.call(srv.uri, "nope", 1, timeout=5.0)
    assert ei.value.ret == Ret.NOENTRY


def test_handler_fault_propagates(engines):
    srv, cli = engines

    def bad(_):
        raise ValueError("boom")

    srv.register("bad", bad)
    with pytest.raises(RemoteError) as ei:
        cli.call(srv.uri, "bad", None, timeout=5.0)
    assert ei.value.ret == Ret.FAULT
    assert "boom" in str(ei.value)


def test_timeout(engines):
    srv, cli = engines
    srv.register("slow", lambda x: time.sleep(3.0) or x)
    t0 = time.time()
    with pytest.raises(RemoteError) as ei:
        cli.call(srv.uri, "slow", None, timeout=0.3)
    assert ei.value.ret == Ret.TIMEOUT
    assert time.time() - t0 < 2.0


def test_notify_fire_and_forget(engines):
    srv, cli = engines
    got = threading.Event()
    srv.register("note", lambda x: got.set(), no_response=True)
    cli.notify(srv.uri, "note", {"x": 1})
    assert got.wait(5.0)


def test_concurrent_calls(engines):
    srv, cli = engines
    srv.register("sq", lambda x: x * x)
    futs = [cli.call_async(srv.uri, "sq", i) for i in range(32)]
    assert [f.result(timeout=10) for f in futs] == [i * i for i in range(32)]


def test_origin_target_symmetry():
    """Paper C4: both endpoints serve and call simultaneously."""
    with Engine("tcp://127.0.0.1:0") as a, Engine("tcp://127.0.0.1:0") as b:
        a.register("ping_a", lambda x: ("a", x))
        b.register("ping_b", lambda x: ("b", x))
        assert a.call(b.uri, "ping_b", 1) == ("b", 1)
        assert b.call(a.uri, "ping_a", 2) == ("a", 2)

        # and a handler on b that itself calls back into a (service chain)
        def chained(x):
            return b.call(a.uri, "ping_a", x)[1] + 1

        b.register("chain", chained)
        assert a.call(b.uri, "chain", 10) == 11


def test_large_eager_payload(engines):
    srv, cli = engines
    srv.register("blob", lambda x: np.asarray(x).sum())
    a = np.ones(200_000, dtype=np.float64)      # 1.6 MB inline
    assert cli.call(srv.uri, "blob", a, timeout=30) == 200_000.0


# ---------------------------------------------------------------------------
# Self-tier fast path (DESIGN.md §9)
# ---------------------------------------------------------------------------
def test_local_dispatch_value_isolation():
    """Default self-tier calls keep wire semantics: handler mutations of
    the request never alias the caller's object, and the response is
    likewise isolated."""
    with Engine(None) as e:
        state = {}

        def grab(v):
            state["got"] = v
            v["mutated"] = True
            return {"r": [1, 2]}

        e.register("grab", grab)
        arg = {"x": 1}
        out = e.call(e.uri, "grab", arg)
        assert "mutated" not in arg            # request deep-copied
        assert state["got"] is not arg
        out["r"].append(3)
        assert e.call(e.uri, "grab", {"x": 2})["r"] == [1, 2]


def test_local_dispatch_zero_copy_opt_out():
    """checksum=False + copy_local=False: the handler receives the very
    object the caller passed, and the caller receives the very object
    the handler returned — no serialization, no copy."""
    with Engine(None, checksum=False, copy_local=False) as e:
        seen = {}
        e.register("id", lambda v: seen.setdefault("v", v))
        arg = {"big": list(range(100))}
        out = e.call(e.uri, "id", arg)
        assert seen["v"] is arg
        assert out is arg


def test_local_cancel_after_delivery_settles_once():
    """Handle.cancel() racing (or trailing) a locally-delivered response
    must settle the future exactly once, with the winner's verdict."""
    with Engine(None) as e:
        e.register("ok", lambda v: v)
        fut = e.call_async(e.uri, "ok", 7, timeout=5.0)
        assert fut.result(timeout=5.0) == 7
        fut.cancel_call()                      # after delivery: no-op
        assert fut.result(timeout=1.0) == 7    # verdict unchanged

        # and a cancel that genuinely wins: handler parked on an event
        hold = threading.Event()
        e.register("park", lambda v: hold.wait(5.0) or v)
        fut2 = e.call_async(e.uri, "park", 1, timeout=10.0)
        fut2.cancel_call()
        with pytest.raises(RemoteError) as ei:
            fut2.result(timeout=5.0)
        assert ei.value.ret == Ret.CANCELED
        hold.set()                             # unpark; late respond is a no-op
        time.sleep(0.1)
        with pytest.raises(RemoteError):
            fut2.result(timeout=1.0)           # still CANCELED, settled once


def test_local_cancel_storm_settles_every_future():
    """Many concurrent cancels racing live local responses: every future
    settles (success or CANCELED), none hangs, none settles twice."""
    with Engine(None) as e:
        e.register("tick", lambda v: v + 1)
        errors = []

        def storm(i):
            try:
                fut = e.call_async(e.uri, "tick", i, timeout=5.0)
                if i % 2:
                    fut.cancel_call()
                try:
                    out = fut.result(timeout=5.0)
                    assert out == i + 1
                except RemoteError as err:
                    assert err.ret == Ret.CANCELED
            except Exception as err:            # noqa: BLE001
                errors.append(err)

        threads = [threading.Thread(target=storm, args=(i,))
                   for i in range(64)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=10.0)
        assert not errors
