"""RPC core semantics over both NA plugins: round trips, error paths,
origin/target symmetry, concurrency, fire-and-forget."""
import threading
import time

import numpy as np
import pytest

from repro.core.executor import Engine, RemoteError
from repro.core.types import Ret


@pytest.fixture(params=["self", "tcp", "sm"])
def engines(request):
    if request.param == "self":
        with Engine(None) as e:
            yield e, e
    elif request.param == "sm":
        import uuid
        tag = uuid.uuid4().hex[:8]
        with Engine(f"sm://rpc-a-{tag}") as a, \
                Engine(f"sm://rpc-b-{tag}") as b:
            yield a, b
    else:
        with Engine("tcp://127.0.0.1:0") as a, \
                Engine("tcp://127.0.0.1:0") as b:
            yield a, b


def test_echo(engines):
    srv, cli = engines
    srv.register("echo", lambda x: x)
    v = {"a": [1, 2.5, "x"], "arr": np.arange(4)}
    out = cli.call(srv.uri, "echo", v)
    assert out["a"] == v["a"]
    np.testing.assert_array_equal(out["arr"], v["arr"])


def test_unregistered_rpc_is_noentry(engines):
    srv, cli = engines
    with pytest.raises(RemoteError) as ei:
        cli.call(srv.uri, "nope", 1, timeout=5.0)
    assert ei.value.ret == Ret.NOENTRY


def test_handler_fault_propagates(engines):
    srv, cli = engines

    def bad(_):
        raise ValueError("boom")

    srv.register("bad", bad)
    with pytest.raises(RemoteError) as ei:
        cli.call(srv.uri, "bad", None, timeout=5.0)
    assert ei.value.ret == Ret.FAULT
    assert "boom" in str(ei.value)


def test_timeout(engines):
    srv, cli = engines
    srv.register("slow", lambda x: time.sleep(3.0) or x)
    t0 = time.time()
    with pytest.raises(RemoteError) as ei:
        cli.call(srv.uri, "slow", None, timeout=0.3)
    assert ei.value.ret == Ret.TIMEOUT
    assert time.time() - t0 < 2.0


def test_notify_fire_and_forget(engines):
    srv, cli = engines
    got = threading.Event()
    srv.register("note", lambda x: got.set(), no_response=True)
    cli.notify(srv.uri, "note", {"x": 1})
    assert got.wait(5.0)


def test_concurrent_calls(engines):
    srv, cli = engines
    srv.register("sq", lambda x: x * x)
    futs = [cli.call_async(srv.uri, "sq", i) for i in range(32)]
    assert [f.result(timeout=10) for f in futs] == [i * i for i in range(32)]


def test_origin_target_symmetry():
    """Paper C4: both endpoints serve and call simultaneously."""
    with Engine("tcp://127.0.0.1:0") as a, Engine("tcp://127.0.0.1:0") as b:
        a.register("ping_a", lambda x: ("a", x))
        b.register("ping_b", lambda x: ("b", x))
        assert a.call(b.uri, "ping_b", 1) == ("b", 1)
        assert b.call(a.uri, "ping_a", 2) == ("a", 2)

        # and a handler on b that itself calls back into a (service chain)
        def chained(x):
            return b.call(a.uri, "ping_a", x)[1] + 1

        b.register("chain", chained)
        assert a.call(b.uri, "chain", 10) == 11


def test_large_eager_payload(engines):
    srv, cli = engines
    srv.register("blob", lambda x: np.asarray(x).sum())
    a = np.ones(200_000, dtype=np.float64)      # 1.6 MB inline
    assert cli.call(srv.uri, "blob", a, timeout=30) == 200_000.0
