"""Optimizers: convergence on a toy problem, schedule shape, dtype policy,
microbatch-accumulation equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import P, unzip
from repro.train import optim


def toy_params():
    return {"w": P(jnp.zeros((8, 4)), ("embed", "mlp")),
            "b": P(jnp.zeros((4,)), ("mlp",)),
            "stack": (P(jnp.ones((2, 3)), ("layers", "mlp")),)}


def quad_loss(params, key=None):
    tgt = jnp.arange(32, dtype=jnp.float32).reshape(8, 4) / 10
    return jnp.sum((params["w"] - tgt) ** 2) + jnp.sum(params["b"] ** 2) \
        + jnp.sum((params["stack"][0] - 0.5) ** 2)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges(name):
    cfg = optim.OptConfig(name=name, lr=5e-2, weight_decay=0.0,
                          warmup=1, decay_steps=400)
    params_p = toy_params()
    params, _ = unzip(params_p)
    if name == "adamw":
        opt, _ = unzip(optim.adamw_init(params_p))
    else:
        opt, _ = unzip(optim.adafactor_init(params_p))

    @jax.jit
    def step(params, opt):
        grads = jax.grad(quad_loss)(params)
        if name == "adamw":
            p, m, v, c, stats = optim.adamw_update(
                cfg, params, grads, opt["m"], opt["v"], opt["count"])
            return p, {"m": m, "v": v, "count": c}, stats
        p, f, c, stats = optim.adafactor_update(
            cfg, params, grads, opt["f"], opt["count"])
        return p, {"f": f, "count": c}, stats

    l0 = float(quad_loss(params))
    for _ in range(300):
        params, opt, stats = step(params, opt)
    l1 = float(quad_loss(params))
    assert l1 < 0.01 * l0, (l0, l1)
    assert np.isfinite(float(stats["grad_norm"]))


def test_schedule_warmup_cosine():
    cfg = optim.OptConfig(lr=1e-3, warmup=10, decay_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(optim.schedule(cfg, jnp.int32(s))) for s in range(0, 120)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[119] < lrs[50] < lrs[11]
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-12


def test_state_dtype_policy():
    params_p = toy_params()
    st = optim.adamw_init(params_p)
    st = optim.cast_state(st, "bfloat16")
    vals, _ = unzip(st)
    assert vals["m"]["w"].dtype == jnp.bfloat16
    assert vals["count"].dtype == jnp.int32


def test_grad_clip_applied():
    cfg = optim.OptConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0,
                          warmup=0, decay_steps=10)
    params_p = toy_params()
    params, _ = unzip(params_p)
    opt, _ = unzip(optim.adamw_init(params_p))
    big = jax.tree_util.tree_map(lambda x: jnp.full_like(x, 100.0), params)
    p2, *_rest, stats = optim.adamw_update(cfg, params, big, opt["m"],
                                           opt["v"], opt["count"])
    # with clip the first-step |Δw| is bounded by lr (adam step ≈ ±1)
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) <= 1.05


def test_microbatch_accumulation_equivalence():
    """make_train_step(microbatches=4) == microbatches=1 for a linear-in-
    batch loss (same total batch)."""
    from repro import configs
    from repro.configs.base import ParallelConfig
    from repro.models import Model
    from repro.train.step import init_state, make_train_step

    cfg = configs.reduced("qwen1.5-0.5b").replace(compute_dtype="float32")
    model = Model(cfg)
    ocfg = optim.OptConfig(lr=1e-3, warmup=0, decay_steps=10)
    state1, _ = init_state(model, ocfg, jax.random.PRNGKey(0))
    state4 = jax.tree_util.tree_map(lambda x: x, state1)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                     cfg.vocab),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                      cfg.vocab),
    }
    s1 = jax.jit(make_train_step(model, ocfg,
                                 ParallelConfig(microbatches=1,
                                                remat="none")))
    s4 = jax.jit(make_train_step(model, ocfg,
                                 ParallelConfig(microbatches=4,
                                                remat="none")))
    out1, m1 = s1(state1, batch)
    out4, m4 = s4(state4, batch)
    w1 = jax.tree_util.tree_leaves(out1["params"])
    w4 = jax.tree_util.tree_leaves(out4["params"])
    for a, b in zip(w1, w4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)
