"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) and the fast-XLA
paths vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fletcher import fletcher64_pallas
from repro.kernels.moe_router import router_topk_pallas
from repro.kernels.rglru_scan import rglru_pallas
from repro.kernels.ssd import ssd_pallas

from proptest import cases

R = np.random.default_rng(0)


def t(*s, dtype=np.float32):
    return jnp.asarray(R.standard_normal(s), dtype)


ATTN_SWEEP = [
    # S, T, Hq, Hkv, D, causal, window, softcap, prefix, dtype
    (64, 64, 4, 2, 16, True, 0, 0.0, None, "float32"),
    (128, 128, 4, 4, 32, True, 32, 0.0, None, "float32"),
    (96, 96, 8, 1, 64, True, 0, 30.0, None, "float32"),
    (80, 80, 4, 2, 16, True, 0, 0.0, 24, "float32"),
    (200, 200, 2, 2, 16, True, 0, 0.0, None, "float32"),
    (64, 64, 2, 2, 16, False, 0, 0.0, None, "float32"),
    (128, 128, 4, 2, 32, True, 0, 0.0, None, "bfloat16"),
]


@pytest.mark.parametrize("case", ATTN_SWEEP)
def test_flash_attention_vs_ref(case):
    S, T, Hq, Hkv, D, causal, window, softcap, prefix, dt = case
    q, k, v = t(2, S, Hq, D, dtype=dt), t(2, T, Hkv, D, dtype=dt), \
        t(2, T, Hkv, D, dtype=dt)
    pl_arr = None if prefix is None else jnp.asarray(prefix)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, prefix_len=pl_arr)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, prefix_len=prefix,
                          interpret=True, block_q=64, block_k=64)
    tol = 2e-2 if dt == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("case", ATTN_SWEEP)
def test_xla_attention_vs_ref(case):
    S, T, Hq, Hkv, D, causal, window, softcap, prefix, dt = case
    q, k, v = t(2, S, Hq, D, dtype=dt), t(2, T, Hkv, D, dtype=dt), \
        t(2, T, Hkv, D, dtype=dt)
    pl_arr = None if prefix is None else jnp.asarray(prefix)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, prefix_len=pl_arr)
    got = ops._attention_chunked(q, k, v, causal=causal, window=window,
                                 softcap=softcap, q_offset=0,
                                 prefix_len=pl_arr, kv_chunk=48)
    tol = 2e-2 if dt == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@cases(8)
def test_attention_decode_property(rng):
    """Decode (S=1 at offset T-1) equals the last row of full attention."""
    B, T = 2, int(rng.integers(8, 64))
    Hq, Hkv, D = 4, 2, 16
    q = t(B, T, Hq, D)
    k, v = t(B, T, Hkv, D), t(B, T, Hkv, D)
    full = ref.attention_ref(q, k, v, causal=True)
    got = ops._attention_decode(q[:, -1:], k, v, causal=True, window=0,
                                softcap=0.0, q_offset=T - 1, prefix_len=None)
    np.testing.assert_allclose(got[:, 0], full[:, -1], rtol=2e-5, atol=2e-5)


SSD_SWEEP = [
    (2, 64, 4, 8, 2, 16, 32, True, True),
    (1, 100, 2, 16, 1, 8, 32, False, False),
    (3, 33, 4, 4, 4, 4, 16, True, False),
]


@pytest.mark.parametrize("case", SSD_SWEEP)
def test_ssd_pallas_vs_ref(case):
    B, S, H, P, G, N, Q, use_D, use_h0 = case
    x, dt_ = t(B, S, H, P), jax.nn.softplus(t(B, S, H))
    A = -jnp.exp(t(H) * 0.5)
    Bm, Cm = t(B, S, G, N) * 0.3, t(B, S, G, N) * 0.3
    Dm = t(H) if use_D else None
    h0 = t(B, H, P, N) * 0.1 if use_h0 else None
    yr, hr = ref.ssd_ref(x, dt_, A, Bm, Cm, Dm, h0)
    yp, hp = ssd_pallas(x, dt_, A, Bm, Cm, Dm, h0, chunk=Q, interpret=True)
    np.testing.assert_allclose(yp, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(hp, hr, rtol=2e-4, atol=2e-4)
    yx, hx = ops._ssd_chunked(x, dt_, A, Bm, Cm, Dm, h0, chunk=Q)
    np.testing.assert_allclose(yx, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(hx, hr, rtol=2e-4, atol=2e-4)


@cases(6)
def test_ssd_chunk_invariance(rng):
    """SSD output must not depend on the chunk size (pure algebra)."""
    B, S, H, P, G, N = 1, 48, 2, 4, 1, 8
    x, dt_ = t(B, S, H, P), jax.nn.softplus(t(B, S, H))
    A = -jnp.exp(t(H) * 0.5)
    Bm, Cm = t(B, S, G, N) * 0.3, t(B, S, G, N) * 0.3
    y1, h1 = ops._ssd_chunked(x, dt_, A, Bm, Cm, None, None, chunk=8)
    y2, h2 = ops._ssd_chunked(x, dt_, A, Bm, Cm, None, None,
                              chunk=int(rng.choice([12, 16, 24, 48])))
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h1, h2, rtol=2e-4, atol=2e-4)


RGLRU_SWEEP = [(2, 64, 32, 16, 32, True), (1, 70, 40, 16, 32, False),
               (3, 128, 8, 64, 8, True)]


@pytest.mark.parametrize("case", RGLRU_SWEEP)
def test_rglru_pallas_vs_ref(case):
    B, S, W, bt, bw, use_h0 = case
    x, rg, ig = t(B, S, W), t(B, S, W), t(B, S, W)
    ll = t(W)
    h0 = t(B, W) * 0.2 if use_h0 else None
    hr, hrf = ref.rglru_ref(x, rg, ig, ll, h0)
    hp, hpf = rglru_pallas(x, rg, ig, ll, h0, interpret=True,
                           block_w=bw, block_t=bt)
    np.testing.assert_allclose(hp, hr, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(hpf, hrf, rtol=2e-5, atol=2e-5)
    hx, hxf = ops._rglru_assoc(x, rg, ig, ll, h0)
    np.testing.assert_allclose(hx, hr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(hxf, hrf, rtol=2e-4, atol=2e-4)


@cases(10)
def test_rglru_stability_property(rng):
    """|h| stays bounded: a ∈ (0,1) and beta = sqrt(1-a²) normalizes."""
    B, S, W = 1, 256, 8
    x = jnp.asarray(rng.standard_normal((B, S, W)), jnp.float32)
    h, hf = ref.rglru_ref(x, x * 0, x * 0 + 4.0, jnp.zeros(W))
    assert float(jnp.max(jnp.abs(h))) < 10.0 * float(jnp.max(jnp.abs(x)))


@pytest.mark.parametrize("TE", [(32, 8), (100, 16), (256, 40)])
@pytest.mark.parametrize("k", [1, 2, 6])
def test_router_pallas_vs_ref(TE, k):
    T, E = TE
    if k > E:
        pytest.skip("k > E")
    logits = t(T, E)
    wr, ir, pr = ref.router_topk_ref(logits, k)
    wp, ip, pp = router_topk_pallas(logits, k, interpret=True, block_t=32)
    np.testing.assert_allclose(wp, wr, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(ip, ir)
    np.testing.assert_allclose(pp, pr, rtol=1e-5, atol=1e-6)


@cases(12)
def test_fletcher_pallas_vs_ref(rng):
    n = int(rng.integers(1, 50_000))
    buf = rng.integers(0, 2 ** 32, size=n, dtype=np.uint32)
    assert fletcher64_pallas(buf, interpret=True) == \
        ref.fletcher64_ref(buf) == ops.fletcher64(buf, impl="xla")


@cases(8)
def test_fletcher_detects_corruption(rng):
    buf = rng.integers(0, 2 ** 32, size=1000, dtype=np.uint32)
    want = ops.fletcher64(buf, impl="xla")
    i = int(rng.integers(0, buf.size))
    buf2 = buf.copy()
    buf2[i] ^= np.uint32(1 << int(rng.integers(0, 32)))
    assert ops.fletcher64(buf2, impl="xla") != want
