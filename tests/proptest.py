"""Minimal property-test harness (the offline container has no
`hypothesis`; this emulates its seeded-case style so the invariant tests
read the same way and can be ported back verbatim)."""
from __future__ import annotations

import functools
import numpy as np


def cases(n: int = 25, seed: int = 0):
    """Run the test n times with a seeded numpy Generator as first arg."""
    def deco(fn):
        def wrapper():
            for i in range(n):
                rng = np.random.default_rng(seed * 7919 + i)
                try:
                    fn(rng)
                except AssertionError as e:
                    raise AssertionError(f"[case {i}] {e}") from e
        wrapper.__name__ = fn.__name__       # no __wrapped__: pytest must
        wrapper.__doc__ = fn.__doc__         # see a zero-arg signature
        return wrapper
    return deco


def draw_shape(rng, ndim_range=(1, 3), dim_range=(1, 17)):
    nd = int(rng.integers(*ndim_range))
    return tuple(int(rng.integers(*dim_range)) for _ in range(nd))


# ---------------------------------------------------------------------------
# Value generators (the hypothesis `st.recursive(...)` equivalents) for the
# proc/bulk wire-format properties.
# ---------------------------------------------------------------------------
_DTYPES = ["float32", "float64", "int8", "int16", "int32", "int64",
           "uint8", "uint16", "bool"]


def draw_ndarray(rng, max_dim=9):
    dt = np.dtype(str(rng.choice(_DTYPES)))
    shape = draw_shape(rng, (1, 4), (1, max_dim))
    if dt == np.bool_:
        return rng.integers(0, 2, size=shape).astype(bool)
    if dt.kind in "iu":
        info = np.iinfo(dt)
        return rng.integers(info.min, int(info.max) + 1, size=shape,
                            dtype=np.int64).astype(dt)
    return rng.standard_normal(shape).astype(dt)


def draw_any_value(rng, depth=3):
    """Arbitrary proc_any-compatible value: scalars, bytes/str, ndarrays,
    and nested list/tuple/dict containers."""
    atoms = ["none", "bool", "int", "float", "str", "bytes", "ndarray"]
    kinds = atoms + (["list", "tuple", "dict"] if depth > 0 else [])
    k = str(rng.choice(kinds))
    if k == "none":
        return None
    if k == "bool":
        return bool(rng.integers(2))
    if k == "int":
        return int(rng.integers(-2**62, 2**62))
    if k == "float":
        return float(rng.standard_normal())
    if k == "str":
        return "".join(chr(int(c)) for c in
                       rng.integers(32, 0x2FA0, size=int(rng.integers(0, 12))))
    if k == "bytes":
        return bytes(rng.integers(0, 256, size=int(rng.integers(0, 16)),
                                  dtype=np.uint8))
    if k == "ndarray":
        return draw_ndarray(rng)
    n = int(rng.integers(0, 4))
    if k == "list":
        return [draw_any_value(rng, depth - 1) for _ in range(n)]
    if k == "tuple":
        return tuple(draw_any_value(rng, depth - 1) for _ in range(n))
    return {f"k{i}": draw_any_value(rng, depth - 1) for i in range(n)}


def values_equal(a, b) -> bool:
    """Deep equality that treats ndarrays by content."""
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (isinstance(a, np.ndarray) and isinstance(b, np.ndarray)
                and a.dtype == b.dtype and a.shape == b.shape
                and np.array_equal(a, b))
    if isinstance(a, (list, tuple)):
        return (type(a) is type(b) and len(a) == len(b)
                and all(values_equal(x, y) for x, y in zip(a, b)))
    if isinstance(a, dict):
        return (isinstance(b, dict) and a.keys() == b.keys()
                and all(values_equal(a[k], b[k]) for k in a))
    return type(a) is type(b) and a == b


def draw_descriptor(rng):
    """Random BulkDescriptor (import deferred: repro on sys.path at test
    time via conftest)."""
    from repro.core.bulk import BulkDescriptor, BulkSegment
    nseg = int(rng.integers(1, 6))
    segs = [BulkSegment(key=int(rng.integers(1, 2**63)),
                        size=int(rng.integers(0, 2**40)))
            for _ in range(nseg)]
    scheme = str(rng.choice(["self", "sm", "tcp"]))
    uri = f"{scheme}://node-{int(rng.integers(1e6))}"
    return BulkDescriptor(uri, segs, bool(rng.integers(2)),
                          bool(rng.integers(2)))


def draw_truncation(rng, data: bytes) -> bytes:
    """A strict prefix of ``data`` (decoders must reject, never read OOB)."""
    assert len(data) > 0
    return bytes(data[:int(rng.integers(0, len(data)))])
