"""Minimal property-test harness (the offline container has no
`hypothesis`; this emulates its seeded-case style so the invariant tests
read the same way and can be ported back verbatim)."""
from __future__ import annotations

import functools
import numpy as np


def cases(n: int = 25, seed: int = 0):
    """Run the test n times with a seeded numpy Generator as first arg."""
    def deco(fn):
        def wrapper():
            for i in range(n):
                rng = np.random.default_rng(seed * 7919 + i)
                try:
                    fn(rng)
                except AssertionError as e:
                    raise AssertionError(f"[case {i}] {e}") from e
        wrapper.__name__ = fn.__name__       # no __wrapped__: pytest must
        wrapper.__doc__ = fn.__doc__         # see a zero-arg signature
        return wrapper
    return deco


def draw_shape(rng, ndim_range=(1, 3), dim_range=(1, 17)):
    nd = int(rng.integers(*ndim_range))
    return tuple(int(rng.integers(*dim_range)) for _ in range(nd))
