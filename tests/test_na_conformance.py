"""NA conformance matrix — one suite, every plugin.

The paper's C1 claim is that the NA contract is plugin-agnostic: upper
layers cannot tell transports apart.  This suite pins that contract —
addressing, unexpected/expected messaging, one-sided RMA, cancellation,
and eager-limit enforcement — across ``self``, ``tcp`` and ``sm``, so a
new plugin is done exactly when this matrix passes (DESIGN.md §6).
"""
import subprocess
import sys
import time
import uuid

import numpy as np
import pytest

from repro.core.na import (NACap, SelfPlugin, SMPlugin, TCPPlugin,
                           initialize)
from repro.core.types import MercuryError, Ret

PLUGINS = ["self", "tcp", "sm"]


def make_plugin(kind: str):
    if kind == "self":
        return SelfPlugin()
    if kind == "tcp":
        return TCPPlugin(None, listen=True)
    return SMPlugin(f"sm://conf-{uuid.uuid4().hex[:10]}")


@pytest.fixture(params=PLUGINS)
def pair(request):
    a, b = make_plugin(request.param), make_plugin(request.param)
    yield a, b
    a.finalize()
    b.finalize()


def spin(plugins, cond, timeout=10.0):
    deadline = time.time() + timeout
    while not cond() and time.time() < deadline:
        for p in plugins:
            p.progress(0.005)
    assert cond(), "condition not met within timeout"


# -- addressing ---------------------------------------------------------------
def test_addr_self_and_lookup(pair):
    a, b = pair
    uri = a.addr_self().uri
    assert uri.startswith(f"{a.name}://") or uri.startswith(f"{a.name}-")
    addr = b.addr_lookup(uri)
    assert addr.uri == uri
    assert addr == b.addr_lookup(uri)            # stable equality
    with pytest.raises(MercuryError):
        b.addr_lookup("bogus://nowhere")


# -- two-sided messaging ------------------------------------------------------
def test_unexpected_roundtrip(pair):
    a, b = pair
    got = {}
    b.msg_recv_unexpected(
        lambda ret, src, tag, data: got.update(ret=ret, src=src.uri, tag=tag,
                                               data=bytes(data)))
    sent = {}
    a.msg_send_unexpected(a.addr_lookup(b.addr_self().uri), b"payload-1", 17,
                          lambda ret: sent.update(ret=ret))
    spin(pair, lambda: "data" in got and "ret" in sent)
    assert got["ret"] == Ret.SUCCESS and sent["ret"] == Ret.SUCCESS
    assert got["tag"] == 17 and got["data"] == b"payload-1"
    assert got["src"] == a.addr_self().uri


def test_unexpected_vectored_send(pair):
    a, b = pair
    got = {}
    b.msg_recv_unexpected(
        lambda ret, src, tag, data: got.update(data=bytes(data)))
    a.msg_send_unexpected(a.addr_lookup(b.addr_self().uri),
                          (b"head|", b"body|", b"tail"), 3, lambda ret: None)
    spin(pair, lambda: "data" in got)
    assert got["data"] == b"head|body|tail"


def test_expected_tag_matching(pair):
    a, b = pair
    addr_a = b.addr_lookup(a.addr_self().uri)
    addr_b = a.addr_lookup(b.addr_self().uri)
    got = {}
    b.msg_recv_expected(addr_a, 1, lambda ret, data: got.update(one=bytes(data)))
    b.msg_recv_expected(addr_a, 2, lambda ret, data: got.update(two=bytes(data)))
    # out-of-order sends must still match by tag
    a.msg_send_expected(addr_b, b"TWO", 2, lambda ret: None)
    a.msg_send_expected(addr_b, b"ONE", 1, lambda ret: None)
    spin(pair, lambda: len(got) == 2)
    assert got == {"one": b"ONE", "two": b"TWO"}


def test_expected_waits_for_post(pair):
    """An expected message that arrives before its recv is posted must be
    queued, not dropped."""
    a, b = pair
    addr_b = a.addr_lookup(b.addr_self().uri)
    a.msg_send_expected(addr_b, b"early", 9, lambda ret: None)
    for p in pair:                       # let it land unmatched
        p.progress(0.01)
    got = {}
    b.msg_recv_expected(None, 9, lambda ret, data: got.update(data=bytes(data)))
    spin(pair, lambda: "data" in got)
    assert got["data"] == b"early"


# -- one-sided RMA ------------------------------------------------------------
def _rma(pair, fn, *args):
    """Issue put/get; normalize sync-raise vs async-error completion."""
    box = {}
    try:
        fn(*args, lambda ret: box.setdefault("ret", ret))
    except MercuryError as e:
        return e.ret
    spin(pair, lambda: "ret" in box)
    return box["ret"]


def test_rma_put_get(pair):
    a, b = pair
    addr_b = a.addr_lookup(b.addr_self().uri)
    remote_buf = np.zeros(64, np.uint8)
    mh_remote = b.mem_register(remote_buf)
    src = np.arange(64, dtype=np.uint8)
    mh_local = a.mem_register(src)

    assert _rma(pair, a.put, mh_local, 0, addr_b, mh_remote, 0, 64) == Ret.SUCCESS
    spin(pair, lambda: remote_buf[63] == 63)
    np.testing.assert_array_equal(remote_buf, src)

    back = np.zeros(32, np.uint8)
    mh_back = a.mem_register(back)
    assert _rma(pair, a.get, mh_back, 0, addr_b, mh_remote, 16, 32) == Ret.SUCCESS
    spin(pair, lambda: back[0] == 16)
    np.testing.assert_array_equal(back, src[16:48])

    b.mem_deregister(mh_remote)
    assert _rma(pair, a.get, mh_back, 0, addr_b, mh_remote, 0, 8) != Ret.SUCCESS


def test_rma_permission_enforced(pair):
    a, b = pair
    addr_b = a.addr_lookup(b.addr_self().uri)
    secret = np.arange(16, dtype=np.uint8)
    mh_ro = b.mem_register(secret, read=True, write=False)
    local = np.zeros(16, np.uint8)
    mh_local = a.mem_register(local)
    assert _rma(pair, a.put, mh_local, 0, addr_b, mh_ro, 0, 16) != Ret.SUCCESS
    # read side still works
    assert _rma(pair, a.get, mh_local, 0, addr_b, mh_ro, 0, 16) == Ret.SUCCESS
    spin(pair, lambda: local[15] == 15)


def test_rma_out_of_bounds(pair):
    a, b = pair
    addr_b = a.addr_lookup(b.addr_self().uri)
    mh_remote = b.mem_register(np.zeros(16, np.uint8))
    mh_local = a.mem_register(np.zeros(64, np.uint8))
    assert _rma(pair, a.put, mh_local, 0, addr_b, mh_remote, 8, 16) != Ret.SUCCESS


# -- cancellation -------------------------------------------------------------
def test_cancel_unexpected_recv(pair):
    a, b = pair
    fired = []
    op = b.msg_recv_unexpected(lambda *args: fired.append(args))
    b.cancel(op)
    a.msg_send_unexpected(a.addr_lookup(b.addr_self().uri), b"msg", 5,
                          lambda ret: None)
    for _ in range(20):
        for p in pair:
            p.progress(0.005)
    assert not fired and op.canceled
    # the message was not consumed by the canceled recv: a fresh post gets it
    got = {}
    b.msg_recv_unexpected(lambda ret, src, tag, data: got.update(d=bytes(data)))
    spin(pair, lambda: "d" in got)
    assert got["d"] == b"msg"


def test_cancel_expected_recv(pair):
    a, b = pair
    fired = []
    op = b.msg_recv_expected(None, 77, lambda *args: fired.append(args))
    b.cancel(op)
    for _ in range(5):
        b.progress(0.005)
    assert not fired and op.canceled and not op.done


# -- eager limits -------------------------------------------------------------
def test_oversized_unexpected_rejected(pair):
    a, b = pair
    addr_b = a.addr_lookup(b.addr_self().uri)
    too_big = b"x" * (a.max_unexpected_size + 1)
    with pytest.raises(MercuryError) as ei:
        a.msg_send_unexpected(addr_b, too_big, 0, lambda ret: None)
    assert ei.value.ret == Ret.MSGSIZE


def test_oversized_expected_rejected(pair):
    a, b = pair
    if a.max_expected_size > (1 << 26):
        pytest.skip("plugin has no practical expected limit")
    addr_b = a.addr_lookup(b.addr_self().uri)
    with pytest.raises(MercuryError) as ei:
        a.msg_send_expected(addr_b, b"x" * (a.max_expected_size + 1), 0,
                            lambda ret: None)
    assert ei.value.ret == Ret.MSGSIZE


# -- capability surface -------------------------------------------------------
def test_capability_flags(pair):
    a, _ = pair
    if a.name in ("self", "sm"):
        assert a.caps & NACap.NATIVE_RMA and a.caps & NACap.ZERO_COPY
    else:
        assert not a.caps & NACap.NATIVE_RMA
    assert a.max_unexpected_size > 0 and a.max_expected_size > 0


# -- locality-tiered routing --------------------------------------------------
def test_tiered_resolution_prefers_cheapest_reachable():
    """An address set resolves self > sm > tcp, skipping unreachable tiers."""
    tag = uuid.uuid4().hex[:8]
    srv = initialize(f"self://tier-{tag};sm://tier-{tag};tcp://127.0.0.1:0")
    cli = initialize(f"self://tcli-{tag};sm://tcli-{tag};tcp://127.0.0.1:0")
    try:
        srv_set = srv.addr_self().uri
        assert srv_set.count(";") == 2
        # same process: the self tier wins
        assert cli.addr_lookup(srv_set).uri == f"self://tier-{tag}"
        # self tier unreachable (no such in-process instance): sm wins
        ghost = f"self://ghost-{tag};sm://tier-{tag};tcp://127.0.0.1:1"
        assert cli.addr_lookup(ghost).uri == f"sm://tier-{tag}"
        # only tcp reachable
        tcp_uri = [u for u in srv_set.split(";") if u.startswith("tcp")][0]
        only_tcp = f"self://ghost-{tag};sm://ghost-{tag};{tcp_uri}"
        assert cli.addr_lookup(only_tcp).uri == tcp_uri
    finally:
        srv.finalize()
        cli.finalize()


def test_multi_transport_engine_end_to_end():
    """Engines listening on an address set: calls route over the cheapest
    tier, and bulk descriptors minted by a multi engine stay valid."""
    from repro.core.executor import Engine
    tag = uuid.uuid4().hex[:8]
    with Engine(f"self://ms-{tag};sm://ms-{tag};tcp://127.0.0.1:0") as srv, \
            Engine(f"self://mc-{tag};sm://mc-{tag};tcp://127.0.0.1:0") as cli:
        srv.register("echo", lambda x: x)
        assert cli.call(srv.uri, "echo", {"v": 7})["v"] == 7
        # shared-key registration: pull through the resolved tier
        src = np.arange(10_000, dtype=np.float32)
        h = srv.expose([src])
        dst = np.zeros_like(src)
        hd = cli.expose([dst])
        cli.pull(srv.uri, h.descriptor(), hd)
        np.testing.assert_array_equal(dst, src)


def test_multi_falls_back_when_tier_dies():
    """If the cheap tier's listener vanishes, a fresh lookup of the same
    address set lands on the next tier instead of failing."""
    tag = uuid.uuid4().hex[:8]
    a = SelfPlugin(f"self://dies-{tag}")
    b = SMPlugin(f"sm://dies-{tag}")
    cli = initialize([f"self://dcli-{tag}", f"sm://dcli-{tag}"])
    try:
        addr_set = f"self://dies-{tag};sm://dies-{tag}"
        assert cli.addr_lookup(addr_set).uri.startswith("self://")
        a.finalize()                     # self tier gone
        assert cli.addr_lookup(addr_set).uri.startswith("sm://")
    finally:
        b.finalize()
        cli.finalize()


# -- sm cross-process ---------------------------------------------------------
SM_CHILD = """
import sys, time
sys.path.insert(0, "src")
import numpy as np
from repro.core.na import SMPlugin

parent_uri, key, size = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
from repro.core.na.base import NAMemHandle
p = SMPlugin("sm://child-" + sys.argv[4])
addr = p.addr_lookup(parent_uri)

got = {}
p.msg_recv_expected(addr, 2, lambda ret, data: got.update(d=bytes(data)))
p.msg_send_unexpected(addr, b"hello-from-child", 1, lambda ret: None)
t0 = time.time()
while "d" not in got and time.time() - t0 < 15:
    p.progress(0.01)
assert got.get("d") == b"go", got

# one-sided put into the parent's shm-backed registration: the parent's
# progress loop is *not* serving this — pure initiator-side copy
local = np.arange(size, dtype=np.uint8)
mh_local = p.mem_register(local)
remote = NAMemHandle(key=key, size=size, owner_uri=parent_uri)
done = []
p.put(mh_local, 0, addr, remote, 0, size, lambda ret: done.append(ret))
t0 = time.time()
while not done and time.time() - t0 < 15:
    p.progress(0.01)
p.msg_send_unexpected(addr, b"put-done", 3, lambda ret: None)
t0 = time.time()
while time.time() - t0 < 1:
    p.progress(0.01)
p.finalize()
print("CHILD_OK", done[0].name)
"""


def test_sm_cross_process_messaging_and_rma():
    tag = uuid.uuid4().hex[:10]
    parent = SMPlugin(f"sm://parent-{tag}")
    try:
        target = parent.alloc_array((256,), np.uint8)
        target[:] = 0
        mh = parent.mem_register(target)

        child = subprocess.Popen(
            [sys.executable, "-c", SM_CHILD, parent.addr_self().uri,
             str(mh.key), "256", tag],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True, cwd=".")

        events = {}

        def on_unexp(ret, src, tag_, data):
            parent.msg_recv_unexpected(on_unexp)
            events[bytes(data)] = src

        parent.msg_recv_unexpected(on_unexp)
        spin([parent], lambda: b"hello-from-child" in events, timeout=20)
        src = events[b"hello-from-child"]
        parent.msg_send_expected(src, b"go", 2, lambda ret: None)
        spin([parent], lambda: b"put-done" in events, timeout=20)
        np.testing.assert_array_equal(np.asarray(target),
                                      np.arange(256, dtype=np.uint8))
        out, err = child.communicate(timeout=20)
        assert "CHILD_OK SUCCESS" in out, out + err
    finally:
        parent.finalize()
