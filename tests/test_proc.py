"""Proc (serialization) properties: roundtrip identity, wire compactness,
dataclass derivation, error detection."""
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np
import pytest

from repro.core import proc
from repro.core.types import MercuryError

from proptest import cases, draw_shape


def roundtrip(p, v):
    data = proc.encode(p, v)
    out = proc.decode(p, data)
    return out


@cases(50)
def test_varint_roundtrip(rng):
    n = int(rng.integers(0, 2 ** 62))
    assert roundtrip(proc.proc_varint, n) == n


@cases(30)
def test_scalars_roundtrip(rng):
    for p, lo, hi in [(proc.proc_uint8, 0, 255),
                      (proc.proc_int32, -2**31, 2**31 - 1),
                      (proc.proc_int64, -2**63, 2**63 - 1)]:
        v = int(rng.integers(lo, hi))
        assert roundtrip(p, v) == v
    f = float(rng.standard_normal())
    assert roundtrip(proc.proc_float64, f) == f


@cases(30)
def test_ndarray_roundtrip(rng):
    dt = rng.choice(["float32", "int32", "uint8", "float64", "int16"])
    a = rng.standard_normal(draw_shape(rng)).astype(dt)
    out = roundtrip(proc.proc_ndarray, a)
    np.testing.assert_array_equal(a, out)
    assert out.dtype == a.dtype


@cases(30)
def test_any_roundtrip(rng):
    v = {
        "s": "héllo",
        "xs": [int(rng.integers(100)), 2.5, None, True],
        "t": (1, "two"),
        "nested": {"arr": rng.standard_normal((3, 2)).astype(np.float32)},
        "b": b"\x00\xff",
    }
    out = roundtrip(proc.proc_any, v)
    assert out["s"] == v["s"] and out["xs"] == v["xs"] and out["t"] == v["t"]
    np.testing.assert_array_equal(out["nested"]["arr"], v["nested"]["arr"])
    assert out["b"] == v["b"]


def test_dataclass_derive():
    @dataclasses.dataclass
    class Inner:
        xs: List[int]
        name: str

    @dataclasses.dataclass
    class Msg:
        a: int
        b: float
        inner: Inner
        opt: Optional[str]
        table: Dict[str, int]
        arr: np.ndarray

    p = proc.derive(Msg)
    m = Msg(3, 2.5, Inner([1, 2], "x"), None, {"k": 9},
            np.arange(6, dtype=np.int64).reshape(2, 3))
    out = roundtrip(p, m)
    assert out.a == 3 and out.inner.xs == [1, 2] and out.opt is None
    np.testing.assert_array_equal(out.arr, m.arr)


def test_decode_underflow_raises():
    data = proc.encode(proc.proc_str, "hello")
    with pytest.raises(MercuryError):
        proc.decode(proc.proc_str, data[:2])


def test_varint_compactness():
    assert len(proc.encode(proc.proc_varint, 5)) == 1
    assert len(proc.encode(proc.proc_varint, 300)) == 2


def test_zero_copy_decode_views_buffer():
    a = np.arange(1000, dtype=np.float32)
    data = proc.encode(proc.proc_ndarray, a)
    out = proc.decode(proc.proc_ndarray, data)
    assert not out.flags["OWNDATA"]          # view into the message buffer
