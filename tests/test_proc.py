"""Proc (serialization) properties: roundtrip identity, wire compactness,
dataclass derivation, error detection."""
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np
import pytest

from repro.core import proc
from repro.core.types import MercuryError

from proptest import (cases, draw_any_value, draw_ndarray, draw_shape,
                      draw_truncation, values_equal)


def roundtrip(p, v):
    data = proc.encode(p, v)
    out = proc.decode(p, data)
    return out


@cases(50)
def test_varint_roundtrip(rng):
    n = int(rng.integers(0, 2 ** 62))
    assert roundtrip(proc.proc_varint, n) == n


@cases(30)
def test_scalars_roundtrip(rng):
    for p, lo, hi in [(proc.proc_uint8, 0, 255),
                      (proc.proc_int32, -2**31, 2**31 - 1),
                      (proc.proc_int64, -2**63, 2**63 - 1)]:
        v = int(rng.integers(lo, hi))
        assert roundtrip(p, v) == v
    f = float(rng.standard_normal())
    assert roundtrip(proc.proc_float64, f) == f


@cases(30)
def test_ndarray_roundtrip(rng):
    dt = rng.choice(["float32", "int32", "uint8", "float64", "int16"])
    a = rng.standard_normal(draw_shape(rng)).astype(dt)
    out = roundtrip(proc.proc_ndarray, a)
    np.testing.assert_array_equal(a, out)
    assert out.dtype == a.dtype


@cases(30)
def test_any_roundtrip(rng):
    v = {
        "s": "héllo",
        "xs": [int(rng.integers(100)), 2.5, None, True],
        "t": (1, "two"),
        "nested": {"arr": rng.standard_normal((3, 2)).astype(np.float32)},
        "b": b"\x00\xff",
    }
    out = roundtrip(proc.proc_any, v)
    assert out["s"] == v["s"] and out["xs"] == v["xs"] and out["t"] == v["t"]
    np.testing.assert_array_equal(out["nested"]["arr"], v["nested"]["arr"])
    assert out["b"] == v["b"]


def test_dataclass_derive():
    @dataclasses.dataclass
    class Inner:
        xs: List[int]
        name: str

    @dataclasses.dataclass
    class Msg:
        a: int
        b: float
        inner: Inner
        opt: Optional[str]
        table: Dict[str, int]
        arr: np.ndarray

    p = proc.derive(Msg)
    m = Msg(3, 2.5, Inner([1, 2], "x"), None, {"k": 9},
            np.arange(6, dtype=np.int64).reshape(2, 3))
    out = roundtrip(p, m)
    assert out.a == 3 and out.inner.xs == [1, 2] and out.opt is None
    np.testing.assert_array_equal(out.arr, m.arr)


def test_decode_underflow_raises():
    data = proc.encode(proc.proc_str, "hello")
    with pytest.raises(MercuryError):
        proc.decode(proc.proc_str, data[:2])


def test_varint_compactness():
    assert len(proc.encode(proc.proc_varint, 5)) == 1
    assert len(proc.encode(proc.proc_varint, 300)) == 2


def test_zero_copy_decode_views_buffer():
    a = np.arange(1000, dtype=np.float32)
    data = proc.encode(proc.proc_ndarray, a)
    out = proc.decode(proc.proc_ndarray, data)
    assert not out.flags["OWNDATA"]          # view into the message buffer


def test_large_bytes_decode_is_zero_copy():
    """Regression for the decode double-copy: a >= ZEROCOPY_MIN bytes
    field must come back as a read-only view into the message buffer,
    not a copy (the old path paid bytes(read()) AND the read() slice)."""
    blob = bytes(range(256)) * 64            # 16 KiB >= ZEROCOPY_MIN
    data = bytes(proc.encode(proc.proc_bytes, blob))
    out = proc.decode(proc.proc_bytes, data)
    assert isinstance(out, memoryview) and out.readonly
    assert out == blob
    # buffer identity: the view aliases `data`, no private allocation
    base = np.frombuffer(data, np.uint8)
    view = np.frombuffer(out, np.uint8)
    assert np.shares_memory(base, view)


def test_small_bytes_decode_stays_bytes():
    """Small fields stay plain bytes: a view would pin the whole message
    buffer alive for a handful of bytes."""
    out = proc.decode(proc.proc_bytes, proc.encode(proc.proc_bytes, b"abc"))
    assert isinstance(out, bytes) and out == b"abc"


def test_large_encode_returns_view_not_copy():
    """Regression for the encode full-copy: past ENCODE_VIEW_MIN the
    encoder must hand out a view of its build buffer, not a getvalue()
    duplicate of the whole payload."""
    big = {"blob": b"\x5a" * (2 * proc.ENCODE_VIEW_MIN)}
    enc = proc.encode(proc.proc_any, big)
    assert isinstance(enc, memoryview)
    small = proc.encode(proc.proc_any, {"x": 1})
    assert isinstance(small, bytes)


def test_decoded_view_reencodes_as_bytes():
    """proc_any must accept the memoryviews its own decode now returns
    (proxy paths re-encode decoded requests verbatim)."""
    blob = b"\x11" * (2 * proc.ZEROCOPY_MIN)
    v = proc.decode(proc.proc_any, proc.encode(proc.proc_any, {"b": blob}))
    assert isinstance(v["b"], memoryview)
    again = proc.decode(proc.proc_any, proc.encode(proc.proc_any, v))
    assert bytes(again["b"]) == blob


# ---------------------------------------------------------------------------
# Hypothesis-style properties (seeded-random fallback, see proptest.py)
# ---------------------------------------------------------------------------
@cases(60)
def test_any_roundtrip_arbitrary_values(rng):
    """∀ v drawn from the proc_any domain: decode(encode(v)) == v."""
    v = draw_any_value(rng)
    assert values_equal(roundtrip(proc.proc_any, v), v), v


@cases(60)
def test_any_decode_consumes_exactly(rng):
    """Encoding is self-delimiting: a decode must consume every byte."""
    data = proc.encode(proc.proc_any, draw_any_value(rng))
    buf = proc.ProcBuf(encoding=False, data=data)
    proc.proc_any(buf)
    assert buf.done(), "trailing bytes after decode"


@cases(60)
def test_any_truncated_raises_or_shrinks(rng):
    """∀ strict prefix of an encoding: decoding must raise MercuryError —
    never crash, never read out of bounds.  (A prefix may also decode to a
    *different* valid value when the cut lands on a value boundary of a
    container; it must never equal the original.)"""
    v = draw_any_value(rng)
    data = proc.encode(proc.proc_any, v)
    if not data:
        return
    cut = draw_truncation(rng, data)
    if len(cut) == len(data):
        return
    try:
        out = proc.decode(proc.proc_any, cut)
    except MercuryError:
        return
    assert not values_equal(out, v)


@cases(40)
def test_ndarray_truncated_raises(rng):
    a = draw_ndarray(rng)
    data = proc.encode(proc.proc_ndarray, a)
    cut = draw_truncation(rng, data)
    if len(cut) >= len(data):
        return
    with pytest.raises(MercuryError):
        arr = proc.decode(proc.proc_ndarray, cut)
        # the payload bytes sit at the tail, so any strict prefix of a
        # non-empty array body must underflow on p.read
        if arr.nbytes == a.nbytes:
            raise MercuryError(0, "decoded full array from a prefix")


@cases(40)
def test_scalar_procs_reject_truncation(rng):
    encoders = [(proc.proc_varint, int(rng.integers(128, 2**62))),
                (proc.proc_int64, int(rng.integers(-2**63, 2**63 - 1))),
                (proc.proc_float64, float(rng.standard_normal())),
                (proc.proc_str, "truncate-me-" + "x" * int(rng.integers(1, 9))),
                (proc.proc_bytes, b"\x01\x02\x03\x04\x05")]
    p, v = encoders[int(rng.integers(len(encoders)))]
    data = proc.encode(p, v)
    cut = draw_truncation(rng, data)
    if len(cut) == len(data):
        return
    with pytest.raises(MercuryError):
        out = proc.decode(p, cut)
        if out == v:                 # a shorter varint prefix may decode;
            raise MercuryError(0, "")  # equality from a prefix is the bug
