"""Sharded control plane (DESIGN.md §12): shard-map properties
(cross-process stability, balance, minimal movement under growth),
cross-shard routing and ``fab.services`` merge, per-shard
``(nonce, epoch)`` read-cache tokens, the launcher's co-hosted shard
mode, and the shard-isolation chaos test (leaseholder kill on shard 0
must be invisible to shard 1)."""
import os
import subprocess
import sys
import threading
import time

import pytest

from conftest import poll_until
from proptest import cases
from repro.core.executor import Engine
from repro.fabric import (RegistryClient, RegistryService, ServiceInstance,
                          ServicePool)
from repro.fabric.sharding import (ShardedRegistryClient, membership_home,
                                   parse_shard_spec, registry_client_for,
                                   shard_addr, shard_of)

LEASE = 0.5
GOSSIP = 0.12
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# shard map properties (pure)
# ---------------------------------------------------------------------------
def test_shard_of_stability_across_processes():
    """Same name -> same shard from a different interpreter: the map
    must not lean on anything process-local (PYTHONHASHSEED, import
    order, id())."""
    names = [f"svc-{i}" for i in range(40)] + ["a", "trainer/emb", "日本語"]
    local = [shard_of(n, 4) for n in names]
    env = dict(os.environ, PYTHONPATH=_SRC, PYTHONHASHSEED="12345")
    prog = ("import sys\n"
            "from repro.fabric.sharding import shard_of\n"
            "names = sys.stdin.read().splitlines()\n"
            "print(' '.join(str(shard_of(n, 4)) for n in names))\n")
    out = subprocess.run([sys.executable, "-c", prog],
                         input="\n".join(names), capture_output=True,
                         text=True, env=env, check=True).stdout
    assert [int(x) for x in out.split()] == local


@cases(n=5, seed=11)
def test_shard_of_balance(rng):
    """10k random names land within ±20% of uniform at M=4."""
    names = [bytes(rng.integers(97, 123, size=12)).decode()
             + str(int(rng.integers(0, 10**9))) for _ in range(10_000)]
    counts = [0, 0, 0, 0]
    for n in names:
        counts[shard_of(n, 4)] += 1
    for c in counts:
        assert abs(c - 2500) <= 500, f"imbalanced shards: {counts}"


@cases(n=5, seed=12)
def test_shard_of_minimal_movement(rng):
    """Growing the map M -> M+1 remaps ~1/(M+1) of names, and every
    remapped name moves TO the new shard (rendezvous monotonicity) —
    never between surviving shards."""
    names = [f"n{int(rng.integers(0, 10**12))}-{i}" for i in range(2000)]
    for m in (2, 3, 4, 7):
        before = [shard_of(n, m) for n in names]
        after = [shard_of(n, m + 1) for n in names]
        moved = [i for i in range(len(names)) if before[i] != after[i]]
        assert all(after[i] == m for i in moved), \
            f"M={m}: a name moved between surviving shards"
        frac = len(moved) / len(names)
        assert frac <= 1.0 / (m + 1) + 0.05, \
            f"M={m}: {frac:.1%} of names moved (expected ~{1/(m+1):.1%})"


def test_shard_of_single_shard_and_errors():
    assert shard_of("anything", 1) == 0
    assert shard_of("anything", ["tcp://a:1"]) == 0
    with pytest.raises(ValueError):
        shard_of("x", 0)


def test_parse_spec_membership_home_and_shard_addr():
    spec = "tcp://a:1,tcp://b:1 | tcp://a:2"
    assert parse_shard_spec(spec) == ["tcp://a:1,tcp://b:1", "tcp://a:2"]
    # membership rides shard 0; unsharded specs pass through untouched
    assert membership_home(spec) == "tcp://a:1,tcp://b:1"
    assert membership_home("tcp://a:1,tcp://b:1") == "tcp://a:1,tcp://b:1"
    assert membership_home(["tcp://a:1", "tcp://b:1"]) == \
        ["tcp://a:1", "tcp://b:1"]
    # co-hosting offset convention: port + k, name suffix for portless
    assert shard_addr("tcp://10.0.0.1:7700", 3) == "tcp://10.0.0.1:7703"
    assert shard_addr("tcp://h:7700;sm://ctrl", 1) == "tcp://h:7701;sm://ctrl-1"
    assert shard_addr("sm://ctrl", 0) == "sm://ctrl"
    with pytest.raises(ValueError):
        parse_shard_spec("|")


# ---------------------------------------------------------------------------
# sharded client over live shards
# ---------------------------------------------------------------------------
def _mk_shards(m, **kw):
    """m single-node registry shards (each its own ReplicationCore
    leaseholder) plus the '|'-joined client spec."""
    engines = [Engine("tcp://127.0.0.1:0") for _ in range(m)]
    regs = [RegistryService(e, sweep_interval=0.1, **kw) for e in engines]
    return engines, regs, "|".join(e.uri for e in engines)


def _owned_by(client, shard, prefix="own"):
    """A service name owned by ``shard`` under ``client``'s map."""
    for i in range(10_000):
        name = f"{prefix}-{i}"
        if client.shard_of(name) == shard:
            return name
    raise AssertionError(f"no name owned by shard {shard}?!")


def test_cross_shard_routing_and_services_merge():
    engines, regs, spec = _mk_shards(2, instance_ttl=30.0)
    cli = Engine("tcp://127.0.0.1:0")
    try:
        c = ShardedRegistryClient(cli, spec, timeout=5.0)
        names = [f"merge-{i}" for i in range(12)]
        for n in names:
            c.register(n, ["tcp://10.0.0.1:1"])
        # every name landed on exactly its owning shard
        per_shard = [RegistryClient(cli, e.uri, timeout=5.0)
                     for e in engines]
        for n in names:
            owner = c.shard_of(n)
            for k, direct in enumerate(per_shard):
                got = len(direct.resolve(n)["instances"])
                assert got == (1 if k == owner else 0), \
                    f"{n} visible on shard {k} (owner {owner})"
        # both shards actually own something (the map spreads names)
        owners = {c.shard_of(n) for n in names}
        assert owners == {0, 1}
        # fab.services: sorted union across shards, and a strict
        # superset of any single shard's slice
        merged = c.services()
        assert merged == sorted(names)
        for direct in per_shard:
            slice_ = direct.services()
            assert set(slice_) < set(merged)
        # per-shard epochs/nonces are independent authorities
        infos = c.epoch_info()
        assert len(infos) == 2 and infos[0][1] != infos[1][1]
        assert len(c.status()["shards"]) == 2
    finally:
        for r in regs:
            r.close()
        for e in engines + [cli]:
            e.shutdown()


def test_pool_and_service_instance_route_through_sharded_spec():
    """ServicePool + ServiceInstance take the '|' spec unchanged: both
    bind to the owning shard and the data path works end to end."""
    engines, regs, spec = _mk_shards(2, instance_ttl=30.0)
    cli = Engine("tcp://127.0.0.1:0")
    worker = Engine("tcp://127.0.0.1:0", handler_threads=2)
    worker.register("echo", lambda x: x)
    inst = pool = None
    try:
        svc = _owned_by(ShardedRegistryClient(cli, spec), 1, "pooled")
        inst = ServiceInstance(worker, spec, svc, report_interval=0.1)
        # the reporter bound to the owning shard's quorum
        assert inst.client.uris == [engines[1].uri]
        pool = ServicePool(cli, spec, svc, refresh_interval=0.2)
        assert pool.registry.uris == [engines[1].uri]
        poll_until(lambda: pool.replicas(), msg="pool sees the instance")
        assert pool.call("echo", b"hi", timeout=5.0) == b"hi"
        # registry_client_for: plain client for unsharded specs, owner
        # binding with service=, fan-out client without
        assert isinstance(registry_client_for(cli, engines[0].uri),
                          RegistryClient)
        assert isinstance(registry_client_for(cli, spec),
                          ShardedRegistryClient)
        bound = registry_client_for(cli, spec, service=svc)
        assert isinstance(bound, RegistryClient)
        assert bound.uris == [engines[1].uri]
    finally:
        if pool:
            pool.close()
        if inst:
            inst.close()
        for r in regs:
            r.close()
        for e in engines + [cli, worker]:
            e.shutdown()


def _resolve_counter(engine):
    """Count server-side fab.resolve executions on ``engine``."""
    rec = engine.hg._by_name["fab.resolve"]
    inner = rec.handler
    hits = [0]

    def counting(arg):
        hits[0] += 1
        return inner(arg)

    rec.handler = counting
    return hits


def test_per_shard_tokens_restart_evicts_only_that_shard():
    """A restart (fresh nonce) on shard 1 must evict shard 1's cached
    reads only: shard 0 keeps serving from cache with zero round-trips,
    shard 1 refuses to serve the superseded epoch stream (§12 token
    rules: never compare epochs across shards)."""
    engines, regs, spec = _mk_shards(2, instance_ttl=30.0)
    cli = Engine("tcp://127.0.0.1:0")
    try:
        c = ShardedRegistryClient(cli, spec, timeout=5.0, cache_ttl=30.0)
        svc0, svc1 = _owned_by(c, 0, "tok"), _owned_by(c, 1, "tok")
        c.register(svc0, ["tcp://10.0.0.1:1"])
        c.register(svc1, ["tcp://10.0.0.1:2"])
        hits0 = _resolve_counter(engines[0])
        assert len(c.resolve(svc0)["instances"]) == 1    # fill shard-0 cache
        assert len(c.resolve(svc1)["instances"]) == 1    # fill shard-1 cache
        assert c.resolve(svc0)["instances"] and hits0[0] == 1
        tok0 = c.clients[0].cache.token()
        tok1 = c.clients[1].cache.token()
        assert tok0[0] != tok1[0]                        # independent nonces

        # shard 1 restarts cold on the same address: new core nonce,
        # empty table, epochs restart — the classic stale-token trap
        uri1 = engines[1].uri
        regs[1].close()
        engines[1].shutdown()
        engines[1] = Engine(uri1)
        regs[1] = RegistryService(engines[1], sweep_interval=0.1,
                                  instance_ttl=30.0)

        # an authoritative shard-1 read reconnects, sees the fresh
        # nonce and evicts — after which even plain (cache-eligible)
        # reads serve the new empty authority, never the cached ghost
        view1 = poll_until(
            lambda: _try_resolve(c, svc1, fresh=True), timeout=10.0,
            msg="shard-1 client reconnect")
        assert view1["instances"] == []
        assert c.resolve(svc1)["instances"] == []
        assert c.clients[1].cache.token()[0] != tok1[0]
        # shard 0 was untouched: same token, still cache-served
        assert c.clients[0].cache.token() == tok0
        assert len(c.resolve(svc0)["instances"]) == 1
        assert hits0[0] == 1, "shard-1 restart cross-evicted shard 0"
    finally:
        for r in regs:
            r.close()
        for e in engines + [cli]:
            e.shutdown()


def _try_resolve(client, service, fresh=False):
    try:
        return client.resolve(service, fresh=fresh)
    except Exception:
        return None


# ---------------------------------------------------------------------------
# launcher: co-hosted shards
# ---------------------------------------------------------------------------
def test_launch_registry_cohosts_shards():
    import socket
    socks = []
    try:
        for _ in range(4):   # grab a base with base+1 free alongside
            s = socket.socket()
            s.bind(("127.0.0.1", 0))
            socks.append(s)
        base = max(s.getsockname()[1] for s in socks) + 7
    finally:
        for s in socks:
            s.close()
    env = dict(os.environ, PYTHONPATH=_SRC)
    p = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.registry",
         "--listen", f"tcp://127.0.0.1:{base}", "--shards", "2",
         "--instance-ttl", "30"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    cli = Engine("tcp://127.0.0.1:0")
    try:
        spec = f"tcp://127.0.0.1:{base}|tcp://127.0.0.1:{base + 1}"
        c = ShardedRegistryClient(cli, spec, timeout=2.0)
        for shard in c.clients:
            poll_until(lambda s=shard: _reachable(s), timeout=15.0,
                       msg="co-hosted shard up")
        svc0, svc1 = _owned_by(c, 0, "co"), _owned_by(c, 1, "co")
        c.register(svc0, ["tcp://10.0.0.1:1"])
        c.register(svc1, ["tcp://10.0.0.1:2"])
        assert c.services() == sorted([svc0, svc1])
        infos = c.epoch_info(fresh=True)
        assert infos[0][1] != infos[1][1]
    finally:
        cli.shutdown()
        p.terminate()
        p.wait(timeout=10)


def _reachable(client):
    try:
        client.epoch(fresh=True)
        return True
    except Exception:
        return False


# ---------------------------------------------------------------------------
# chaos: shard-isolated failover
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_shard0_leaseholder_kill_is_invisible_to_shard1():
    """Kill shard 0's leaseholder under concurrent register/resolve
    load on both shards: shard 1 sees ZERO write or resolve errors and
    keeps making progress during the outage; shard 0 elects a new
    leaseholder within ~one lease TTL and heals (extends the PR-4/5
    failover tests to the sharded topology)."""
    shard_engines, shard_regs = [], []
    for _ in range(2):                       # two 3-replica quorums
        engines = [Engine("tcp://127.0.0.1:0") for _ in range(3)]
        peers = [e.uri for e in engines]
        regs = [RegistryService(e, peers=peers, lease_ttl=LEASE,
                                gossip_interval=GOSSIP, sweep_interval=0.1,
                                instance_ttl=30.0)
                for e in engines]
        shard_engines.append(engines)
        shard_regs.append(regs)
    spec = "|".join(",".join(e.uri for e in engines)
                    for engines in shard_engines)
    cli = Engine("tcp://127.0.0.1:0")
    stop = threading.Event()
    threads = []
    try:
        for regs in shard_regs:
            poll_until(lambda r=regs: r[0].is_leader,
                       msg="initial shard leadership")
        probe = ShardedRegistryClient(cli, spec, timeout=5.0)
        svc = [_owned_by(probe, k, "chaos") for k in range(2)]

        errors = {0: [], 1: []}
        progress = {0: [0], 1: [0]}
        lock = threading.Lock()

        def drive(shard):
            c = ShardedRegistryClient(cli, spec, timeout=5.0)
            i = 0
            while not stop.is_set():
                try:
                    c.register(svc[shard], [f"tcp://10.0.0.1:{i}"],
                               iid=f"i{shard}-{i % 8}")
                    c.resolve(svc[shard], fresh=True)
                    with lock:
                        progress[shard][0] += 1
                except Exception as e:  # noqa: BLE001 — the assertion
                    with lock:
                        errors[shard].append(repr(e))
                i += 1

        threads = [threading.Thread(target=drive, args=(k,), daemon=True)
                   for k in (0, 0, 1, 1)]
        for t in threads:
            t.start()
        poll_until(lambda: progress[0][0] > 5 and progress[1][0] > 5,
                   msg="drivers warmed up on both shards")

        # abrupt leaseholder kill on shard 0 (no deregistration: peers
        # learn via lease expiry only)
        regs0, engines0 = shard_regs[0], shard_engines[0]
        leader = next(i for i, r in enumerate(regs0) if r.is_leader)
        base1 = progress[1][0]
        regs0[leader].close()
        engines0[leader].shutdown()
        t_kill = time.monotonic()

        survivor = regs0[(leader + 1) % 3], regs0[(leader + 2) % 3]
        poll_until(lambda: any(r.is_leader for r in survivor),
                   timeout=LEASE + 2.0, msg="shard-0 lease takeover")
        takeover_s = time.monotonic() - t_kill
        # shard 1 kept working *during* the shard-0 outage
        poll_until(lambda: progress[1][0] > base1 + 5,
                   msg="shard-1 progress during shard-0 outage")
        # shard 0 heals: writes land on the new leaseholder
        poll_until(lambda: not errors[1] and _chaos_write_ok(cli, spec,
                                                             svc[0]),
                   timeout=LEASE + 3.0, msg="shard-0 post-takeover write")
        stop.set()
        for t in threads:
            t.join(timeout=10.0)

        assert errors[1] == [], \
            f"shard-0 kill leaked {len(errors[1])} errors into shard 1: " \
            f"{errors[1][:3]}"
        # "within one lease TTL" + scheduling slack (same bound as the
        # unsharded PR-4/5 failover tests use)
        assert takeover_s < LEASE + 2.0, \
            f"shard-0 takeover took {takeover_s:.2f}s"
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=5.0)
        for regs in shard_regs:
            for r in regs:
                r.close()
        for engines in shard_engines:
            for e in engines:
                try:
                    e.shutdown()
                except Exception:
                    pass
        cli.shutdown()


def _chaos_write_ok(cli, spec, service):
    try:
        c = ShardedRegistryClient(cli, spec, timeout=2.0)
        c.register(service, ["tcp://10.0.0.1:999"], iid="post-kill")
        return len(c.resolve(service, fresh=True)["instances"]) > 0
    except Exception:
        return False
