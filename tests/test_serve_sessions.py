"""Session-affine serving data path: chunked prefill correctness (incl.
EOS mid-chunk), KV-session pinning/resume/eviction edge cases, the
gateway's occupancy-aware load signal, and the client-side soft-affinity
layer (prefer_instance + SessionAffinity)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.fabric import SessionAffinity
from repro.fabric.balancer import prefer_instance
from repro.models import Model, unzip
from repro.serve.engine import ServeEngine
from repro.services import ServingGateway

CFG = configs.reduced("qwen1.5-0.5b").replace(compute_dtype="float32")


@pytest.fixture(scope="module")
def model_and_params():
    m = Model(CFG)
    params, _ = unzip(m.init(jax.random.PRNGKey(0)))
    return m, params


def make_engine(m, params, **kw):
    # fp32 cache: chunked-vs-monolithic parity must not hinge on bf16
    # rounding of the cached K/V
    kw.setdefault("cache_dtype", jnp.float32)
    kw.setdefault("max_len", 64)
    return ServeEngine(m, params, **kw)


# ---------------------------------------------------------------- chunked
def test_chunked_prefill_matches_monolithic(model_and_params):
    """A prompt prefilled in fixed-size chunks (last chunk padded) must
    decode exactly the tokens of one monolithic prefill pass."""
    m, params = model_and_params
    prompt = np.arange(1, 20)              # 19 tokens: 3 chunks, pad 5
    mono = make_engine(m, params, n_slots=2)
    want = mono.generate([prompt], max_new=8)[0]

    chunked = make_engine(m, params, n_slots=2, chunk_tokens=8)
    got = chunked.generate([prompt], max_new=8)[0]
    assert got == want


def test_chunked_interleaves_with_decode(model_and_params):
    """Chunked prefill of one slot must not disturb decode of another:
    outputs equal the isolated single-slot run."""
    m, params = model_and_params
    p_a, p_b = np.arange(1, 7), np.arange(3, 25)
    alone = make_engine(m, params, n_slots=1, chunk_tokens=8)
    want_a = alone.generate([p_a], max_new=6)[0]
    want_b = alone.generate([p_b], max_new=6)[0]

    eng = make_engine(m, params, n_slots=2, chunk_tokens=8)
    ra = eng.submit(p_a, max_new=6)
    rb = eng.submit(p_b, max_new=6)
    eng.drain()
    assert ra.out_tokens == want_a
    assert rb.out_tokens == want_b


def test_eos_on_chunked_prefill_first_token(model_and_params):
    """EOS sampled from the *prefill* chunk itself (first emitted token)
    must finish the request immediately and free the slot."""
    m, params = model_and_params
    prompt = np.arange(1, 20)
    probe = make_engine(m, params, n_slots=1, chunk_tokens=8)
    toks = probe.generate([prompt], max_new=4)[0]

    eng = make_engine(m, params, n_slots=1, chunk_tokens=8)
    req = eng.submit(prompt, max_new=4, eos_id=toks[0])
    eng.drain()
    assert req.out_tokens == toks[:1]
    assert req.done_event.is_set()
    assert eng.stats()["active_slots"] == 0
    # the freed slot is immediately reusable for a full generation
    assert eng.generate([prompt], max_new=4)[0] == toks


def test_eos_mid_decode_after_chunked_prefill(model_and_params):
    m, params = model_and_params
    prompt = np.arange(1, 20)
    probe = make_engine(m, params, n_slots=1, chunk_tokens=8)
    toks = probe.generate([prompt], max_new=6)[0]
    # the emitted token whose FIRST occurrence is latest: maximizes the
    # chance the EOS cut lands mid-decode, whatever the tiny random
    # model happens to emit
    eos = max(set(toks), key=toks.index)
    k = toks.index(eos)

    eng = make_engine(m, params, n_slots=1, chunk_tokens=8)
    req = eng.submit(prompt, max_new=6, eos_id=eos)
    eng.drain()
    assert req.out_tokens == toks[:k + 1]


# ---------------------------------------------------------------- sessions
def test_session_resume_matches_fresh_prefill(model_and_params):
    """A follow-up turn resumed from pinned KV (suffix-only prefill)
    must produce exactly the tokens of a from-scratch prefill."""
    m, params = model_and_params
    prompt = np.arange(1, 21)
    eng = make_engine(m, params, n_slots=2, chunk_tokens=8, session_cap=4)
    turn1 = eng.generate([prompt], max_new=4, session_ids=["conv"])[0]
    follow = np.concatenate([prompt, np.asarray(turn1, np.int32),
                             np.asarray([7, 9], np.int32)])

    fresh = make_engine(m, params, n_slots=2, chunk_tokens=8)
    want = fresh.generate([follow], max_new=4)[0]

    got = eng.generate([follow], max_new=4, session_ids=["conv"])[0]
    assert got == want
    st = eng.stats()
    assert st["prefix_hits"] == 1
    # everything up to the last emitted token of turn 1 was reused
    assert st["prefix_tokens_saved"] == len(prompt) + len(turn1) - 1


def test_stale_prefix_misses_and_recovers(model_and_params):
    """A follow-up whose prompt does NOT extend the cached history must
    evict the stale session and full-prefill — correctness never depends
    on the cache."""
    m, params = model_and_params
    eng = make_engine(m, params, n_slots=2, chunk_tokens=8, session_cap=4)
    eng.generate([np.arange(1, 21)], max_new=4, session_ids=["conv"])

    other = np.arange(5, 30)               # unrelated prompt, same sid
    fresh = make_engine(m, params, n_slots=2, chunk_tokens=8)
    want = fresh.generate([other], max_new=4)[0]
    got = eng.generate([other], max_new=4, session_ids=["conv"])[0]
    assert got == want
    st = eng.stats()
    assert st["prefix_hits"] == 0
    assert st["prefix_misses"] == 2        # both turns missed
    assert st["session_evictions"] == 1    # the stale pin was dropped


def test_eviction_racing_follow_up(model_and_params):
    """A follow-up arriving after its session was LRU-evicted (slot
    pressure from fresh conversations) degrades to a miss + full
    prefill with identical output."""
    m, params = model_and_params
    eng = make_engine(m, params, n_slots=2, chunk_tokens=8, session_cap=2)
    prompt = np.arange(1, 15)
    t1 = eng.generate([prompt], max_new=3, session_ids=["victim"])[0]
    # flood: enough fresh sessions to evict "victim" from both the
    # 2-entry table and its slot
    for i in range(3):
        eng.generate([np.arange(2 + i, 20 + i)], max_new=3,
                     session_ids=[f"flood{i}"])
    assert "victim" not in eng.sessions
    follow = np.concatenate([prompt, np.asarray(t1, np.int32),
                             np.asarray([4], np.int32)])
    fresh = make_engine(m, params, n_slots=2, chunk_tokens=8)
    want = fresh.generate([follow], max_new=3)[0]
    hits_before = eng.stats()["prefix_hits"]
    got = eng.generate([follow], max_new=3, session_ids=["victim"])[0]
    assert got == want
    assert eng.stats()["prefix_hits"] == hits_before   # no phantom hit


def test_all_slots_pinned_no_starvation(model_and_params):
    """Every slot pinned by an idle session must not starve fresh
    requests: the LRU pin is evicted and the request runs."""
    m, params = model_and_params
    eng = make_engine(m, params, n_slots=2, chunk_tokens=8, session_cap=4)
    eng.generate([np.arange(1, 10), np.arange(2, 12)], max_new=3,
                 session_ids=["a", "b"])
    st = eng.stats()
    assert st["pinned_sessions"] == 2 and st["active_slots"] == 0

    fresh_prompt = np.arange(4, 18)
    req = eng.submit(fresh_prompt, max_new=3)
    eng.drain()
    assert len(req.out_tokens) == 3
    # LRU ("a", the older pin) was sacrificed; "b" survived
    assert "a" not in eng.sessions and "b" in eng.sessions


def test_drain_with_pinned_sessions_terminates(model_and_params):
    """Pinned sessions hold no slot_req: drain() must return with
    sessions still resident (a pinned engine is an idle engine)."""
    m, params = model_and_params
    eng = make_engine(m, params, n_slots=2, chunk_tokens=8, session_cap=4)
    eng.generate([np.arange(1, 10)], max_new=3, session_ids=["keep"])
    eng.drain()                            # must not spin forever
    st = eng.stats()
    assert st["pinned_sessions"] == 1
    assert st["active_slots"] == 0 and st["occupancy"] == 0.0
    # and the pin is still usable afterwards
    assert "keep" in eng.sessions


def test_sessions_disabled_on_unchunkable_model(model_and_params,
                                                monkeypatch):
    """chunk_tokens/session_cap are silently ignored when the model
    cannot continue prefill at an offset — the engine falls back to
    monolithic prefill and stateless serving."""
    m, params = model_and_params
    monkeypatch.setattr(type(m), "supports_chunked_prefill",
                        property(lambda self: False))
    eng = ServeEngine(m, params, max_len=64, n_slots=2,
                      chunk_tokens=8, session_cap=4,
                      cache_dtype=jnp.float32)
    assert eng.chunk == 0 and eng.session_cap == 0
    out = eng.generate([np.arange(1, 8)], max_new=3, session_ids=["x"])[0]
    assert len(out) == 3
    st = eng.stats()
    assert st["pinned_sessions"] == 0
    assert st["prefix_hits"] == 0 and st["prefix_misses"] == 0


# ---------------------------------------------------------------- gateway
class _StubServe:
    """Just enough ServeEngine surface for ServingGateway._load."""
    def __init__(self, active, queued, pinned):
        self._s = {"active_slots": active, "queued": queued,
                   "pinned_sessions": pinned}

    def stats(self):
        return dict(self._s)


def test_gateway_load_counts_occupancy():
    """Regression: a gateway with a full batch and an empty queue must
    not report near-idle — active slots dominate the balancing signal."""
    gw = ServingGateway.__new__(ServingGateway)   # formula-only unit test
    gw.serve = _StubServe(active=4, queued=0, pinned=0)
    busy = ServingGateway._load(gw)
    gw.serve = _StubServe(active=0, queued=0, pinned=0)
    idle = ServingGateway._load(gw)
    assert idle == 0.0
    assert busy >= 4.0, \
        "full batch with empty queue reported as near-idle"


def test_gateway_load_weights_pinned_sessions():
    """Pinned sessions hold no slot_req but admitting there costs an
    eviction: they must raise load, at less than a live slot's weight."""
    gw = ServingGateway.__new__(ServingGateway)
    gw.serve = _StubServe(active=0, queued=0, pinned=4)
    pinned = ServingGateway._load(gw)
    gw.serve = _StubServe(active=4, queued=0, pinned=0)
    active = ServingGateway._load(gw)
    assert 0.0 < pinned < active


# ---------------------------------------------------------------- affinity
class _Rep:
    def __init__(self, iid):
        self.iid = iid


def test_prefer_instance_ordering():
    ranked = [_Rep("a"), _Rep("b"), _Rep("c")]
    assert prefer_instance(ranked, None) is ranked
    out = prefer_instance(ranked, "b")
    assert [r.iid for r in out] == ["b", "a", "c"]
    # unknown iid: ranking untouched (dead/evicted replica fallback)
    assert [r.iid for r in prefer_instance(ranked, "zz")] == ["a", "b", "c"]
    assert prefer_instance([], "a") == []


class _FakePool:
    """Scripted pool: serves from ``homes`` (prefer honored only when
    still listed), recording what prefer= each call carried."""
    def __init__(self, default_iid):
        self.default = default_iid
        self.live = {default_iid}
        self.prefers = []

    def call_routed(self, rpc, arg=None, prefer=None, **kw):
        self.prefers.append(prefer)
        iid = prefer if prefer in self.live else self.default
        return {"ok": True}, iid


def test_session_affinity_hit_miss_move():
    pool = _FakePool("r1")
    aff = SessionAffinity(pool)
    _, iid = aff.call_routed("s1", "gen.generate", {})
    assert iid == "r1" and aff.misses == 1          # first turn: no map
    _, iid = aff.call_routed("s1", "gen.generate", {})
    assert iid == "r1" and aff.hits == 1
    assert pool.prefers == [None, "r1"]

    # preferred replica dies: the call lands elsewhere and the session
    # is re-homed (a move, not an error)
    pool.default = "r2"
    pool.live = {"r2"}
    _, iid = aff.call_routed("s1", "gen.generate", {})
    assert iid == "r2" and aff.moves == 1
    assert aff.lookup("s1") == "r2"

    aff.forget("s1")
    assert aff.lookup("s1") is None
    st = aff.stats()
    assert (st["hits"], st["misses"], st["moves"]) == (1, 1, 1)


def test_session_affinity_lru_capacity():
    pool = _FakePool("r1")
    aff = SessionAffinity(pool, capacity=2)
    for sid in ("a", "b", "c"):
        aff.call_routed(sid, "gen.generate", {})
    assert aff.lookup("a") is None                  # LRU-dropped
    assert aff.lookup("b") == "r1" and aff.lookup("c") == "r1"
