"""tcp plugin edge cases: partial/dribbled socket reads, peer disconnect
mid-RPC, and cancellation of in-flight expected receives — the failure
paths a real DCN transport hits that the happy-path suites never touch."""
import socket
import struct
import time

import pytest

from repro.core.executor import Engine, RemoteError
from repro.core.na import TCPPlugin
from repro.core.types import Ret

_FRAME_HDR = struct.Struct("<IB")
_TAG = struct.Struct("<Q")
K_HELLO = 0
K_UNEXP = 1


def spin(plugins, cond, timeout=10.0):
    deadline = time.time() + timeout
    while not cond() and time.time() < deadline:
        for p in plugins:
            p.progress(0.005)
    assert cond(), "condition not met within timeout"


def _frames(kind: int, payload: bytes) -> bytes:
    return _FRAME_HDR.pack(len(payload) + 1, kind) + payload


def test_partial_socket_reads():
    """A frame dribbled in 1-byte chunks must still assemble correctly."""
    p = TCPPlugin(None, listen=True)
    try:
        host, port = p.addr_self().uri[len("tcp://"):].rsplit(":", 1)
        got = {}
        p.msg_recv_unexpected(
            lambda ret, src, tag, data: got.update(tag=tag, data=bytes(data)))

        s = socket.create_connection((host, int(port)))
        wire = _frames(K_HELLO, b"tcp://1.2.3.4:9") + \
            _frames(K_UNEXP, _TAG.pack(42) + b"dribbled-payload")
        for i in range(len(wire)):           # one byte at a time
            s.sendall(wire[i:i + 1])
            p.progress(0.001)
        spin([p], lambda: "data" in got)
        assert got["tag"] == 42 and got["data"] == b"dribbled-payload"
        s.close()
    finally:
        p.finalize()


def test_partial_frame_then_disconnect():
    """A connection dying mid-frame must not crash or deliver garbage."""
    p = TCPPlugin(None, listen=True)
    try:
        host, port = p.addr_self().uri[len("tcp://"):].rsplit(":", 1)
        got = []
        p.msg_recv_unexpected(lambda ret, src, tag, data: got.append(data))

        s = socket.create_connection((host, int(port)))
        full = _frames(K_UNEXP, _TAG.pack(1) + b"never-completes")
        s.sendall(full[:len(full) // 2])     # half a frame, then vanish
        for _ in range(10):
            p.progress(0.005)
        s.close()
        for _ in range(10):
            p.progress(0.005)
        assert got == []
    finally:
        p.finalize()


def test_oversized_frame_disconnects_peer():
    """A frame header advertising > MAX_FRAME is a protocol error: the
    connection is dropped rather than the buffer allocated."""
    from repro.core.na.tcp import MAX_FRAME
    p = TCPPlugin(None, listen=True)
    try:
        host, port = p.addr_self().uri[len("tcp://"):].rsplit(":", 1)
        s = socket.create_connection((host, int(port)))
        s.sendall(_FRAME_HDR.pack(MAX_FRAME + 1, K_UNEXP))
        deadline = time.time() + 5
        closed = False
        while time.time() < deadline and not closed:
            p.progress(0.01)
            try:
                s.settimeout(0.05)
                if s.recv(4096) == b"":
                    closed = True
            except socket.timeout:
                pass
            except OSError:
                closed = True
        assert closed
    finally:
        p.finalize()


def test_peer_disconnect_mid_rpc():
    """Server dies between request and response: the origin's pre-posted
    expected recv must fail with DISCONNECT, not hang until timeout."""
    srv = Engine("tcp://127.0.0.1:0")
    cli = Engine("tcp://127.0.0.1:0")
    try:
        import threading
        started = threading.Event()

        def stall(_x):
            started.set()
            time.sleep(30)           # never responds in time
            return None

        srv.register("stall", stall)
        fut = cli.call_async(srv.uri, "stall", None, timeout=25.0)
        assert started.wait(10.0)
        t0 = time.time()
        srv.shutdown()               # closes the connection mid-RPC
        with pytest.raises(RemoteError) as ei:
            fut.result(timeout=20.0)
        assert ei.value.ret == Ret.DISCONNECT
        assert time.time() - t0 < 10.0   # failed fast, not via timeout
    finally:
        cli.shutdown()
        srv.shutdown()


def test_cancel_inflight_expected_recv():
    """Cancel an armed expected recv while its message is in flight: the
    callback must not fire, and a later recv for the same tag still can."""
    a = TCPPlugin(None, listen=True)
    b = TCPPlugin(None, listen=True)
    try:
        addr_a = b.addr_lookup(a.addr_self().uri)
        addr_b = a.addr_lookup(b.addr_self().uri)
        fired = []
        op = b.msg_recv_expected(addr_a, 5, lambda *args: fired.append(args))
        for _ in range(5):           # let the post land in the progress loop
            b.progress(0.005)
        b.cancel(op)
        a.msg_send_expected(addr_b, b"in-flight", 5, lambda ret: None)
        for _ in range(20):
            a.progress(0.005)
            b.progress(0.005)
        assert not fired and op.canceled
        got = {}
        b.msg_recv_expected(None, 5, lambda ret, data: got.update(d=bytes(data)))
        spin([a, b], lambda: "d" in got)
        assert got["d"] == b"in-flight"
    finally:
        a.finalize()
        b.finalize()


def test_v4_peer_request_decodes_and_response_does_not_grow():
    """Cross-version wire compat: a v4 peer's 36-byte request header (no
    trace fields) must decode cleanly, dispatch, and be answered with the
    same 20-byte response layout the old peer expects — version byte
    echoed as 4, nothing appended."""
    from repro.core import proc as hg_proc
    from repro.core.types import (RESPONSE_HEADER_SIZE, Flags,
                                  RequestHeader, ResponseHeader,
                                  payload_crc32, stable_rpc_id)
    K_EXP = 2
    srv = Engine("tcp://127.0.0.1:0")
    try:
        srv.register("echo", lambda x: {"got": x})
        host, port = srv.uri[len("tcp://"):].rsplit(":", 1)
        s = socket.create_connection((host, int(port)))
        payload = bytes(hg_proc.encode(hg_proc.proc_any, [1, 2, 3]))
        hdr = RequestHeader(rpc_id=stable_rpc_id("echo"), cookie=77,
                            flags=Flags.CHECKSUM, payload_len=len(payload),
                            payload_crc=payload_crc32(payload),
                            budget_ms=5000, version=4)
        raw = hdr.pack()
        assert len(raw) == 36                    # legacy layout on the wire
        s.sendall(_frames(K_HELLO, b"tcp://v4-peer.test:1") +
                  _frames(K_UNEXP, _TAG.pack(77) + raw + payload))

        buf, rsp = b"", None
        s.settimeout(10.0)
        while rsp is None:
            chunk = s.recv(65536)
            assert chunk, "server dropped the v4 peer's connection"
            buf += chunk
            while len(buf) >= _FRAME_HDR.size:
                ln, kind = _FRAME_HDR.unpack_from(buf)
                if len(buf) < _FRAME_HDR.size + ln - 1:
                    break
                body = buf[_FRAME_HDR.size:_FRAME_HDR.size + ln - 1]
                buf = buf[_FRAME_HDR.size + ln - 1:]
                if kind == K_EXP:
                    assert _TAG.unpack_from(body)[0] == 77
                    rsp = body[_TAG.size:]
                    break
        out = ResponseHeader.unpack(rsp)
        assert out.version == 4                  # echoed, not upgraded
        assert out.cookie == 77 and out.ret == Ret.SUCCESS
        body = rsp[RESPONSE_HEADER_SIZE:]        # did not grow: 24B header
        assert len(body) == out.payload_len
        assert hg_proc.decode(hg_proc.proc_any, body) == {"got": [1, 2, 3]}
        s.close()
    finally:
        srv.shutdown()
