"""MoE invariants: dropless exactness, capacity-drop monotonicity,
weight normalization, aux-loss bounds, expert-parallel parity (SPMD run
in a subprocess with 8 host devices)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import moe as moe_mod
from repro.models.common import unzip

from proptest import cases

RNG = jax.random.PRNGKey(0)


def tiny_cfg(E=8, k=2, shared=0):
    from repro.configs.base import ModelConfig, MoEConfig
    return ModelConfig(d_model=32, d_ff=16, vocab=64,
                       moe=MoEConfig(num_experts=E, top_k=k,
                                     num_shared_experts=shared))


def dense_gather_oracle(cfg, params, x2d):
    """Reference: per-token gather of expert FFNs (no capacity)."""
    logits = x2d @ params["router"]
    w, idx, _ = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.moe.top_k), None, None
    w, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.moe.top_k)
    w = w / w.sum(-1, keepdims=True)
    y = jnp.zeros_like(x2d)
    for t in range(x2d.shape[0]):
        acc = jnp.zeros((x2d.shape[1],))
        for j in range(cfg.moe.top_k):
            e = idx[t, j]
            g = x2d[t] @ params["wi_gate"][e]
            u = x2d[t] @ params["wi_up"][e]
            acc = acc + w[t, j] * ((jax.nn.silu(g) * u) @ params["wo"][e])
        y = y.at[t].set(acc)
    return y


@cases(5)
def test_dropless_equals_dense_gather(rng):
    cfg = tiny_cfg()
    pp = moe_mod.moe_params(cfg, RNG, ("moe",))
    params, _ = unzip(pp)
    T = int(rng.integers(4, 24))
    x = jnp.asarray(rng.standard_normal((1, T, 32)), jnp.float32)
    cfgf = cfg.replace(compute_dtype="float32")
    y, aux = moe_mod.moe_apply(cfgf, params, x, dropless=True)
    want = dense_gather_oracle(cfgf, params, x[0])
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_capacity_monotone_drops():
    """Raising the capacity factor monotonically increases the number of
    tokens whose output matches the dropless reference; at high capacity
    the outputs are identical."""
    cfg = tiny_cfg().replace(compute_dtype="float32")
    params, _ = unzip(moe_mod.moe_params(cfg, RNG, ("moe",)))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 32))
    y_full, _ = moe_mod.moe_apply(cfg, params, x, dropless=True)

    def equal_rows(cf):
        y_cap, _ = moe_mod.moe_apply(cfg, params, x, capacity_factor=cf)
        return int(jnp.sum(jnp.all(jnp.abs(y_cap[0] - y_full[0]) < 1e-5,
                                   axis=-1)))

    counts = [equal_rows(cf) for cf in (0.25, 0.5, 1.0, 8.0)]
    assert counts == sorted(counts), counts
    assert counts[-1] == 64


def test_aux_losses_bounded():
    cfg = tiny_cfg().replace(compute_dtype="float32")
    params, _ = unzip(moe_mod.moe_params(cfg, RNG, ("moe",)))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    _, aux = moe_mod.moe_apply(cfg, params, x, dropless=True)
    # perfectly balanced load ⇒ lb = aux_coef; random ⇒ close to it
    assert 0.0 < float(aux["moe_lb"]) < 10 * cfg.moe.aux_coef
    assert float(aux["moe_z"]) >= 0.0


def test_padded_experts_masked():
    cfg = tiny_cfg(E=5, k=2).replace(compute_dtype="float32")
    params, _ = unzip(moe_mod.moe_params(cfg, RNG, ("moe",), e_pad=8))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 32))
    y, _ = moe_mod.moe_apply(cfg, params, x, dropless=True)
    # routing must never select padded experts 5..7
    logits = x[0] @ params["router"]
    logits = jnp.where(jnp.arange(8) >= 5, -1e30, logits)
    _, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    assert int(jnp.max(idx)) < 5
    assert np.all(np.isfinite(np.asarray(y)))


SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models import moe as moe_mod
    from repro.models.common import unzip

    cfg = ModelConfig(d_model=32, d_ff=16, vocab=64,
                      moe=MoEConfig(num_experts=8, top_k=2),
                      compute_dtype="float32")
    params, _ = unzip(moe_mod.moe_params(cfg, jax.random.PRNGKey(0), ("m",)))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    y_local, aux_local = moe_mod.moe_apply(cfg, params, x, dropless=True)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    spmd = moe_mod.MoESpmd(mesh=mesh, token_axes=("data",),
                           expert_axis="model")
    with mesh:
        y_spmd, aux_spmd = jax.jit(
            lambda p, xx: moe_mod.moe_apply(cfg, p, xx, spmd=spmd,
                                            dropless=True))(params, x)
    np.testing.assert_allclose(np.asarray(y_spmd), np.asarray(y_local),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_spmd["moe_lb"]),
                               float(aux_local["moe_lb"]), rtol=1e-3)
    print("SPMD_PARITY_OK")
""")


def test_expert_parallel_parity_spmd():
    """MoE over a real (2,4) device mesh == single-device math."""
    r = subprocess.run([sys.executable, "-c", SPMD_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       cwd=".")
    assert "SPMD_PARITY_OK" in r.stdout, r.stdout + r.stderr
