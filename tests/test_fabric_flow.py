"""Property tests for the credit gates (fixed + adaptive).

The adaptive-credit invariants pinned here (DESIGN.md §7):

  * the limit never leaves ``[min_credits, max_credits]``, whatever
    latency schedule / failure pattern the controller sees;
  * credit conservation: every release had a matching acquire and
    ``inflight == acquired - released`` at all times — including under
    hedge-cancel storms (concurrent acquires racing releases racing
    limit changes), and including when the limit shrinks below the
    in-flight count;
  * the control law moves the right way: consistently-fast completions
    grow the limit, consistently-slow ones (or hard failures) shrink it.
"""
import threading

import numpy as np
import pytest

from proptest import cases
from repro.fabric.flow import AdaptiveCreditGate, CreditGate


def test_fixed_gate_basics():
    g = CreditGate(2)
    assert g.try_acquire() and g.try_acquire()
    assert not g.try_acquire()
    assert g.inflight == 2 and g.available == 0
    g.release()
    assert g.try_acquire()
    g.release(), g.release()
    assert g.inflight == 0
    with pytest.raises(RuntimeError):
        g.release()                     # over-release is a bug, loudly


def test_adaptive_gate_grows_when_fast_shrinks_when_slow():
    g = AdaptiveCreditGate(4, min_credits=2, max_credits=32,
                           target_latency=0.1)
    for _ in range(200):                # far below target: additive growth
        g.record_latency(0.01)
    grown = g.credits
    assert grown > 4
    assert g.stats()["grown"] > 0
    # now the replica degrades: multiplicative decrease (rate-limited to
    # one shrink per EWMA window — feed spaced timestamps)
    t = 1000.0
    for i in range(64):
        t += 10.0
        g.record_latency(5.0, now=t)
    assert g.credits < grown
    assert g.credits >= 2               # never below min
    for i in range(64):
        t += 10.0
        g.record_failure(now=t)
    assert g.credits == 2               # floor holds


def test_adaptive_gate_auto_target_learns_base_latency():
    """No explicit target: the decaying-min base × headroom is the
    target, so a uniformly-fast replica still grows."""
    g = AdaptiveCreditGate(2, max_credits=16)   # target_latency=None
    for _ in range(100):
        g.record_latency(0.02)          # flat latency == base -> "fast"
    assert g.credits > 2
    st = g.stats()
    assert st["target_ms"] == pytest.approx(st["ema_ms"] * 2.0, rel=0.2)


def test_shrink_below_inflight_strands_nothing():
    """Limit dropping under the in-flight count must not break release
    accounting, and new acquires wait until occupancy drains."""
    g = AdaptiveCreditGate(8, min_credits=1, max_credits=8,
                           target_latency=0.01)
    for _ in range(8):
        assert g.try_acquire()
    t = 1000.0
    for i in range(32):                 # collapse the limit to 1
        t += 10.0
        g.record_failure(now=t)
    assert g.credits == 1 and g.inflight == 8
    assert not g.try_acquire()          # over the (new) limit
    for _ in range(8):
        g.release()                     # all in-flight still release fine
    assert g.inflight == 0
    assert g.try_acquire()              # and the single credit works
    g.release()
    st = g.stats()
    assert st["acquired"] == st["released"] == 9


@cases(n=30, seed=101)
def test_adaptive_limit_bounds_invariant(rng):
    """Random latency/failure schedule: the limit never leaves
    [min_credits, max_credits]."""
    lo = int(rng.integers(1, 4))
    hi = int(rng.integers(lo, lo + 12))
    g = AdaptiveCreditGate(int(rng.integers(lo, hi + 1)),
                           min_credits=lo, max_credits=hi,
                           target_latency=float(rng.uniform(0.01, 0.5)),
                           decrease=float(rng.uniform(0.3, 0.9)))
    t = 0.0
    for _ in range(400):
        t += float(rng.uniform(0.0, 1.0))
        if rng.random() < 0.2:
            g.record_failure(now=t)
        else:
            g.record_latency(float(rng.uniform(0.001, 1.0)), now=t)
        assert lo <= g.credits <= hi
        limit = g.stats()["limit"]
        assert lo - 1e-9 <= limit <= hi + 1e-9


@cases(n=8, seed=202)
def test_hedge_cancel_storm_conserves_credits(rng):
    """Hedge-cancel storm: many threads acquire, randomly 'cancel'
    (release immediately) or 'complete' (feed a latency then release),
    while the latency feed itself keeps moving the limit.  Total
    releases must equal total acquires and the gate must end empty."""
    g = AdaptiveCreditGate(int(rng.integers(2, 6)), min_credits=1,
                           max_credits=int(rng.integers(8, 24)),
                           target_latency=0.05)
    n_threads = int(rng.integers(3, 8))
    per_thread = 60
    seeds = [int(rng.integers(0, 2**31)) for _ in range(n_threads)]
    errors = []

    def storm(seed):
        r = np.random.default_rng(seed)
        try:
            for _ in range(per_thread):
                if not g.acquire(timeout=5.0):
                    errors.append("acquire timed out")
                    return
                if r.random() < 0.5:
                    # hedge loser: canceled, no latency sample
                    g.release()
                else:
                    # winner: latency feeds the controller, then release
                    g.record_latency(float(r.uniform(0.001, 0.2)))
                    g.release()
        except Exception as e:          # noqa: BLE001 — surfaced below
            errors.append(repr(e))

    threads = [threading.Thread(target=storm, args=(s,)) for s in seeds]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=30)
    assert not errors, errors
    st = g.stats()
    assert st["acquired"] == st["released"] == n_threads * per_thread
    assert st["inflight"] == 0 and g.inflight == 0
    assert g.min_credits <= g.credits <= g.max_credits


def test_hedged_reads_collapse_and_never_poison():
    """Hedge/read-cache interplay (DESIGN.md §9): a storm of concurrent
    idempotent reads collapses to one registry round-trip (the winner
    populates the cache exactly once); fetches that fail CANCELED — the
    hedged loser's fate — propagate to their waiters and never leave an
    entry behind; and the client's own write evicts immediately, so no
    read after it ever sees the pre-write view."""
    from repro.core.executor import Engine
    from repro.core.types import MercuryError, Ret
    from repro.fabric.registry import RegistryClient, RegistryService

    with Engine(None) as e:
        reg = RegistryService(e)
        try:
            client = RegistryClient(e, e.uri, cache_ttl=60.0)
            client.register("svc", ["self://inst-a"], iid="aaaaaaaaaaaa")

            # count true server-side resolves (registry round-trips)
            info = e.hg._by_name["fab.resolve"]
            orig_handler = info.handler
            served = [0]

            def counting(handle):
                served[0] += 1
                orig_handler(handle)

            info.handler = counting

            # phase A — collapse: warm once, then storm cached reads
            client.resolve("svc")
            warm = served[0]
            errors = []

            def read_storm():
                try:
                    for _ in range(50):
                        view = client.resolve("svc")
                        assert len(view["instances"]) == 1
                except Exception as err:    # noqa: BLE001 — surfaced below
                    errors.append(repr(err))

            threads = [threading.Thread(target=read_storm) for _ in range(8)]
            for th in threads:
                th.start()
            for th in threads:
                th.join(timeout=30)
            assert not errors, errors
            assert served[0] == warm        # exactly-once population held

            # phase B — canceled losers never poison: half the fetches
            # die CANCELED mid-flight (the hedge loser's error class)
            orig_call = client._caller.call
            flake = {"on": True}

            def flaky_call(name, req, seq=[0]):
                seq[0] += 1
                if flake["on"] and seq[0] % 2:
                    raise MercuryError(Ret.CANCELED, "hedge loser canceled")
                return orig_call(name, req)

            client._caller.call = flaky_call
            outcomes = {"ok": 0, "canceled": 0}
            lock = threading.Lock()

            def hedge_storm():
                for _ in range(20):
                    try:
                        view = client.resolve("svc", fresh=True)
                        assert len(view["instances"]) == 1
                        with lock:
                            outcomes["ok"] += 1
                    except MercuryError as err:
                        assert err.ret == Ret.CANCELED
                        with lock:
                            outcomes["canceled"] += 1

            threads = [threading.Thread(target=hedge_storm)
                       for _ in range(4)]
            for th in threads:
                th.start()
            for th in threads:
                th.join(timeout=30)
            assert outcomes["ok"] > 0 and outcomes["canceled"] > 0
            flake["on"] = False
            # whatever the storm left cached must be a winner's view
            assert len(client.resolve("svc")["instances"]) == 1

            # phase C — read-your-writes: our own register bumps the
            # epoch, which must evict instantly (TTL is 60s — only token
            # invalidation can explain the fresh view)
            client.register("svc", ["self://inst-b"], iid="bbbbbbbbbbbb")
            assert len(client.resolve("svc")["instances"]) == 2
        finally:
            reg.close()
