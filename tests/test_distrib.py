"""Distribution layer: sharding resolver rules, compressed collectives
(convergence parity), pipeline-parallel stage runner (device-mesh
subprocesses)."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from proptest import cases


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    """AbstractMesh: enough for spec resolution without devices."""
    from repro.distrib.sharding import abstract_mesh
    return abstract_mesh(shape, axes)


def test_spec_resolution_basics():
    from jax.sharding import PartitionSpec as PS
    from repro.distrib.sharding import DEFAULT_RULES, spec_for
    mesh = fake_mesh()
    # TP + FSDP weight
    s = spec_for((1024, 16, 64), ("embed", "heads", "head_dim"), mesh,
                 DEFAULT_RULES)
    assert s == PS("data", "model")
    # kv_heads=8 does not divide 16 -> replicated
    s = spec_for((1024, 8, 64), ("embed", "kv_heads", "head_dim"), mesh,
                 DEFAULT_RULES)
    assert s == PS("data")
    # vocab-parallel embedding
    s = spec_for((49155, 1536), ("vocab", "embed"), mesh, DEFAULT_RULES)
    assert s == PS(None, "data")        # 49155 odd -> vocab replicated!
    s = spec_for((151936, 1024), ("vocab", "embed"), mesh, DEFAULT_RULES)
    assert s == PS("model", "data")


def test_spec_multi_axis_and_fallback():
    from jax.sharding import PartitionSpec as PS
    from repro.distrib.sharding import DEFAULT_RULES, merge_rules, spec_for
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    s = spec_for((4096, 16384), ("embed", "mlp"), mesh, DEFAULT_RULES)
    assert s == PS(("pod", "data"), "model")
    # batch=1 cannot shard -> None; kv_seq spreads over (data, model)
    rules = merge_rules(DEFAULT_RULES, {"kv_seq": ("data", "model")})
    s = spec_for((1, 524288, 1, 256),
                 ("batch", "kv_seq", "kv_heads", "head_dim"), mesh, rules)
    assert s == PS(None, ("data", "model"))


def test_no_double_axis_use():
    from repro.distrib.sharding import DEFAULT_RULES, merge_rules, spec_for
    mesh = fake_mesh()
    rules = merge_rules(DEFAULT_RULES, {"a": ("model",), "b": ("model",)})
    s = spec_for((32, 32), ("a", "b"), mesh, rules)
    flat = [x for e in s if e for x in (e if isinstance(e, tuple) else (e,))]
    assert len(flat) == len(set(flat)) == 1


@cases(10)
def test_bytes_per_device_consistent(rng):
    import jax
    from repro.distrib.sharding import bytes_per_device
    mesh = fake_mesh((4, 4), ("data", "model"))
    d = int(rng.integers(1, 8)) * 16
    f = int(rng.integers(1, 8)) * 16
    tree = {"w": jax.ShapeDtypeStruct((d, f), np.dtype("float32"))}
    axes = {"w": ("embed", "mlp")}
    got = bytes_per_device(tree, axes, mesh)
    assert got == d * f * 4 // 16


QUANT_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as PS
    from jax.experimental.shard_map import shard_map
    from repro.distrib.collectives import compressed_psum

    mesh = jax.make_mesh((4,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))

    def f(x_l):
        out, err = compressed_psum(x_l[0], "data")
        return out[None], err[None]

    with mesh:
        out, err = jax.jit(shard_map(f, mesh=mesh, in_specs=(PS("data"),),
                                     out_specs=(PS("data"), PS("data")),
                                     check_rep=False))(x)
    want = np.asarray(x.mean(0))
    got = np.asarray(out[0])
    # int8 with a shared per-tensor scale: per-element error bounded by
    # scale/2 = max|x|/254 (relative-to-zero errors are meaningless)
    scale = np.abs(np.asarray(x)).max() / 127.0
    assert np.abs(got - want).max() <= scale * 0.75, \
        (np.abs(got - want).max(), scale)
    print("QUANT_OK", float(np.abs(got - want).max() / scale))

    # convergence parity: toy regression, compressed vs exact grads
    k = jax.random.PRNGKey(1)
    Xd = jax.random.normal(k, (4, 64, 8))
    wt = jax.random.normal(jax.random.PRNGKey(2), (8,))
    yd = jnp.einsum("dbi,i->db", Xd, wt)

    def loss_grad(w, X, y):
        pred = X @ w
        return X.T @ (pred - y) / y.size

    def step_exact(w):
        g = jnp.mean(jax.vmap(loss_grad, (None, 0, 0))(w, Xd, yd), 0)
        return w - 0.3 * g

    def step_comp(w, e):
        def f(X, y, err):
            g = loss_grad(w, X[0], y[0])
            out, new_err = compressed_psum(g + err[0], "data")
            return out[None], new_err[None]
        with mesh:
            g, e = shard_map(f, mesh=mesh,
                             in_specs=(PS("data"), PS("data"), PS("data")),
                             out_specs=(PS("data"), PS("data")),
                             check_rep=False)(Xd, yd, e)
        return w - 0.3 * g[0], e

    w1 = jnp.zeros(8); w2 = jnp.zeros(8); e = jnp.zeros((4, 8))
    for i in range(60):
        w1 = step_exact(w1)
        w2, e = step_comp(w2, e)
    d_exact = float(jnp.linalg.norm(w1 - wt))
    d_comp = float(jnp.linalg.norm(w2 - wt))
    assert d_comp < 0.05, (d_exact, d_comp)
    print("CONV_OK", d_exact, d_comp)
""")


def test_compressed_allreduce_and_convergence():
    r = subprocess.run([sys.executable, "-c", QUANT_SCRIPT],
                       capture_output=True, text=True, timeout=300, cwd=".")
    assert "QUANT_OK" in r.stdout and "CONV_OK" in r.stdout, \
        r.stdout + r.stderr


PIPE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.distrib.pipeline import pipeline_apply

    mesh = jax.make_mesh((4,), ("stage",))
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    Ws = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

    def stage_fn(W, h):
        return jnp.tanh(h @ W)

    with mesh:
        out = jax.jit(lambda W, xx: pipeline_apply(
            stage_fn, W, xx, mesh, stage_axis="stage"))(Ws, x)

    want = x
    for s in range(n_stages):
        want = jnp.tanh(want @ Ws[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print("PIPE_OK")
""")


def test_pipeline_stage_runner():
    r = subprocess.run([sys.executable, "-c", PIPE_SCRIPT],
                       capture_output=True, text=True, timeout=300, cwd=".")
    assert "PIPE_OK" in r.stdout, r.stdout + r.stderr


SP_DECODE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.distrib.collectives import sp_decode_attention
    from repro.kernels import ref

    mesh = jax.make_mesh((1, 4), ("data", "model"))
    B, T, Hq, Hkv, D = 2, 64, 4, 2, 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, 1, Hq, D))
    k = jax.random.normal(k2, (B, T, Hkv, D))
    v = jax.random.normal(k3, (B, T, Hkv, D))
    with mesh:
        out = jax.jit(lambda q, k, v: sp_decode_attention(
            q, k, v, mesh, seq_axis="model"))(q, k, v)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=T - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print("SP_OK")
""")


def test_sp_decode_attention():
    r = subprocess.run([sys.executable, "-c", SP_DECODE_SCRIPT],
                       capture_output=True, text=True, timeout=300, cwd=".")
    assert "SP_OK" in r.stdout, r.stdout + r.stderr
