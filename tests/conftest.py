import os
import sys

# src layout on path regardless of how pytest is invoked
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# IMPORTANT: the dry-run's 512-device override must never leak into tests;
# smoke tests and benches see the host's real (1-device) platform.
os.environ.pop("XLA_FLAGS", None)

# Opt-in lock-order sanitizer (DESIGN.md §11): REPRO_LOCKDEP=1 patches the
# threading.Lock/RLock/Condition factories *before* any fabric module is
# imported, so every fabric lock the suite creates is tracked.  The
# session teardown fails the run on any recorded cycle or lock-held-
# across-RPC violation.
from repro.analysis import lockdep as _lockdep  # noqa: E402

if _lockdep.enabled():
    _lockdep.install()

import time  # noqa: E402

import pytest  # noqa: E402


def poll_until(pred, timeout: float = 8.0, interval: float = 0.02,
               msg: str = "condition"):
    """Deflake helper: poll ``pred`` until truthy, bounded by
    ``timeout`` (monotonic).  Returns the first truthy value, so tests
    can both wait for and capture a result.  Use this instead of fixed
    ``time.sleep`` waits — it converges as fast as the system actually
    is and fails loudly with ``msg`` instead of silently racing."""
    deadline = time.monotonic() + timeout
    while True:
        value = pred()
        if value:
            return value
        if time.monotonic() >= deadline:
            raise AssertionError(
                f"timed out after {timeout:.1f}s waiting for {msg}")
        time.sleep(interval)


def wait_event(event, timeout: float = 8.0, msg: str = "event"):
    """Deflake helper: bounded ``threading.Event`` wait that fails
    loudly instead of letting a test limp past an unset event."""
    if not event.wait(timeout):
        raise AssertionError(
            f"timed out after {timeout:.1f}s waiting for {msg}")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: multi-second quorum/chaos tests; deselect with "
        "-m 'not slow' for a fast local loop")


@pytest.fixture(scope="session", autouse=True)
def _lockdep_gate():
    yield
    if _lockdep.enabled():
        _lockdep.assert_clean()
