import os
import sys

# src layout on path regardless of how pytest is invoked
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# IMPORTANT: the dry-run's 512-device override must never leak into tests;
# smoke tests and benches see the host's real (1-device) platform.
os.environ.pop("XLA_FLAGS", None)

# Opt-in lock-order sanitizer (DESIGN.md §11): REPRO_LOCKDEP=1 patches the
# threading.Lock/RLock/Condition factories *before* any fabric module is
# imported, so every fabric lock the suite creates is tracked.  The
# session teardown fails the run on any recorded cycle or lock-held-
# across-RPC violation.
from repro.analysis import lockdep as _lockdep  # noqa: E402

if _lockdep.enabled():
    _lockdep.install()

import pytest  # noqa: E402


@pytest.fixture(scope="session", autouse=True)
def _lockdep_gate():
    yield
    if _lockdep.enabled():
        _lockdep.assert_clean()
