import os
import sys

# src layout on path regardless of how pytest is invoked
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# IMPORTANT: the dry-run's 512-device override must never leak into tests;
# smoke tests and benches see the host's real (1-device) platform.
os.environ.pop("XLA_FLAGS", None)
