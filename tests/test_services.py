"""Service-layer integration: checkpoint (incl. corruption detection),
membership failure detection, datafeed eager/bulk parity, straggler
mitigation, gateway end-to-end."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.executor import Engine, RemoteError
from repro.core.types import Ret
from repro.data.pipeline import Prefetcher, SyntheticSource
from repro.services import (CheckpointClient, CheckpointServer,
                            DataFeedClient, DataFeedServer,
                            MembershipClient, MembershipServer,
                            replicated_call)
from repro.services.base import checksum_of, flatten_named, unflatten_named


@pytest.fixture
def tcp_pair():
    with Engine("tcp://127.0.0.1:0") as a, Engine("tcp://127.0.0.1:0") as b:
        yield a, b


def test_checkpoint_roundtrip(tcp_pair):
    srv, cli_e = tcp_pair
    CheckpointServer(srv)
    cli = CheckpointClient(cli_e, srv.uri)
    tree = {"params": {"w": np.arange(60_000, dtype=np.float32).reshape(300, 200)},
            "opt": (np.ones(5, np.int64), {"count": np.int32(7)})}
    assert cli.save("m", 3, tree)["ok"]
    tpl = jax.tree_util.tree_map(np.zeros_like, tree)
    out, step = cli.restore("m", tpl)
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_latest_and_list(tcp_pair):
    srv, cli_e = tcp_pair
    CheckpointServer(srv)
    cli = CheckpointClient(cli_e, srv.uri)
    tree = {"x": np.ones(10, np.float32)}
    cli.save("m", 1, tree)
    cli.save("m", 5, {"x": np.full(10, 5.0, np.float32)})
    out, step = cli.restore("m", jax.tree_util.tree_map(np.zeros_like, tree))
    assert step == 5 and out["x"][0] == 5.0
    assert {c["step"] for c in cli.list()} == {1, 5}


def test_checkpoint_checksum_detects_corruption(tcp_pair):
    srv, cli_e = tcp_pair
    server = CheckpointServer(srv)
    cli = CheckpointClient(cli_e, srv.uri)
    cli.save("m", 1, {"x": np.arange(1000, dtype=np.float32)})
    # corrupt the stored shard behind the server's back
    entry = server.store[("m", 1)]
    list(entry["named"].values())[0][17] = 1e9
    with pytest.raises(Exception):
        cli.restore("m", {"x": np.zeros(1000, np.float32)})


def test_checkpoint_restore_missing(tcp_pair):
    srv, cli_e = tcp_pair
    CheckpointServer(srv)
    cli = CheckpointClient(cli_e, srv.uri)
    with pytest.raises(RemoteError):
        cli.restore("ghost", {"x": np.zeros(1)})


def test_flatten_unflatten_roundtrip():
    tree = {"a": np.ones((2, 3)), "b": (np.zeros(4), {"c": np.int32(2)})}
    named = flatten_named(tree)
    out = unflatten_named(jax.tree_util.tree_map(np.zeros_like, tree), named)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(a, b)


def test_membership_failure_detection():
    with Engine("tcp://127.0.0.1:0") as coord, \
            Engine("tcp://127.0.0.1:0") as w1, \
            Engine("tcp://127.0.0.1:0") as w2:
        ms = MembershipServer(coord, heartbeat_timeout=0.5,
                              sweep_interval=0.1)
        changes = []
        c1 = MembershipClient(w1, coord.uri, "w1", 0.1,
                              on_change=lambda v: changes.append(v))
        c2 = MembershipClient(w2, coord.uri, "w2", 0.1)
        c1.join()
        c2.join()
        time.sleep(0.4)
        assert c1.current_view()["members"] == ["w1", "w2"]
        # kill w2's heartbeat (simulated node failure)
        c2._stop.set()
        deadline = time.time() + 5
        while time.time() < deadline:
            if c1.current_view()["members"] == ["w1"]:
                break
            time.sleep(0.1)
        assert c1.current_view()["members"] == ["w1"]
        assert changes, "on_change must fire on epoch bump"
        ms.stop()
        c1.leave()


def test_membership_heartbeat_rejoin_preserves_meta():
    """Regression: an expired member re-announcing via heartbeat used to
    be re-joined with ``meta={}``, silently dropping its registered
    metadata.  The server now preserves the heartbeat's meta like
    ``mem.join`` does, and the client carries its join meta on every
    heartbeat so the round trip restores it."""
    with Engine("tcp://127.0.0.1:0") as coord, \
            Engine("tcp://127.0.0.1:0") as w:
        ms = MembershipServer(coord, heartbeat_timeout=0.3,
                              sweep_interval=0.05)
        meta = {"role": "trainer", "rank": 3}
        # server-side path: raw wire join, expiry, heartbeat re-announce
        w.call(coord.uri, "mem.join",
               {"member_id": "m", "uri": w.uri, "meta": meta})
        deadline = time.time() + 5
        while time.time() < deadline and ms.table.get("m") is not None:
            time.sleep(0.05)             # no heartbeats: m expires
        assert ms.table.get("m") is None
        view = w.call(coord.uri, "mem.heartbeat",
                      {"member_id": "m", "uri": w.uri, "meta": meta})
        assert "m" in view["members"]
        assert ms.table.get("m")["meta"] == meta

        # client path: the heartbeat loop itself must carry the meta
        c = MembershipClient(w, coord.uri, "c1", 0.05)
        c.join({"zone": "a"})
        with ms.core._lock:              # force-expire behind its back
            ms.table.delete("c1")
        deadline = time.time() + 5
        while time.time() < deadline:
            rec = ms.table.get("c1")
            if rec is not None and rec["meta"] == {"zone": "a"}:
                break
            time.sleep(0.05)
        rec = ms.table.get("c1")
        assert rec is not None and rec["meta"] == {"zone": "a"}, \
            "client heartbeat re-join dropped the join metadata"
        c.leave()
        ms.close()


def test_datafeed_eager_vs_bulk_identical():
    src = SyntheticSource(vocab=500, seq_len=64, batch_per_host=4)
    with Engine("tcp://127.0.0.1:0") as fe_eager, \
            Engine("tcp://127.0.0.1:0") as fe_bulk, \
            Engine("tcp://127.0.0.1:0") as tr:
        DataFeedServer(fe_eager, src, eager_limit=1 << 30)
        DataFeedServer(fe_bulk, src, eager_limit=1)
        c_eager = DataFeedClient(tr, [fe_eager.uri])
        c_bulk = DataFeedClient(tr, [fe_bulk.uri])
        b1, b2 = c_eager.get(7), c_bulk.get(7)
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])


def test_datafeed_prefetch_pipeline():
    src = SyntheticSource(vocab=100, seq_len=32, batch_per_host=2)
    with Engine("tcp://127.0.0.1:0") as fe, Engine("tcp://127.0.0.1:0") as tr:
        DataFeedServer(fe, src)
        cli = DataFeedClient(tr, [fe.uri], depth=3)
        for step in range(6):
            b = cli.get(step)
            np.testing.assert_array_equal(b["tokens"],
                                          src.batch_at(step)["tokens"])


def test_replicated_call_first_wins_over_straggler():
    with Engine("tcp://127.0.0.1:0") as slow, \
            Engine("tcp://127.0.0.1:0") as fast, \
            Engine("tcp://127.0.0.1:0") as cli:
        slow.register("work", lambda x: time.sleep(5.0) or "slow")
        fast.register("work", lambda x: "fast")
        t0 = time.time()
        out = replicated_call(cli, [slow.uri, fast.uri], "work", None,
                              timeout=10.0)
        assert out == "fast"
        assert time.time() - t0 < 3.0


def test_replicated_call_survives_dead_target():
    with Engine("tcp://127.0.0.1:0") as ok, Engine("tcp://127.0.0.1:0") as cli:
        ok.register("work", lambda x: 42)
        out = replicated_call(cli, ["tcp://127.0.0.1:1", ok.uri], "work",
                              None, timeout=5.0)
        assert out == 42


def test_prefetcher_overlaps():
    class SlowSource:
        def __iter__(self):
            def gen():
                for i in range(5):
                    time.sleep(0.05)
                    yield {"i": np.int32(i)}
            return gen()

    pf = Prefetcher(SlowSource(), depth=3)
    time.sleep(0.3)                      # let it run ahead
    t0 = time.time()
    vals = [next(pf)["i"] for _ in range(3)]
    assert time.time() - t0 < 0.1        # already buffered
    assert vals == [0, 1, 2]
    pf.close()
