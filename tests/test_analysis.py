"""fablint + lockdep (DESIGN.md §11).

Static rules are exercised through :meth:`Linter.check_source` with one
*triggering* and one *passing* fixture per rule; lockdep through private
:class:`LockGraph` instances (no global factory patching), including the
classic two-lock inversion and the lock-held-across-RPC case.  The final
test is the real gate: fablint over ``src/`` must exit 0 against the
committed baseline.
"""
import os
import threading
import time

import pytest

from repro.analysis import lockdep
from repro.analysis.lint import (Linter, default_baseline_path,
                                 load_baseline, main as lint_main)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _violations(source: str, rule: str = None, path: str = "repro/x.py"):
    out = Linter().check_source(source, path)
    return [v for v in out if rule is None or v.rule == rule]


# ---------------------------------------------------------------------------
# guarded-by


GUARDED_BAD = """
import threading

class C:
    def __init__(self):
        self._lock = threading.Lock()
        self._q = []  #: guarded-by _lock

    def broken(self):
        self._q.append(1)
"""

GUARDED_OK = GUARDED_BAD.replace(
    "        self._q.append(1)",
    "        with self._lock:\n            self._q.append(1)")


def test_guarded_by_triggers_and_passes():
    bad = _violations(GUARDED_BAD, "guarded-by")
    assert len(bad) == 1 and "_q" in bad[0].msg
    assert not _violations(GUARDED_OK, "guarded-by")


def test_guarded_by_init_exempt():
    # __init__ publishes before the object is shared: never flagged
    assert not _violations(GUARDED_BAD, "guarded-by")[0].qualname.endswith(
        "__init__")


def test_requires_annotation_seeds_held_set():
    src = GUARDED_BAD.replace(
        "    def broken(self):",
        "    #: requires _lock\n    def broken(self):")
    assert not _violations(src, "guarded-by")


def test_locked_suffix_seeds_held_set():
    src = GUARDED_BAD.replace("def broken(", "def broken_locked(")
    assert not _violations(src, "guarded-by")


def test_condition_aliases_its_lock():
    src = """
import threading

class C:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._q = []  #: guarded-by _lock

    def ok(self):
        with self._cv:
            self._q.append(1)
"""
    assert not _violations(src, "guarded-by")


def test_inline_suppression():
    src = GUARDED_BAD.replace(
        "        self._q.append(1)",
        "        self._q.append(1)  # fablint: ok[guarded-by] startup only")
    assert not _violations(src, "guarded-by")


# ---------------------------------------------------------------------------
# lock-blocking


def test_blocking_sleep_under_lock():
    src = """
import threading, time

class C:
    def __init__(self):
        self._lock = threading.Lock()

    def bad(self):
        with self._lock:
            time.sleep(0.1)
"""
    vs = _violations(src, "lock-blocking")
    assert len(vs) == 1 and "sleep" in vs[0].msg
    assert not _violations(src.replace("            time.sleep(0.1)",
                                       "            pass"),
                           "lock-blocking")


def test_blocking_rpc_under_lock():
    src = """
import threading

class C:
    def __init__(self):
        self._lock = threading.Lock()

    def bad(self, pool):
        with self._lock:
            pool.call("svc.rpc", {})
"""
    assert _violations(src, "lock-blocking")


def test_encode_under_lock_flagged_but_str_encode_ok():
    src = """
import threading
from repro.core import proc as hg_proc

class C:
    def __init__(self):
        self._lock = threading.Lock()

    def bad(self, payload):
        with self._lock:
            return hg_proc.encode(hg_proc.proc_any, payload)

    def fine(self):
        with self._lock:
            return "x".encode()   # str.encode is not the proc encode
"""
    vs = _violations(src, "lock-blocking")
    assert len(vs) == 1 and vs[0].qualname.endswith("bad")


def test_cv_wait_on_held_lock_allowed():
    src = """
import threading

class C:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)

    def ok(self):
        with self._cv:
            self._cv.wait(0.1)

    def bad(self, other_event):
        with self._lock:
            other_event.wait()
"""
    vs = _violations(src, "lock-blocking")
    assert len(vs) == 1 and vs[0].qualname.endswith("bad")


# ---------------------------------------------------------------------------
# span-finish


def test_span_must_finish_on_all_paths():
    bad = """
from repro.telemetry import trace as _trace

def handler():
    span = _trace.start_span("op")
    do_work()
"""
    good = """
from repro.telemetry import trace as _trace

def handler():
    span = _trace.start_span("op")
    try:
        do_work()
    finally:
        span.finish("OK")
"""
    assert _violations(bad, "span-finish")
    assert not _violations(good, "span-finish")


def test_span_escaping_is_not_a_leak():
    src = """
from repro.telemetry import trace as _trace

def make():
    span = _trace.start_span("op")
    return span
"""
    assert not _violations(src, "span-finish")


# ---------------------------------------------------------------------------
# wallclock


def test_wallclock_banned_monotonic_ok():
    bad = "import time\n\ndef f():\n    return time.time()\n"
    good = "import time\n\ndef f():\n    return time.monotonic()\n"
    assert _violations(bad, "wallclock")
    assert not _violations(good, "wallclock")


# ---------------------------------------------------------------------------
# thread-hygiene


def test_thread_daemon_or_joined():
    bad = """
import threading

def run(fn):
    t = threading.Thread(target=fn)
    t.start()
"""
    daemon = bad.replace("target=fn", "target=fn, daemon=True")
    joined = bad + "    t.join()\n"
    assert _violations(bad, "thread-hygiene")
    assert not _violations(daemon, "thread-hygiene")
    assert not _violations(joined, "thread-hygiene")


# ---------------------------------------------------------------------------
# metric-cardinality


def test_metric_names_literal_and_labels_bounded():
    bad_name = """
from repro.telemetry import metrics

def f(name):
    metrics.counter("prefix." + name).inc()
"""
    bad_label = """
from repro.telemetry import metrics

def f(uri):
    metrics.counter("fabric.calls", peer=uri.split(":")[0]).inc()
"""
    good = """
from repro.telemetry import metrics

def f(tier):
    metrics.counter("fabric.calls", tier=tier).inc()
"""
    assert _violations(bad_name, "metric-cardinality")
    assert _violations(bad_label, "metric-cardinality")
    assert not _violations(good, "metric-cardinality")


# ---------------------------------------------------------------------------
# baseline mechanics


def test_baseline_suppresses_and_drift_fails(tmp_path, capsys):
    # the "repro/" marker makes norm_path yield a stable baseline key
    pkg = tmp_path / "repro"
    pkg.mkdir()
    mod = pkg / "m.py"
    mod.write_text("import time\n\ndef f():\n    return time.time()\n")
    base = tmp_path / "baseline.txt"
    base.write_text("wallclock repro/m.py::f  # display timestamp\n")
    assert lint_main([str(mod), "--baseline", str(base)]) == 0

    # entry goes stale once the violation is fixed -> drift error
    mod.write_text("import time\n\ndef f():\n    return time.monotonic()\n")
    assert lint_main([str(mod), "--baseline", str(base)]) == 1
    assert "baseline drift" in capsys.readouterr().out


def test_committed_baseline_is_small_and_loadable():
    entries = load_baseline(default_baseline_path())
    assert len(entries) <= 5


# ---------------------------------------------------------------------------
# lockdep: acquisition-order graph


def _mk(graph, name):
    return lockdep.wrap(threading.Lock(), name, graph)


def test_lockdep_two_lock_inversion_is_a_cycle():
    g = lockdep.LockGraph(metrics=False)
    a, b = _mk(g, "A"), _mk(g, "B")

    def order_ab():
        with a:
            with b:
                pass

    def order_ba():
        with b:
            with a:
                pass

    t1 = threading.Thread(target=order_ab)
    t1.start(); t1.join()
    t2 = threading.Thread(target=order_ba)
    t2.start(); t2.join()

    rep = g.report()
    assert rep["cycles"], rep
    cyc = rep["cycles"][0]["cycle"]
    assert set(cyc) >= {"A", "B"}
    with pytest.raises(AssertionError, match="cycle"):
        g.assert_clean()


def test_lockdep_consistent_order_is_clean():
    g = lockdep.LockGraph(metrics=False)
    a, b = _mk(g, "A"), _mk(g, "B")
    for _ in range(3):
        with a:
            with b:
                pass
    rep = g.report()
    assert rep["edges"] == 1 and not rep["cycles"]
    g.assert_clean()


def test_lockdep_same_site_nesting_not_a_cycle():
    # two instances of one class may nest by protocol (peer inboxes):
    # same-site edges are skipped
    g = lockdep.LockGraph(metrics=False)
    a1 = lockdep.wrap(threading.Lock(), "repro/x.py:10", g)
    a2 = lockdep.wrap(threading.Lock(), "repro/x.py:10", g)
    with a1:
        with a2:
            pass
    rep = g.report()
    assert rep["edges"] == 0 and not rep["cycles"]


def test_lockdep_reentrant_rlock_no_self_edge():
    g = lockdep.LockGraph(metrics=False)
    r = lockdep.wrap(threading.RLock(), "R", g)
    with r:
        with r:
            pass
    assert not g.report()["cycles"]
    assert not g.held_sites()


def test_lockdep_condition_over_tracked_lock():
    g = lockdep.LockGraph(metrics=False)
    lk = lockdep.wrap(threading.Lock(), "CV", g)
    cv = threading.Condition(lk)
    hit = []

    def waiter():
        with cv:
            while not hit:
                cv.wait(1.0)

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    with cv:
        hit.append(1)
        cv.notify_all()
    t.join(2.0)
    assert not t.is_alive()
    assert not g.held_sites()          # wait() dropped it from the stack
    g.assert_clean()


# ---------------------------------------------------------------------------
# lockdep: RPC boundary


def test_lockdep_lock_held_across_rpc():
    g = lockdep.LockGraph(metrics=False)
    lk = _mk(g, "repro/svc.py:5")
    with lk:
        g.note_rpc("Engine.call")
    rep = g.report()
    assert rep["rpc_violations"] and \
        rep["rpc_violations"][0]["held"] == ["repro/svc.py:5"]
    with pytest.raises(AssertionError, match="RPC boundary"):
        g.assert_clean()


def test_lockdep_rpc_without_lock_is_clean():
    g = lockdep.LockGraph(metrics=False)
    lk = _mk(g, "L")
    with lk:
        pass
    g.note_rpc("Engine.call")
    assert not g.report()["rpc_violations"]


# ---------------------------------------------------------------------------
# lockdep: hold-time metrics


def test_lockdep_hold_time_histogram():
    from repro.telemetry import metrics
    g = lockdep.LockGraph(metrics=True)
    lk = lockdep.wrap(threading.Lock(), "repro/hold.py:1", g)
    with lk:
        pass
    key = 'analysis.lock.hold_ms{site=repro/hold.py:1}'
    snap = metrics.snapshot()["histograms"]
    assert key in snap and snap[key]["count"] >= 1


# ---------------------------------------------------------------------------
# lockdep: global install (factory patching)


def test_lockdep_install_wraps_new_fabric_locks():
    if lockdep.graph() is not None:
        # conftest already installed for a REPRO_LOCKDEP=1 run (with the
        # repro/-only prefix filter) — the suite itself is the coverage
        pytest.skip("global lockdep active")
    g = lockdep.install(prefixes=None)          # track every site
    try:
        lk = threading.Lock()
        assert isinstance(lk, lockdep.TrackedLock)
        with lk:
            pass
        assert g.acquisitions >= 1
    finally:
        lockdep.uninstall()
    assert not isinstance(threading.Lock(), lockdep.TrackedLock)


def test_lockdep_install_excludes_metrics_registry():
    if lockdep.graph() is not None:
        pytest.skip("global lockdep active; factory routing already proven")
    lockdep.install(prefixes=None)
    try:
        from repro.telemetry import metrics
        h = metrics.REGISTRY.histogram("analysis.selftest.hold_ms")
        assert not isinstance(h._lock, lockdep.TrackedLock)
    finally:
        lockdep.uninstall()


# ---------------------------------------------------------------------------
# the real gate


def test_fablint_src_tree_is_clean():
    rc = lint_main([SRC])
    assert rc == 0, "fablint found violations in src/ (see stdout)"
