"""ReadCache unit semantics: TTL and token invalidation, singleflight
collapse, error/cancel non-poisoning, eviction bounds (DESIGN.md §9)."""
import threading
import time

import pytest

from repro.core.types import MercuryError, Ret
from repro.fabric.readcache import ReadCache, args_digest


class Counter:
    def __init__(self, value="v"):
        self.calls = 0
        self.value = value
        self._lock = threading.Lock()

    def __call__(self):
        with self._lock:
            self.calls += 1
        return self.value


def test_hit_within_ttl_and_token():
    c = ReadCache(ttl=5.0)
    c.observe("n1", 1)
    f = Counter()
    assert c.get_or_call("m", {"k": 1}, f) == "v"
    assert c.get_or_call("m", {"k": 1}, f) == "v"
    assert f.calls == 1
    st = c.stats()
    assert st["hits"] == 1 and st["misses"] == 1


def test_distinct_args_distinct_entries():
    c = ReadCache(ttl=5.0)
    f = Counter()
    c.get_or_call("m", {"k": 1}, f)
    c.get_or_call("m", {"k": 2}, f)
    c.get_or_call("m", {"k": 1}, f)
    assert f.calls == 2
    assert args_digest("m", {"k": 1}) != args_digest("m", {"k": 2})
    assert args_digest("m", {"k": 1}) == args_digest("m", {"k": 1})


def test_ttl_expiry_evicts():
    c = ReadCache(ttl=0.05)
    f = Counter()
    c.get_or_call("m", {}, f)
    time.sleep(0.08)
    c.get_or_call("m", {}, f)
    assert f.calls == 2


def test_epoch_bump_evicts():
    c = ReadCache(ttl=60.0)
    c.observe("n1", 1)
    f = Counter()
    c.get_or_call("m", {}, f)
    assert c.observe("n1", 2)             # epoch bump on same nonce
    c.get_or_call("m", {}, f)
    assert f.calls == 2


def test_nonce_change_evicts_even_with_lower_epoch():
    """A registry restart resets the epoch to 0 under a fresh nonce —
    that MUST evict (a bare epoch comparison would read it as stale)."""
    c = ReadCache(ttl=60.0)
    c.observe("n1", 100)
    f = Counter()
    c.get_or_call("m", {}, f)
    assert c.observe("n2", 0)
    c.get_or_call("m", {}, f)
    assert f.calls == 2


def test_stale_epoch_observation_ignored():
    c = ReadCache(ttl=60.0)
    c.observe("n1", 5)
    f = Counter()
    c.get_or_call("m", {}, f)
    assert not c.observe("n1", 3)         # older read racing in: ignored
    c.get_or_call("m", {}, f)
    assert f.calls == 1


def test_fresh_bypasses_but_repopulates():
    c = ReadCache(ttl=60.0)
    f = Counter()
    c.get_or_call("m", {}, f)
    c.get_or_call("m", {}, f, fresh=True)
    assert f.calls == 2
    c.get_or_call("m", {}, f)             # repopulated by the fresh read
    assert f.calls == 2


def test_ttl_zero_disables_caching():
    c = ReadCache(ttl=0.0)
    f = Counter()
    c.get_or_call("m", {}, f)
    c.get_or_call("m", {}, f)
    assert f.calls == 2


def test_singleflight_collapses_concurrent_misses():
    c = ReadCache(ttl=60.0)
    started = threading.Event()
    release = threading.Event()
    calls = [0]

    def slow_fetch():
        calls[0] += 1
        started.set()
        release.wait(5.0)
        return "shared"

    results = []

    def worker():
        results.append(c.get_or_call("m", {}, slow_fetch))

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    assert started.wait(5.0)
    time.sleep(0.05)                      # let the others pile onto the future
    release.set()
    for t in threads:
        t.join(timeout=5.0)
    assert calls[0] == 1
    assert results == ["shared"] * 8


def test_error_propagates_and_is_not_cached():
    """A failed (or canceled) fetch must reach every waiter and cache
    nothing — the canceled loser of a hedge can never poison reads."""
    c = ReadCache(ttl=60.0)
    boom = Counter()

    def failing():
        boom.calls += 1
        raise MercuryError(Ret.CANCELED, "hedge loser canceled")

    for _ in range(2):
        with pytest.raises(MercuryError):
            c.get_or_call("m", {}, failing)
    assert boom.calls == 2                # second call re-fetched: no entry
    ok = Counter()
    assert c.get_or_call("m", {}, ok) == "v"   # healthy fetch now populates
    assert c.get_or_call("m", {}, ok) == "v"
    assert ok.calls == 1


def test_token_of_observes_and_caches_under_response_token():
    """A read whose response reveals a bump both evicts older entries
    and seeds the cache under its own token."""
    c = ReadCache(ttl=60.0)
    c.observe("n1", 1)
    old = Counter("old")
    c.get_or_call("other", {}, old)

    f = Counter({"nonce": "n1", "epoch": 2, "data": 1})
    tok = lambda v: (v["nonce"], v["epoch"])
    c.get_or_call("m", {}, f, token_of=tok)
    assert c.stats()["token"]["epoch"] == 2
    c.get_or_call("m", {}, f, token_of=tok)
    assert f.calls == 1                   # cached under its own token
    c.get_or_call("other", {}, old)
    assert old.calls == 2                 # older-token entry was evicted


def test_invalidate_drops_without_token_advance():
    c = ReadCache(ttl=60.0)
    f = Counter()
    c.get_or_call("m", {}, f)
    c.invalidate()
    c.get_or_call("m", {}, f)
    assert f.calls == 2


def test_max_entries_bounds_cache():
    c = ReadCache(ttl=60.0, max_entries=4)
    f = Counter()
    for i in range(10):
        c.get_or_call("m", {"k": i}, f)
    assert len(c) <= 4


def test_population_raced_by_observe_does_not_stick():
    """A fetch that straddles a token bump must not populate: the result
    may be from either side of the bump."""
    c = ReadCache(ttl=60.0)
    c.observe("n1", 1)

    def fetch_and_bump():
        c.observe("n1", 2)                # authority moved mid-fetch
        return "ambiguous"

    c.get_or_call("m", {}, fetch_and_bump)
    f = Counter()
    c.get_or_call("m", {}, f)
    assert f.calls == 1                   # ambiguous result was NOT cached


def test_per_shard_tokens_do_not_cross_evict():
    """Sharded control plane (DESIGN.md §12): a sharded client holds
    one cache — one ``(nonce, epoch)`` token — per shard, so a restart
    (nonce change) on shard 1 evicts only shard-1 entries and shard 0
    keeps serving its cached authority.  Epochs are NEVER comparable
    across shards: shard 1 restarting onto a *lower* epoch than shard
    0's must still evict shard 1 (new nonce) and must not touch shard 0
    (the end-to-end version lives in test_sharding.py)."""
    shard0, shard1 = ReadCache(ttl=30.0), ReadCache(ttl=30.0)
    tok = lambda out: (out["nonce"], out["epoch"])  # noqa: E731
    f0 = Counter({"nonce": "s0-boot", "epoch": 9, "v": "alpha"})
    f1 = Counter({"nonce": "s1-boot", "epoch": 3, "v": "beta"})
    assert shard0.get_or_call("fab.resolve", {"service": "alpha"}, f0,
                              token_of=tok)["v"] == "alpha"
    assert shard1.get_or_call("fab.resolve", {"service": "beta"}, f1,
                              token_of=tok)["v"] == "beta"
    assert shard0.token() == ("s0-boot", 9)
    assert shard1.token() == ("s1-boot", 3)

    # shard 1 restarts: fresh nonce, epoch counter reset below BOTH
    # shards' previous epochs — a global token would deadlock or
    # cross-evict here; per-shard tokens just advance shard 1's
    assert shard1.observe("s1-reborn", 1)
    assert len(shard1) == 0 and shard1.token() == ("s1-reborn", 1)
    assert len(shard0) == 1 and shard0.token() == ("s0-boot", 9)

    # shard 0 still serves from cache (zero new fetches); shard 1
    # refetches under its reborn authority
    assert shard0.get_or_call("fab.resolve", {"service": "alpha"}, f0,
                              token_of=tok)["v"] == "alpha"
    assert f0.calls == 1
    f1.value = {"nonce": "s1-reborn", "epoch": 1, "v": "beta'"}
    assert shard1.get_or_call("fab.resolve", {"service": "beta"}, f1,
                              token_of=tok)["v"] == "beta'"
    assert f1.calls == 2
