"""End-to-end behaviours: continuous batching parity, gateway over tcp,
training loss decreases, checkpoint/restart determinism, elastic recovery
(membership epoch bump → restore from checkpoint and continue)."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ParallelConfig
from repro.core.executor import Engine
from repro.models import Model, unzip
from repro.serve.engine import ServeEngine
from repro.services import (CheckpointClient, CheckpointServer,
                            MembershipClient, MembershipServer,
                            ServingGateway)
from repro.train import optim
from repro.train.step import init_state, make_train_step

CFG = configs.reduced("qwen1.5-0.5b").replace(compute_dtype="float32")


@pytest.fixture(scope="module")
def model_and_params():
    m = Model(CFG)
    params, _ = unzip(m.init(jax.random.PRNGKey(0)))
    return m, params


def test_continuous_batching_matches_isolated(model_and_params):
    """A request decoded among other (different) slot traffic must produce
    the same tokens as decoded alone."""
    m, params = model_and_params
    p_main = np.arange(1, 7)
    others = [np.arange(2, 10), np.arange(3, 6), np.arange(5, 17)]

    alone = ServeEngine(m, params, max_len=64, n_slots=1)
    want = alone.generate([p_main], max_new=6)[0]

    mixed = ServeEngine(m, params, max_len=64, n_slots=2)
    reqs = [mixed.submit(p, max_new=6) for p in [p_main] + others]
    mixed.drain()
    assert reqs[0].out_tokens == want


def test_gateway_tcp_end_to_end(model_and_params):
    m, params = model_and_params
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        gw = ServingGateway(srv, ServeEngine(m, params, max_len=64,
                                             n_slots=2))
        outs = []
        for i in range(3):
            outs.append(cli.call(srv.uri, "gen.generate",
                                 {"tokens": [1 + i, 2, 3], "max_new": 5},
                                 timeout=120.0))
        assert all(len(o["tokens"]) == 5 and o["done"] for o in outs)
        stats = cli.call(srv.uri, "gen.stats", {})
        assert stats["n_slots"] == 2
        gw.stop()


def test_gateway_sm_bulk_submit(model_and_params):
    """Gateway over the shared-memory tier: the prompt never rides the
    eager message — the gateway pulls it from the client's registered
    memory (gen.submit_bulk)."""
    import uuid
    m, params = model_and_params
    tag = uuid.uuid4().hex[:8]
    with Engine(f"sm://gw-{tag}") as srv, Engine(f"sm://gwc-{tag}") as cli:
        gw = ServingGateway(srv, ServeEngine(m, params, max_len=64,
                                             n_slots=2))
        tokens = np.asarray([1, 2, 3], np.int32)
        h = cli.expose([tokens])
        out = cli.call(srv.uri, "gen.submit_bulk",
                       {"desc": h.descriptor().to_bytes(), "count": 3,
                        "max_new": 4}, timeout=120.0)
        res = cli.call(srv.uri, "gen.result",
                       {"rid": out["rid"], "wait": True, "timeout": 60.0},
                       timeout=120.0)
        h.free()
        assert res["done"] and len(res["tokens"]) == 4
        stats = cli.call(srv.uri, "gen.stats", {})
        assert "sm://" in stats["uris"]
        gw.stop()


def make_batch(step):
    k = jax.random.PRNGKey(step)
    toks = jax.random.randint(k, (4, 33), 0, CFG.vocab)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def test_training_reduces_loss(model_and_params):
    m, _ = model_and_params
    ocfg = optim.OptConfig(lr=3e-3, warmup=2, decay_steps=40)
    state, _ = init_state(m, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, ocfg,
                                   ParallelConfig(remat="none")))
    losses = []
    for i in range(15):
        state, metrics = step(state, make_batch(i % 3))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_checkpoint_restart_determinism(model_and_params):
    """Train 6 steps straight == train 3, save, restore, train 3 more."""
    m, _ = model_and_params
    ocfg = optim.OptConfig(lr=1e-3, warmup=0, decay_steps=100)
    step = jax.jit(make_train_step(m, ocfg, ParallelConfig(remat="none")))

    state, _ = init_state(m, ocfg, jax.random.PRNGKey(0))
    for i in range(6):
        state, _m = step(state, make_batch(i))
    direct = state

    with Engine(None) as e:
        CheckpointServer(e)
        cli = CheckpointClient(e, e.uri)
        state, _ = init_state(m, ocfg, jax.random.PRNGKey(0))
        for i in range(3):
            state, _m = step(state, make_batch(i))
        cli.save("t", 3, jax.tree_util.tree_map(np.asarray, state))

        fresh, _ = init_state(m, ocfg, jax.random.PRNGKey(42))  # wrong init
        restored, at = cli.restore("t", fresh)
        assert at == 3
        restored = jax.tree_util.tree_map(jnp.asarray, restored)
        for i in range(3, 6):
            restored, _m = step(restored, make_batch(i))

    for a, b in zip(jax.tree_util.tree_leaves(direct["params"]),
                    jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_elastic_recovery_on_membership_change(model_and_params):
    """Simulated node failure: epoch bump triggers restore-from-checkpoint
    and training continues to lower loss."""
    m, _ = model_and_params
    ocfg = optim.OptConfig(lr=3e-3, warmup=0, decay_steps=100)
    step = jax.jit(make_train_step(m, ocfg, ParallelConfig(remat="none")))

    with Engine("tcp://127.0.0.1:0") as coord_e, \
            Engine("tcp://127.0.0.1:0") as trainer_e, \
            Engine("tcp://127.0.0.1:0") as peer_e:
        ms = MembershipServer(coord_e, heartbeat_timeout=0.4,
                              sweep_interval=0.1)
        CheckpointServer(coord_e)
        ckpt = CheckpointClient(trainer_e, coord_e.uri)

        epoch_changed = threading.Event()
        me = MembershipClient(trainer_e, coord_e.uri, "trainer", 0.1,
                              on_change=lambda v: epoch_changed.set())
        me.join()
        peer = MembershipClient(peer_e, coord_e.uri, "peer", 0.1)
        peer.join()
        time.sleep(0.3)
        epoch_changed.clear()

        state, _ = init_state(m, ocfg, jax.random.PRNGKey(0))
        for i in range(3):
            state, metrics = step(state, make_batch(i))
        ckpt.save("elastic", 3, jax.tree_util.tree_map(np.asarray, state))
        loss_at_ckpt = float(metrics["loss"])

        peer._stop.set()                        # peer dies silently
        assert epoch_changed.wait(5.0), "failure must bump the epoch"

        # driver reaction: rebuild (here: same host), restore, continue
        fresh, _ = init_state(m, ocfg, jax.random.PRNGKey(9))
        state2, at = ckpt.restore("elastic", fresh)
        state2 = jax.tree_util.tree_map(jnp.asarray, state2)
        for i in range(at, at + 5):
            state2, metrics2 = step(state2, make_batch(i))
        assert float(metrics2["loss"]) < loss_at_ckpt + 0.5
        ms.stop()
        me.leave()
