"""Mercury microbenchmarks — one per CLUSTER'13 evaluation axis.

1. small-RPC round-trip latency vs the raw transport round-trip
   (paper claim: the RPC layer adds small, flat overhead);
2. bulk transfer bandwidth vs size, eager vs rendezvous crossover and
   pipelining depth (paper claim: bulk approaches raw bandwidth);
3. RPC rate vs in-flight concurrency (the callback/CQ model's point).
"""
from __future__ import annotations

import socket
import statistics
import threading
import time
from typing import Dict, List

import numpy as np

from repro.core.bulk import BulkDescriptor
from repro.core.executor import Engine


def _raw_tcp_rtt(n: int = 200, payload: int = 64) -> float:
    """Baseline: bare non-blocking-free socket ping-pong, seconds/rt."""
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]
    stop = threading.Event()

    def serve():
        conn, _ = srv.accept()
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        while not stop.is_set():
            try:
                data = conn.recv(65536)
            except OSError:
                return
            if not data:
                return
            conn.sendall(data)

    t = threading.Thread(target=serve, daemon=True)
    t.start()
    cli = socket.create_connection(("127.0.0.1", port))
    cli.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    msg = b"x" * payload
    cli.sendall(msg)
    cli.recv(65536)                     # warm
    t0 = time.perf_counter()
    for _ in range(n):
        cli.sendall(msg)
        got = b""
        while len(got) < payload:
            got += cli.recv(65536)
    dt = (time.perf_counter() - t0) / n
    stop.set()
    cli.close()
    srv.close()
    return dt


def bench_latency() -> Dict:
    """RPC round-trip latency (self + tcp) vs raw socket ping-pong."""
    out: Dict = {"name": "rpc_latency"}
    out["raw_tcp_rtt_us"] = _raw_tcp_rtt() * 1e6

    for plugin, uri in [("self", None), ("tcp", "tcp://127.0.0.1:0")]:
        with Engine(uri) as srv, \
                (Engine("tcp://127.0.0.1:0") if plugin == "tcp" else srv) \
                as cli:
            srv.register("ping", lambda x: x)
            srv.register("ping_inline", lambda x: x, inline=True)
            for name, key in (("ping", f"{plugin}_rtt_us"),
                              ("ping_inline", f"{plugin}_inline_rtt_us")):
                cli.call(srv.uri, name, b"x" * 64)       # warm
                samples = []
                for _ in range(200):
                    t0 = time.perf_counter()
                    cli.call(srv.uri, name, b"x" * 64)
                    samples.append(time.perf_counter() - t0)
                out[key] = statistics.median(samples) * 1e6
            if plugin == "tcp":
                out["tcp_overhead_x"] = out["tcp_rtt_us"] / \
                    max(out["raw_tcp_rtt_us"], 1e-9)
    return out


def bench_bandwidth(sizes=(4 << 10, 64 << 10, 1 << 20, 16 << 20, 64 << 20),
                    chunks=(256 << 10, 4 << 20),
                    inflights=(1, 4)) -> Dict:
    """Bulk GET bandwidth vs size × pipelining; eager RPC for contrast."""
    out: Dict = {"name": "bulk_bandwidth", "points": []}
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        srv.register("eager", lambda x: x)

        for size in sizes:
            src = np.random.default_rng(0).integers(
                0, 255, size=size, dtype=np.uint8)
            h = srv.expose([src])
            desc = h.descriptor()
            for chunk in chunks:
                for infl in inflights:
                    dst = np.zeros_like(src)
                    lh = cli.expose([dst])
                    t0 = time.perf_counter()
                    cli.pull(srv.uri, desc, lh, chunk_size=chunk,
                             max_inflight=infl)
                    dt = time.perf_counter() - t0
                    lh.free()
                    assert np.array_equal(dst, src)
                    out["points"].append({
                        "size": size, "mode": "bulk", "chunk": chunk,
                        "inflight": infl, "MBps": size / dt / 1e6})
            h.free()
            if size <= (16 << 20):
                payload = bytes(src[:size])
                t0 = time.perf_counter()
                got = cli.call(srv.uri, "eager", payload, timeout=120)
                dt = time.perf_counter() - t0
                out["points"].append({"size": size, "mode": "eager",
                                      "MBps": 2 * size / dt / 1e6})
    return out


def bench_rate(inflight_levels=(1, 2, 8, 32, 128)) -> Dict:
    """Small-RPC throughput vs number of in-flight requests."""
    out: Dict = {"name": "rpc_rate", "points": []}
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        srv.register("tick", lambda x: x + 1)
        cli.call(srv.uri, "tick", 0)
        N = 600
        for infl in inflight_levels:
            t0 = time.perf_counter()
            done = 0
            pending = []
            i = 0
            while done < N:
                while len(pending) < infl and i < N:
                    pending.append(cli.call_async(srv.uri, "tick", i))
                    i += 1
                pending[0].result(timeout=30)
                pending.pop(0)
                done += 1
            dt = time.perf_counter() - t0
            out["points"].append({"inflight": infl, "rps": N / dt})
    return out


def run_all(verbose=True) -> List[Dict]:
    results = [bench_latency(), bench_bandwidth(), bench_rate()]
    if verbose:
        lat = results[0]
        print(f"[latency] raw tcp rtt {lat['raw_tcp_rtt_us']:.0f}us | "
              f"mercury self {lat['self_rtt_us']:.0f}us "
              f"(inline {lat['self_inline_rtt_us']:.0f}us) | "
              f"mercury tcp {lat['tcp_rtt_us']:.0f}us "
              f"(inline {lat['tcp_inline_rtt_us']:.0f}us, "
              f"{lat['tcp_overhead_x']:.2f}x raw)")
        print("[bandwidth] (size, mode, chunk, inflight) -> MB/s")
        for p in results[1]["points"]:
            if p["mode"] == "bulk":
                print(f"   {p['size'] >> 10:8d}KiB bulk  c={p['chunk'] >> 10}KiB "
                      f"i={p['inflight']}  {p['MBps']:8.0f}")
            else:
                print(f"   {p['size'] >> 10:8d}KiB eager              "
                      f"{p['MBps']:8.0f}")
        print("[rate] inflight -> req/s")
        for p in results[2]["points"]:
            print(f"   {p['inflight']:4d} -> {p['rps']:7.0f}")
    return results
