"""Mercury microbenchmarks — one per CLUSTER'13 evaluation axis.

1. small-RPC round-trip latency vs the raw transport round-trip
   (paper claim: the RPC layer adds small, flat overhead);
2. bulk transfer bandwidth vs size, eager vs rendezvous crossover and
   pipelining depth (paper claim: bulk approaches raw bandwidth);
3. RPC rate vs in-flight concurrency (the callback/CQ model's point);
4. routed-pool throughput: 1 client fanned across 3 service replicas
   (sm+tcp mix) through the fabric's ServicePool vs the same load on a
   single endpoint — the scale-out win is measured, not asserted;
5. routed-pool *overload*: offered load above handler capacity, every
   call deadlined — static credits + accept-everything servers vs
   adaptive credits + EWMA-weighted balancing + deadline-aware
   admission control (goodput and deadline-miss rate compared).
   Run standalone via ``--only overload``.
6. *registry failover*: routed load through a 3-replica registry quorum
   while the leaseholder is killed mid-run — client-visible resolution
   failures (must be zero), client failover time vs the pool refresh
   interval, lease takeover time, and view resync onto the survivor's
   stream.  Run standalone via ``--only registry_failover``.
7. *gossip churn*: control-plane gossip bytes/round at scale — 500
   registered instances on a 3-replica quorum, per-entry delta gossip
   (the default) vs the PR-4 full-state snapshot protocol, measured
   idle and under churn.  Asserts the ≥10x idle reduction claimed in
   DESIGN.md §8.  Run standalone via ``--only gossip_churn``.
8. *cached resolve*: the client-side idempotent read cache — the same
   resolve storm with the cache on vs off, counting true registry
   round-trips server-side.  Asserts the ≥10x reduction and zero stale
   reads across an epoch bump, a foreign write, and a full registry
   restart (nonce change).  Latency bench 1 additionally records the
   co-located wire-path baseline and asserts the self-tier fast path
   (DESIGN.md §9) is ≥3x faster.  Run via ``--only cached_resolve``.
"""
from __future__ import annotations

import os
import random
import socket
import statistics
import subprocess
import sys
import textwrap
import threading
import time
import uuid
from contextlib import contextmanager
from typing import Dict, List, Tuple

import numpy as np

# allow `python benchmarks/bench_core.py` without PYTHONPATH
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.core.bulk import BulkDescriptor
from repro.core.executor import Engine

_SERVER_SRC = textwrap.dedent("""
    import sys
    sys.path.insert(0, sys.argv[1])
    from repro.core.executor import Engine
    with Engine(sys.argv[2]) as e:
        e.register("ping", lambda x: x)
        e.register("ping_inline", lambda x: x, inline=True)
        print("URI " + e.uri, flush=True)
        sys.stdin.read()            # parent closes stdin to stop us
""")


@contextmanager
def _server_process(transport: str):
    """Echo server in a *separate process* — the honest co-located-services
    comparison for the sm-vs-tcp-loopback latency claim."""
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    uri = f"sm://bench-srv-{uuid.uuid4().hex[:8]}" if transport == "sm" \
        else "tcp://127.0.0.1:0"
    p = subprocess.Popen([sys.executable, "-c", _SERVER_SRC, src, uri],
                         stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                         text=True)
    try:
        line = p.stdout.readline().strip()
        if not line.startswith("URI "):
            raise RuntimeError(f"bench server failed to start: {line!r}")
        yield line[4:]
    finally:
        p.stdin.close()
        p.wait(timeout=10)


_BW_SERVER_SRC = textwrap.dedent("""
    import sys
    sys.path.insert(0, sys.argv[1])
    import numpy as np
    from repro.core.executor import Engine
    max_size = int(sys.argv[3])
    with Engine(sys.argv[2]) as e:
        # sm cross-process RMA requires shm-backed registrations
        alloc = getattr(e.na, "alloc_array", None)
        buf = alloc((max_size,), np.uint8) if alloc is not None \\
            else np.empty(max_size, np.uint8)
        buf[:] = np.resize(np.arange(251, dtype=np.uint8), max_size)
        h = e.expose([buf])
        e.register("desc", lambda _x: h.descriptor().to_bytes())
        e.register("eager", lambda x: x)
        print("URI " + e.uri, flush=True)
        sys.stdin.read()
""")


def _cli_uri(transport: str) -> str:
    return f"sm://bench-cli-{uuid.uuid4().hex[:8]}" if transport == "sm" \
        else "tcp://127.0.0.1:0"


@contextmanager
def _bw_server(transport: str, max_size: int):
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    uri = f"sm://bench-srv-{uuid.uuid4().hex[:8]}" if transport == "sm" \
        else "tcp://127.0.0.1:0"
    p = subprocess.Popen([sys.executable, "-c", _BW_SERVER_SRC, src, uri,
                          str(max_size)],
                         stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                         text=True)
    try:
        line = p.stdout.readline().strip()
        if not line.startswith("URI "):
            raise RuntimeError(f"bench server failed to start: {line!r}")
        yield line[4:]
    finally:
        p.stdin.close()
        p.wait(timeout=10)


def _raw_tcp_rtt(n: int = 200, payload: int = 64) -> float:
    """Baseline: bare non-blocking-free socket ping-pong, seconds/rt."""
    srv = socket.socket()
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    port = srv.getsockname()[1]
    stop = threading.Event()

    def serve():
        conn, _ = srv.accept()
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        while not stop.is_set():
            try:
                data = conn.recv(65536)
            except OSError:
                return
            if not data:
                return
            conn.sendall(data)

    t = threading.Thread(target=serve, daemon=True)
    t.start()
    cli = socket.create_connection(("127.0.0.1", port))
    cli.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    msg = b"x" * payload
    cli.sendall(msg)
    cli.recv(65536)                     # warm
    t0 = time.perf_counter()
    for _ in range(n):
        cli.sendall(msg)
        got = b""
        while len(got) < payload:
            got += cli.recv(65536)
    dt = (time.perf_counter() - t0) / n
    stop.set()
    cli.close()
    srv.close()
    return dt


def _sample_rtt(cli: Engine, target: str, name: str, n: int) -> List[float]:
    samples = []
    for _ in range(n):
        t0 = time.perf_counter()
        cli.call(target, name, b"x" * 64)
        samples.append(time.perf_counter() - t0)
    return samples


def bench_latency(transports=("self", "sm", "tcp"), iters: int = 200) -> Dict:
    """RPC round-trip latency per transport vs raw socket ping-pong.

    ``self`` is in-process; ``sm`` and ``tcp`` talk to a server in a
    separate process — the locality-tier claim is that co-located services
    see sm < tcp-loopback round trips (DESIGN.md §2).  sm and tcp samples
    are *interleaved* in rounds so background load on a shared machine
    skews both transports equally, not whichever was measured first."""
    out: Dict = {"name": "rpc_latency"}
    out["raw_tcp_rtt_us"] = _raw_tcp_rtt(n=iters) * 1e6

    if "self" in transports:
        with Engine(None) as eng:
            eng.register("ping", lambda x: x)
            eng.register("ping_inline", lambda x: x, inline=True)
            for name, key in (("ping", "self_rtt_us"),
                              ("ping_inline", "self_inline_rtt_us")):
                _sample_rtt(eng, eng.uri, name, 10)      # warm
                out[key] = statistics.median(
                    _sample_rtt(eng, eng.uri, name, iters)) * 1e6
        # wire-path baseline for the same co-located call: local_dispatch
        # off forces full proc encode/decode + header + progress-thread
        # round trips.  The self-tier fast path (DESIGN.md §9) must beat
        # it by >= 3x or the PR regressed.
        with Engine(None, local_dispatch=False) as eng:
            eng.register("ping", lambda x: x)
            _sample_rtt(eng, eng.uri, "ping", 10)        # warm
            out["self_wire_rtt_us"] = statistics.median(
                _sample_rtt(eng, eng.uri, "ping", iters)) * 1e6
        out["self_local_speedup_x"] = (out["self_wire_rtt_us"]
                                       / max(out["self_rtt_us"], 1e-9))
        assert out["self_local_speedup_x"] >= 3.0, \
            (f"self-tier dispatch only {out['self_local_speedup_x']:.2f}x "
             f"faster than the wire path (local "
             f"{out['self_rtt_us']:.0f}us vs wire "
             f"{out['self_wire_rtt_us']:.0f}us); expected >= 3x")

    remote = [t for t in transports if t in ("sm", "tcp")]
    if remote:
        from contextlib import ExitStack
        with ExitStack() as stack:
            clis: Dict[str, Tuple[Engine, str]] = {}
            for t in remote:
                srv_uri = stack.enter_context(_server_process(t))
                cli_uri = f"sm://bench-cli-{uuid.uuid4().hex[:8]}" \
                    if t == "sm" else "tcp://127.0.0.1:0"
                clis[t] = (stack.enter_context(Engine(cli_uri)), srv_uri)
            samples: Dict[str, List[float]] = \
                {f"{t}_{n}": [] for t in remote for n in ("ping",
                                                          "ping_inline")}
            for t in remote:
                cli, srv_uri = clis[t]
                _sample_rtt(cli, srv_uri, "ping", 10)    # warm
                _sample_rtt(cli, srv_uri, "ping_inline", 10)
            rounds, chunk = max(1, iters // 25), 25
            for _ in range(rounds):
                for t in remote:
                    cli, srv_uri = clis[t]
                    samples[f"{t}_ping"] += _sample_rtt(cli, srv_uri,
                                                        "ping", chunk)
                    samples[f"{t}_ping_inline"] += _sample_rtt(
                        cli, srv_uri, "ping_inline", chunk)
            for t in remote:
                out[f"{t}_rtt_us"] = \
                    statistics.median(samples[f"{t}_ping"]) * 1e6
                out[f"{t}_inline_rtt_us"] = \
                    statistics.median(samples[f"{t}_ping_inline"]) * 1e6
        if "tcp" in remote:
            out["tcp_overhead_x"] = out["tcp_rtt_us"] / \
                max(out["raw_tcp_rtt_us"], 1e-9)
    if "sm_rtt_us" in out and "tcp_rtt_us" in out:
        out["sm_speedup_vs_tcp"] = out["tcp_rtt_us"] / out["sm_rtt_us"]
    return out


def bench_bandwidth(sizes=(4 << 10, 64 << 10, 1 << 20, 16 << 20, 64 << 20),
                    chunks=(256 << 10, 4 << 20),
                    inflights=(1, 4), transport: str = "tcp") -> Dict:
    """Bulk GET bandwidth vs size × pipelining; eager RPC for contrast.

    The server runs in a separate process (shm-backed buffers on sm, so
    the pull exercises the real memdir/attach path, not the in-process
    shortcut).  On ``sm`` the native-RMA fast path skips chunking, so
    chunk size and pipeline depth should be ~irrelevant there."""
    out: Dict = {"name": "bulk_bandwidth", "transport": transport,
                 "points": []}
    max_size = max(sizes)
    expected = np.resize(np.arange(251, dtype=np.uint8), max_size)
    with _bw_server(transport, max_size) as srv_uri, \
            Engine(_cli_uri(transport)) as cli:
        desc = BulkDescriptor.from_bytes(
            cli.call(srv_uri, "desc", None, timeout=60))
        # eager echoes ride the expected-message path: stay within it
        eager_max = min(16 << 20,
                        getattr(cli.na, "max_expected_size", 16 << 20) // 2)

        for size in sizes:
            for chunk in chunks:
                for infl in inflights:
                    dst = np.zeros(size, np.uint8)
                    lh = cli.expose([dst])
                    t0 = time.perf_counter()
                    cli.pull(srv_uri, desc, lh, size=size, chunk_size=chunk,
                             max_inflight=infl)
                    dt = time.perf_counter() - t0
                    lh.free()
                    assert np.array_equal(dst, expected[:size])
                    out["points"].append({
                        "size": size, "mode": "bulk", "chunk": chunk,
                        "inflight": infl, "MBps": size / dt / 1e6})
            if size <= eager_max:
                payload = bytes(expected[:size])
                t0 = time.perf_counter()
                got = cli.call(srv_uri, "eager", payload, timeout=120)
                dt = time.perf_counter() - t0
                assert got == payload
                out["points"].append({"size": size, "mode": "eager",
                                      "MBps": 2 * size / dt / 1e6})
    return out


_POOL_WORKER_SRC = textwrap.dedent("""
    import sys, time
    sys.path.insert(0, sys.argv[1])
    from repro.core.executor import Engine
    from repro.fabric import ServiceInstance
    uris = sys.argv[2].split(";")
    registry, work_ms = sys.argv[3], float(sys.argv[4])
    # 2 handler threads/worker: the benchmark contrasts handler *capacity*
    # (1 endpoint = 2 concurrent handlers vs pool = 2 x n_workers), keeping
    # both sides far below the client's noisy per-RPC ceiling on tiny boxes
    with Engine(uris, handler_threads=2) as e:
        e.register("work", lambda x: time.sleep(work_ms / 1e3) or x)
        inst = ServiceInstance(e, registry, "bench-pool", capacity=4,
                               report_interval=0.2)
        print("URI " + e.uri, flush=True)
        sys.stdin.read()
        inst.close()
""")


def _drive(call_one, n_calls: int, concurrency: int) -> float:
    """Issue ``n_calls`` blocking calls from ``concurrency`` threads;
    returns calls/second."""
    import concurrent.futures as cf
    t0 = time.perf_counter()
    with cf.ThreadPoolExecutor(concurrency) as tp:
        futs = [tp.submit(call_one, i) for i in range(n_calls)]
        for f in futs:
            f.result(timeout=120)
    return n_calls / (time.perf_counter() - t0)


def bench_pool(n_workers: int = 3, work_ms: float = 40.0,
               n_calls: int = 300, concurrency: int = 12) -> Dict:
    # work_ms is deliberately large relative to per-RPC client overhead:
    # the benchmark measures *handler-capacity* scale-out (what replicas
    # add), and must stay >=1.5x even when scheduling noise on a small
    # CI box doubles the client-side cost of each call.
    """Routed-pool throughput: the same workload against one endpoint vs
    fanned across ``n_workers`` replicas by a ServicePool (locality
    balancer, sm+tcp mix: workers 0..n-2 are reachable over shared
    memory, the last only over tcp)."""
    from contextlib import ExitStack

    from repro.fabric import RegistryService, RetryPolicy, ServicePool

    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    out: Dict = {"name": "routed_pool", "workers": n_workers,
                 "work_ms": work_ms, "calls": n_calls,
                 "concurrency": concurrency}
    tag = uuid.uuid4().hex[:8]
    with Engine("tcp://127.0.0.1:0") as reg_engine:
        registry = RegistryService(reg_engine, instance_ttl=5.0)
        with ExitStack() as stack:
            worker_uris = []
            for i in range(n_workers):
                uri = (f"sm://bpw{i}-{tag};tcp://127.0.0.1:0"
                       if i < n_workers - 1 else "tcp://127.0.0.1:0")
                p = subprocess.Popen(
                    [sys.executable, "-c", _POOL_WORKER_SRC, src, uri,
                     reg_engine.uri, str(work_ms)],
                    stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True)

                def _stop(proc=p):
                    try:
                        proc.stdin.close()
                        proc.wait(timeout=10)
                    except Exception:
                        proc.kill()
                stack.callback(_stop)
                line = p.stdout.readline().strip()
                if not line.startswith("URI "):
                    raise RuntimeError(f"pool worker failed: {line!r}")
                worker_uris.append(line[4:])

            with Engine([f"sm://bpc-{tag}", "tcp://127.0.0.1:0"]) as cli:
                payload = b"x" * 64
                # baseline: every call to ONE endpoint (worker 0)
                single = worker_uris[0]
                cli.call(single, "work", payload)            # warm
                out["single_rps"] = _drive(
                    lambda i: cli.call(single, "work", payload, timeout=30),
                    n_calls, concurrency)

                # credits sized so the locality balancer overflows past
                # the sm tier onto the tcp replica once sm saturates —
                # the mixed-tier routing the benchmark is about
                pool = ServicePool(cli, reg_engine.uri, "bench-pool",
                                   balancer="locality",
                                   credits_per_target=max(concurrency //
                                                          n_workers, 2),
                                   policy=RetryPolicy(attempts=3,
                                                      rpc_timeout=30.0))
                pool.call("work", payload)                   # warm
                out["pool_rps"] = _drive(
                    lambda i: pool.call("work", payload, timeout=30),
                    n_calls, concurrency)
                st = pool.stats()
                out["pool_tiers"] = sorted(r["tier"]
                                           for r in st["replicas"])
                out["pool_calls_per_replica"] = sorted(
                    r["calls"] for r in st["replicas"])
        registry.close()
    out["speedup_vs_single"] = out["pool_rps"] / max(out["single_rps"], 1e-9)
    return out


_OVERLOAD_WORKER_SRC = textwrap.dedent("""
    import queue, sys, threading, time
    sys.path.insert(0, sys.argv[1])
    from repro.core.executor import Engine
    from repro.fabric import ServiceInstance
    from repro.services.base import AdmissionController

    uris = sys.argv[2].split(";")
    registry, work_ms = sys.argv[3], float(sys.argv[4])
    n_threads, shed = int(sys.argv[5]), sys.argv[6] == "1"

    adm = AdmissionController()
    q = queue.Queue()
    active = [0]
    lock = threading.Lock()

    def worker():
        while True:
            handle, x = q.get()
            with lock:
                active[0] += 1
            t0 = time.monotonic()
            time.sleep(work_ms / 1e3)
            adm.observe(time.monotonic() - t0)   # pure service time
            try:
                handle.respond(x)
            except Exception:
                pass                    # caller gone (deadline passed)
            with lock:
                active[0] -= 1

    with Engine(uris) as e:
        def work(x, handle):
            # admission BEFORE taking ownership: a shed is a plain
            # MercuryError(OVERLOAD) response from the register wrapper
            if shed:
                adm.admit(handle.remaining_budget(),
                          backlog=q.qsize() + active[0],
                          parallelism=n_threads)
            handle.deferred = True
            q.put((handle, x))
        e.register("work", work, pass_handle=True)
        for _ in range(n_threads):
            threading.Thread(target=worker, daemon=True).start()
        inst = ServiceInstance(e, registry, "bench-overload",
                               capacity=n_threads, report_interval=0.2,
                               load_fn=lambda: float(q.qsize() + active[0]))
        print("URI " + e.uri, flush=True)
        sys.stdin.read()
        inst.close()
""")


def bench_pool_overload(n_workers: int = 3, work_ms: float = 100.0,
                        deadline_ms: float = 250.0, n_calls: int = 200,
                        concurrency: int = 32,
                        worker_threads: int = 2) -> Dict:
    """Overload scenario: offered load exceeds aggregate handler
    capacity (handlers are slower than the arrival rate), every call
    carries a deadline.  Two configurations of the SAME workload:

      * ``static``   — PR-2 fabric: fixed credits, locality balancer,
                       no server-side admission.  Servers accept
                       everything; queues grow; capacity is burned on
                       requests whose deadlines already passed.
      * ``adaptive`` — this PR: adaptive credits + EWMA-weighted
                       balancing + deadline-aware admission
                       (``Ret.OVERLOAD`` sheds, rerouted by the pool).

    Reported per variant: **goodput** (calls completed within their
    deadline / second), **deadline-miss rate**, and p50/p99 latency of
    the within-deadline completions.  The claim under test: adaptive +
    admission gives >= goodput and strictly lower miss rate."""
    from contextlib import ExitStack

    from repro.fabric import RegistryService, RetryPolicy, ServicePool

    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    deadline_s = deadline_ms / 1e3
    out: Dict = {"name": "routed_pool_overload", "workers": n_workers,
                 "worker_threads": worker_threads, "work_ms": work_ms,
                 "deadline_ms": deadline_ms, "calls": n_calls,
                 "concurrency": concurrency,
                 "capacity_rps": n_workers * worker_threads
                 / (work_ms / 1e3)}

    def run_variant(shed: bool, adaptive: bool, balancer: str) -> Dict:
        with Engine("tcp://127.0.0.1:0") as reg_engine:
            registry = RegistryService(reg_engine, instance_ttl=5.0)
            with ExitStack() as stack:
                for i in range(n_workers):
                    p = subprocess.Popen(
                        [sys.executable, "-c", _OVERLOAD_WORKER_SRC, src,
                         "tcp://127.0.0.1:0", reg_engine.uri, str(work_ms),
                         str(worker_threads), "1" if shed else "0"],
                        stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                        text=True)

                    def _stop(proc=p):
                        try:
                            proc.stdin.close()
                            proc.wait(timeout=10)
                        except Exception:
                            proc.kill()
                    stack.callback(_stop)
                    line = p.stdout.readline().strip()
                    if not line.startswith("URI "):
                        raise RuntimeError(f"overload worker failed: "
                                           f"{line!r}")
                with Engine("tcp://127.0.0.1:0") as cli:
                    pool = ServicePool(
                        cli, reg_engine.uri, "bench-overload",
                        balancer=balancer, credits_per_target=8,
                        adaptive_credits=adaptive, credit_max=32,
                        refresh_interval=0.2,
                        policy=RetryPolicy(attempts=3,
                                           rpc_timeout=deadline_s,
                                           backoff_base=0.01,
                                           jitter=0.5))
                    payload = b"x" * 64
                    pool.call("work", payload, timeout=5.0)      # warm
                    lats: List[float] = []
                    misses = [0]
                    mlock = threading.Lock()

                    def call_one(i):
                        t0 = time.perf_counter()
                        try:
                            pool.call("work", payload, timeout=deadline_s)
                            dt = time.perf_counter() - t0
                            if dt <= deadline_s:
                                with mlock:
                                    lats.append(dt)
                                return
                        except Exception:
                            pass
                        with mlock:
                            misses[0] += 1

                    import concurrent.futures as cf
                    t0 = time.perf_counter()
                    with cf.ThreadPoolExecutor(concurrency) as tp:
                        futs = [tp.submit(call_one, i)
                                for i in range(n_calls)]
                        for f in futs:
                            f.result(timeout=120)
                    wall = time.perf_counter() - t0
                    st = pool.stats()
            registry.close()
        good = sorted(lats)
        return {"goodput_rps": len(good) / wall,
                "miss_rate": misses[0] / n_calls,
                "completed_in_deadline": len(good),
                "wall_s": wall,
                "p50_ms": (good[len(good) // 2] * 1e3 if good else None),
                "p99_ms": (good[int(len(good) * 0.99)] * 1e3
                           if good else None),
                "replica_credits": sorted(
                    r.get("credits", 0) for r in st["replicas"])}

    out["static"] = run_variant(shed=False, adaptive=False,
                                balancer="locality")
    out["adaptive"] = run_variant(shed=True, adaptive=True,
                                  balancer="weighted")
    if out["static"]["goodput_rps"] > 0:
        out["goodput_gain_x"] = (out["adaptive"]["goodput_rps"]
                                 / out["static"]["goodput_rps"])
    out["miss_rate_delta"] = (out["adaptive"]["miss_rate"]
                              - out["static"]["miss_rate"])
    return out


def _poll_until(pred, timeout, msg, label="bench"):
    """Poll ``pred`` until truthy or ``timeout`` (shared by the
    control-plane chaos benchmarks)."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.02)
    raise RuntimeError(f"{label}: timed out on {msg}")


def bench_registry_failover(n_registries: int = 3, n_workers: int = 3,
                            work_ms: float = 15.0, duration_s: float = 8.0,
                            concurrency: int = 8,
                            lease_ttl: float = 0.6,
                            refresh_interval: float = 0.25) -> Dict:
    """Control-plane failover under routed load (DESIGN.md §8).

    A 3-replica registry quorum fronts ``n_workers`` service replicas;
    ``concurrency`` client threads drive routed calls continuously.  A
    third of the way in, the **leaseholder** registry is killed abruptly
    (no deregistration — its peers only learn via lease expiry).  The
    claim under test: zero client-visible resolution failures, client
    control-plane failover within one pool refresh interval (endpoint
    rotation is immediate), lease takeover within ~``lease_ttl``, and
    the pool's view resyncing onto the survivor's fresh epoch stream.
    """
    from repro.fabric import (RegistryService, RetryPolicy, ServiceInstance,
                              ServicePool)

    out: Dict = {"name": "registry_failover", "registries": n_registries,
                 "workers": n_workers, "work_ms": work_ms,
                 "duration_s": duration_s, "concurrency": concurrency,
                 "lease_ttl": lease_ttl,
                 "refresh_interval": refresh_interval}
    reg_engines = [Engine("tcp://127.0.0.1:0") for _ in range(n_registries)]
    peers = [e.uri for e in reg_engines]
    regs = [RegistryService(e, peers=peers, lease_ttl=lease_ttl,
                            gossip_interval=lease_ttl / 4,
                            sweep_interval=0.2, instance_ttl=5.0)
            for e in reg_engines]

    def _wait(pred, timeout, msg):
        _poll_until(pred, timeout, msg, label="registry_failover")

    workers, insts = [], []
    cli = Engine("tcp://127.0.0.1:0")
    try:
        _wait(lambda: regs[0].is_leader, 10.0, "initial leader election")
        for i in range(n_workers):
            w = Engine("tcp://127.0.0.1:0", handler_threads=2)
            w.register("work",
                       lambda x: time.sleep(work_ms / 1e3) or x)
            workers.append(w)
            insts.append(ServiceInstance(w, peers, "bench-rf", capacity=2,
                                         report_interval=0.2))
        pool = ServicePool(cli, peers, "bench-rf",
                           refresh_interval=refresh_interval,
                           policy=RetryPolicy(attempts=3, rpc_timeout=5.0,
                                              backoff_base=0.02))
        payload = b"x" * 64
        pool.call("work", payload, timeout=10.0)          # warm

        errors: List[str] = []
        counts = [0, 0]                   # calls before / after the kill
        killed = threading.Event()
        stop = threading.Event()
        lock = threading.Lock()

        def drive():
            while not stop.is_set():
                try:
                    pool.call("work", payload, timeout=5.0)
                    with lock:
                        counts[1 if killed.is_set() else 0] += 1
                except Exception as e:    # noqa: BLE001 — reported below
                    with lock:
                        errors.append(repr(e))

        # daemons: a failed assertion must not leave live driver threads
        # blocking interpreter exit (that reads as a CI hang)
        threads = [threading.Thread(target=drive, daemon=True)
                   for _ in range(concurrency)]
        for t in threads:
            t.start()
        time.sleep(duration_s / 3)

        # abrupt leaseholder kill: close the service, drop the engine
        leader_idx = next(i for i, r in enumerate(regs) if r.is_leader)
        regs[leader_idx].close()
        reg_engines[leader_idx].shutdown()
        t_kill = time.monotonic()
        killed.set()

        # client failover: the pool's registry client answers again the
        # moment its rotation lands on a survivor
        _wait(lambda: _epoch_ok(pool), refresh_interval + 3.0,
              "client control-plane failover")
        out["client_failover_s"] = time.monotonic() - t_kill
        survivors = [r for i, r in enumerate(regs) if i != leader_idx]
        _wait(lambda: any(r.is_leader for r in survivors),
              lease_ttl * 4 + 3.0, "lease takeover")
        out["leader_takeover_s"] = time.monotonic() - t_kill
        # read the survivor's nonce inside the predicate: a lease flap
        # around the kill can mint a transient stream that is replaced
        # by the post-kill takeover
        _wait(lambda: (pool.refresh(force=True) or any(
                  r.is_leader and pool._view_nonce == r.nonce
                  for r in survivors)),
              refresh_interval * 4 + 3.0, "pool view resync")
        out["view_resync_s"] = time.monotonic() - t_kill

        time.sleep(max(duration_s - (time.monotonic() - t_kill
                                     + duration_s / 3), 0.5))
        stop.set()
        for t in threads:
            t.join(timeout=30)
        out["calls_before_kill"] = counts[0]
        out["calls_after_kill"] = counts[1]
        out["resolution_errors"] = len(errors)
        out["converged_within_refresh"] = (out["client_failover_s"]
                                           <= refresh_interval)
        out["surviving_replicas"] = len(pool.replicas())
        if errors:
            out["first_errors"] = errors[:3]
        # the acceptance claim: the control-plane kill is invisible to
        # routed callers, and the pool is back on a live registry within
        # one refresh interval.  The hard assert carries a fixed
        # scheduling allowance for loaded CI runners; the strict
        # comparison is reported (converged_within_refresh) and trended
        # via the JSON artifact.
        assert not errors, f"client-visible failures: {errors[:3]}"
        assert out["client_failover_s"] <= refresh_interval + 1.0, \
            out["client_failover_s"]
        assert out["surviving_replicas"] == n_workers
    finally:
        for inst in insts:
            try:
                inst.close()
            except Exception:
                pass
        for r in regs:
            r.close()
        for e in workers + reg_engines:
            try:
                e.shutdown()
            except Exception:
                pass
        cli.shutdown()
    return out


def bench_gossip_churn(n_instances: int = 500, idle_s: float = 4.0,
                       churn_frac: float = 0.1,
                       gossip_interval: float = 0.1) -> Dict:
    """Control-plane gossip cost at scale (DESIGN.md §8).

    A 3-replica quorum carries ``n_instances`` registered instances with
    no reporters (steady state: nothing changes).  Measured per
    protocol: gossip bytes per round while **idle**, and while a
    ``churn_frac`` slice of the instances re-registers on new addresses.
    Full-state gossip ships the whole table on its periodic cadence —
    O(table) bytes/round however quiet the fabric is — while delta
    gossip ships bare heartbeats when idle and only the changed entries
    under churn.  The assert pins the headline claim: ≥10x fewer idle
    bytes/round, with both protocols fully converged.
    """
    from repro.fabric import RegistryClient, RegistryService

    out: Dict = {"name": "gossip_churn", "instances": n_instances,
                 "gossip_interval": gossip_interval, "replicas": 3,
                 "churn_frac": churn_frac}

    def _wait(pred, timeout, msg):
        _poll_until(pred, timeout, msg, label="gossip_churn")

    def measure(delta: bool) -> Dict:
        engines = [Engine("tcp://127.0.0.1:0") for _ in range(3)]
        peers = [e.uri for e in engines]
        regs = [RegistryService(e, peers=peers, lease_ttl=1.0,
                                gossip_interval=gossip_interval,
                                sweep_interval=1.0, instance_ttl=3600.0,
                                delta_gossip=delta)
                for e in engines]
        cli = Engine("tcp://127.0.0.1:0")
        res: Dict = {"protocol": "delta" if delta else "full"}
        try:
            _wait(lambda: regs[0].is_leader, 10.0, "leader election")
            c = RegistryClient(cli, peers[0], timeout=10.0)
            t0 = time.monotonic()
            for i in range(n_instances):
                c.register("churn", f"tcp://10.0.0.{i % 240 + 1}:{7000 + i}",
                           iid=f"i{i:05d}", capacity=1)
            res["register_s"] = round(time.monotonic() - t0, 3)
            _wait(lambda: all((r.epoch, r.nonce)
                              == (regs[0].epoch, regs[0].nonce)
                              for r in regs),
                  15.0, "follower convergence after registration")

            def window(seconds: float, label: str):
                time.sleep(3 * gossip_interval)   # drain in-flight rounds
                s0 = dict(regs[0].core.stats)
                time.sleep(seconds)
                s1 = dict(regs[0].core.stats)
                rounds = max(s1["rounds"] - s0["rounds"], 1)
                total = sum(s1[k] - s0[k] for k in
                            ("delta_bytes", "snapshot_bytes",
                             "heartbeat_bytes"))
                res[f"{label}_rounds"] = rounds
                res[f"{label}_bytes_per_round"] = round(total / rounds, 1)
                res[f"{label}_snapshot_pushes"] = (s1["snapshot_pushes"]
                                                   - s0["snapshot_pushes"])
                res[f"{label}_delta_pushes"] = (s1["delta_pushes"]
                                                - s0["delta_pushes"])

            window(idle_s, "idle")

            # churn: a slice of the fleet re-registers on new addresses
            # (a version-bumping membership change per instance)
            k = max(int(n_instances * churn_frac), 1)
            t0 = time.monotonic()
            s0 = dict(regs[0].core.stats)
            for j in range(k):
                c.register("churn",
                           f"tcp://10.0.1.{j % 240 + 1}:{9000 + j}",
                           iid=f"i{j:05d}", capacity=1)
            _wait(lambda: all((r.epoch, r.nonce)
                              == (regs[0].epoch, regs[0].nonce)
                              for r in regs),
                  15.0, "reconvergence after churn")
            s1 = dict(regs[0].core.stats)
            rounds = max(s1["rounds"] - s0["rounds"], 1)
            res["churn_registrations"] = k
            res["churn_s"] = round(time.monotonic() - t0, 3)
            res["churn_bytes_per_round"] = round(
                sum(s1[x] - s0[x] for x in ("delta_bytes",
                                            "snapshot_bytes",
                                            "heartbeat_bytes")) / rounds,
                1)
            res["converged"] = all((r.epoch, r.nonce)
                                   == (regs[0].epoch, regs[0].nonce)
                                   for r in regs)
        finally:
            for r in regs:
                r.close()
            for e in engines:
                try:
                    e.shutdown()
                except Exception:
                    pass
            cli.shutdown()
        return res

    out["full"] = measure(delta=False)
    out["delta"] = measure(delta=True)
    out["idle_reduction_x"] = round(
        out["full"]["idle_bytes_per_round"]
        / max(out["delta"]["idle_bytes_per_round"], 1.0), 1)
    out["churn_reduction_x"] = round(
        out["full"]["churn_bytes_per_round"]
        / max(out["delta"]["churn_bytes_per_round"], 1.0), 1)
    assert out["full"]["converged"] and out["delta"]["converged"]
    # the headline claim: idle delta gossip is ≥10x cheaper than
    # full-state at 500 instances (in practice it is heartbeat-only,
    # so the measured ratio is far larger)
    assert out["idle_reduction_x"] >= 10.0, out["idle_reduction_x"]
    return out


def _epoch_ok(pool) -> bool:
    try:
        pool.registry.epoch_info()
        return True
    except Exception:        # noqa: BLE001 — polled until rotation lands
        return False


def bench_rate(inflight_levels=(1, 2, 8, 32, 128)) -> Dict:
    """Small-RPC throughput vs number of in-flight requests."""
    out: Dict = {"name": "rpc_rate", "points": []}
    with Engine("tcp://127.0.0.1:0") as srv, \
            Engine("tcp://127.0.0.1:0") as cli:
        srv.register("tick", lambda x: x + 1)
        cli.call(srv.uri, "tick", 0)
        N = 600
        for infl in inflight_levels:
            t0 = time.perf_counter()
            done = 0
            pending = []
            i = 0
            while done < N:
                while len(pending) < infl and i < N:
                    pending.append(cli.call_async(srv.uri, "tick", i))
                    i += 1
                pending[0].result(timeout=30)
                pending.pop(0)
                done += 1
            dt = time.perf_counter() - t0
            out["points"].append({"inflight": infl, "rps": N / dt})
    return out


def bench_cached_resolve(n_threads: int = 4, n_reads: int = 250) -> Dict:
    """Client-side idempotent read cache (DESIGN.md §9): the same resolve
    storm with and without the cache, counting true registry round-trips
    server-side, then staleness probes across an epoch bump (new
    registration), a foreign write observed via a fresh epoch probe, and
    a nonce change (registry restart).  Run via ``--only cached_resolve``.
    """
    from repro.fabric.registry import RegistryClient, RegistryService

    out: Dict = {"name": "cached_resolve", "threads": n_threads,
                 "reads_per_thread": n_reads}
    tag = uuid.uuid4().hex[:8]
    reg_uri = f"self://bench-reg-{tag}"

    def start_registry(eng):
        reg = RegistryService(eng)
        served = [0]
        info = eng.hg._by_name["fab.resolve"]
        orig = info.handler

        def counting(handle):
            served[0] += 1
            orig(handle)

        info.handler = counting
        return reg, served

    def storm(client) -> float:
        errors: List[str] = []

        def run():
            try:
                for _ in range(n_reads):
                    if not client.resolve("svc")["instances"]:
                        errors.append("empty view")
                        return
            except Exception as e:      # noqa: BLE001 — surfaced below
                errors.append(repr(e))

        threads = [threading.Thread(target=run) for _ in range(n_threads)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        dt = time.perf_counter() - t0
        assert not errors, errors
        return dt

    reg_eng = Engine(reg_uri)
    cli_eng = Engine(None)
    reg = None
    try:
        reg, served = start_registry(reg_eng)
        writer = RegistryClient(cli_eng, reg_uri)
        writer.register("svc", ["self://inst-1"], iid="aaaaaaaaaaaa")

        # baseline: cache off — every resolve is a registry round-trip
        # (singleflight still collapses concurrent overlap, so this is
        # the honest "best you can do without caching" number)
        plain = RegistryClient(cli_eng, reg_uri, cache_ttl=0.0)
        plain.resolve("svc")                         # warm addr/session
        served[0] = 0
        dt = storm(plain)
        out["uncached_roundtrips"] = served[0]
        out["uncached_rps"] = n_threads * n_reads / dt

        # cached: TTL far above the storm duration; the token keeps it
        # honest (any epoch/nonce movement evicts)
        cached = RegistryClient(cli_eng, reg_uri, cache_ttl=60.0)
        cached.resolve("svc")                        # warm populates
        served[0] = 0
        dt = storm(cached)
        out["cached_roundtrips"] = served[0]
        out["cached_rps"] = n_threads * n_reads / dt
        out["roundtrip_reduction_x"] = round(
            out["uncached_roundtrips"] / max(out["cached_roundtrips"], 1), 1)

        stale = 0
        # probe 1 — own write: register bumps the epoch, the response's
        # token evicts, the very next read must see the new instance
        cached.register("svc", ["self://inst-2"], iid="bbbbbbbbbbbb")
        if len(cached.resolve("svc")["instances"]) != 2:
            stale += 1
        # probe 2 — foreign write observed via a fresh epoch probe (what
        # ServicePool's periodic load refresh does): must evict too
        writer.register("svc", ["self://inst-3"], iid="cccccccccccc")
        cached.epoch_info(fresh=True)
        if len(cached.resolve("svc")["instances"]) != 3:
            stale += 1
        # probe 3 — nonce change: restart the registry on the same uri.
        # The fresh instance starts from a LOWER epoch under a new nonce;
        # a bare epoch comparison would read it as stale and serve the
        # dead registry's view forever.
        reg.close()
        reg_eng.shutdown()
        reg_eng = Engine(reg_uri)
        reg, served = start_registry(reg_eng)
        writer2 = RegistryClient(cli_eng, reg_uri)
        writer2.register("svc", ["self://inst-9"], iid="dddddddddddd")
        cached.epoch_info(fresh=True)
        view = cached.resolve("svc")
        if [i["uris"] for i in view["instances"]] != [["self://inst-9"]]:
            stale += 1
        out["stale_reads"] = stale

        assert out["roundtrip_reduction_x"] >= 10.0, \
            (f"read cache only cut registry round-trips "
             f"{out['roundtrip_reduction_x']:.1f}x "
             f"({out['uncached_roundtrips']} -> "
             f"{out['cached_roundtrips']}); expected >= 10x")
        assert stale == 0, f"{stale} stale read(s) served after invalidation"
        return out
    finally:
        if reg is not None:
            reg.close()
        reg_eng.shutdown()
        cli_eng.shutdown()


def bench_trace_overhead(n_workers: int = 2, n_calls: int = 300,
                         work_ms: float = 30.0) -> Dict:
    """Telemetry-plane cost + cross-process reassembly (DESIGN.md §10).

    Part 1 — overhead: routed-pool RTT against in-process replicas with
    tracing *off* (machinery disabled), *unsampled* (ids propagate on
    every hop, nothing records — the production default path), and
    *100%-sampled*.  The three modes are interleaved **per call** —
    off/unsampled/sampled back to back for every call index — so each
    triplet shares its ambient load, and the overhead is the median of
    the *paired* per-call differences.  Scheduler noise that swings
    loopback RTTs by tens of percent cancels pairwise; the ≤5%
    unsampled budget is asserted on that paired median.

    Part 2 — reassembly: a hedged call against subprocess replicas
    (hedge delay ≪ service time, so both are always contacted), 100%
    sampled; the span tree is reassembled by unioning ``dbg.trace``
    rings from every worker with the client's own and must form ONE
    connected tree spanning client + both workers, with the hedge
    loser's attempt span closed CANCELED.  Run via
    ``--only trace_overhead``.
    """
    from contextlib import ExitStack

    from repro.fabric import (RegistryService, RetryPolicy, ServiceInstance,
                              ServicePool)
    from repro.telemetry import trace

    out: Dict = {"name": "trace_overhead", "calls_per_mode": n_calls}
    prev_sample, prev_enabled = trace.sample_rate(), trace.is_enabled()
    modes = ("off", "unsampled", "sampled")

    def _mode(m):
        if m == "off":
            trace.configure(enabled=False)
        else:
            trace.configure(enabled=True,
                            sample=0.0 if m == "unsampled" else 1.0)

    # ---- part 1: interleaved RTT medians, in-process replicas ----------
    lat = {m: [] for m in modes}
    with Engine("tcp://127.0.0.1:0") as reg_eng:
        registry = RegistryService(reg_eng, instance_ttl=10.0)
        reps = [Engine("tcp://127.0.0.1:0") for _ in range(n_workers)]
        insts = []
        try:
            for r in reps:
                r.register("work", lambda x: x)
                insts.append(ServiceInstance(r, reg_eng.uri, "bench-trace",
                                             capacity=8,
                                             report_interval=0.5))
            with Engine("tcp://127.0.0.1:0") as cli:
                pool = ServicePool(cli, reg_eng.uri, "bench-trace",
                                   balancer="rr",
                                   policy=RetryPolicy(attempts=3,
                                                      rpc_timeout=10.0))
                payload = b"x" * 64
                for _ in range(20):                        # warm all paths
                    pool.call("work", payload, timeout=10)
                for i in range(n_calls):
                    # rotate which mode leads so ordering bias cancels
                    for m in (modes[i % 3:] + modes[:i % 3]):
                        _mode(m)
                        t0 = time.perf_counter()
                        pool.call("work", payload, timeout=10)
                        lat[m].append(time.perf_counter() - t0)
        finally:
            trace.configure(sample=prev_sample, enabled=prev_enabled)
            for i in insts:
                i.close()
            for r in reps:
                r.shutdown()
            registry.close()

    for m in modes:
        out[f"{m}_rtt_us"] = statistics.median(lat[m]) * 1e6
    base = out["off_rtt_us"]
    for m in ("unsampled", "sampled"):
        paired_us = statistics.median(
            (b - a) for a, b in zip(lat["off"], lat[m])) * 1e6
        out[f"{m}_paired_delta_us"] = paired_us
        out[f"{m}_overhead_pct"] = paired_us / base * 100.0

    # ---- part 2: hedged call reassembled via dbg.trace -----------------
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "src")
    trace.configure(enabled=True, sample=1.0)
    trace.clear()
    try:
        with Engine("tcp://127.0.0.1:0") as reg_eng:
            registry = RegistryService(reg_eng, instance_ttl=10.0)
            with ExitStack() as stack:
                stack.callback(registry.close)
                worker_uris = []
                for _ in range(2):
                    p = subprocess.Popen(
                        [sys.executable, "-c", _POOL_WORKER_SRC, src,
                         "tcp://127.0.0.1:0", reg_eng.uri, str(work_ms)],
                        stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                        text=True)

                    def _stop(proc=p):
                        try:
                            proc.stdin.close()
                            proc.wait(timeout=10)
                        except Exception:
                            proc.kill()
                    stack.callback(_stop)
                    line = p.stdout.readline().strip()
                    if not line.startswith("URI "):
                        raise RuntimeError(f"trace worker failed: {line!r}")
                    worker_uris.append(line[4:])

                with Engine("tcp://127.0.0.1:0") as cli:
                    # hedge long before the 30ms service time completes:
                    # every call contacts BOTH replicas, the loser is
                    # canceled at the transport
                    pool = ServicePool(
                        cli, reg_eng.uri, "bench-pool", balancer="rr",
                        policy=RetryPolicy(attempts=3, rpc_timeout=10.0,
                                           hedge_after=0.005))
                    pool.call("work", b"y", timeout=10)      # warm
                    time.sleep(0.2)
                    trace.clear()
                    pool.call("work", b"y", timeout=10)
                    time.sleep(0.3)            # hedge loser settles

                    local = trace.export()["spans"]
                    root = next(s for s in local
                                if s["name"].startswith("pool."))
                    spans = [s for s in local
                             if s["trace"] == root["trace"]]
                    for u in worker_uris:
                        spans += cli.call(u, "dbg.trace",
                                          {"trace_id": root["trace"]},
                                          timeout=10)["spans"]
                    roots, _ = trace.build_tree(spans)
                    attempts = [s for s in spans
                                if s["name"].startswith("attempt.")]
                    out["reassembly"] = {
                        "span_count": len(spans),
                        "processes": len({s["pid"] for s in spans}),
                        "roots": len(roots),
                        "attempts": len(attempts),
                        "canceled": sum(1 for s in attempts
                                        if s["status"] == "CANCELED"),
                    }
    finally:
        trace.configure(sample=prev_sample, enabled=prev_enabled)
        trace.clear()

    rs = out["reassembly"]
    assert out["unsampled_overhead_pct"] <= 5.0, \
        (f"unsampled tracing adds {out['unsampled_paired_delta_us']:.1f}us "
         f"({out['unsampled_overhead_pct']:.1f}%) to the "
         f"{out['off_rtt_us']:.0f}us routed-pool RTT; budget is 5%")
    assert rs["roots"] == 1, \
        f"span tree is disconnected ({rs['roots']} roots)"
    assert rs["processes"] >= 3, \
        (f"trace only spans {rs['processes']} processes; expected client "
         f"+ 2 workers")
    assert rs["attempts"] >= 2 and rs["canceled"] >= 1, \
        (f"hedge not visible in trace: {rs['attempts']} attempts, "
         f"{rs['canceled']} canceled")
    return out


def _reachable(client) -> bool:
    """True once a registry client's endpoint answers ``fab.epoch``."""
    try:
        client.epoch(fresh=True)
        return True
    except Exception:  # noqa: BLE001 — readiness probe
        return False


_SHARD_SERVER_SRC = textwrap.dedent("""
    import sys
    sys.path.insert(0, sys.argv[1])
    from repro.launch import registry
    registry.main(sys.argv[2:])
""")


def _free_port_base(n: int, tries: int = 32) -> int:
    """A base port with ``n`` consecutive free TCP ports (the sharded
    launcher's port-offset convention needs a contiguous range)."""
    for _ in range(tries):
        with socket.socket() as probe:
            probe.bind(("127.0.0.1", 0))
            base = probe.getsockname()[1]
        if base + n >= 65536:
            continue
        socks = []
        try:
            for k in range(n):
                s = socket.socket()
                s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
                s.bind(("127.0.0.1", base + k))
                socks.append(s)
            return base
        except OSError:
            continue
        finally:
            for s in socks:
                s.close()
    raise RuntimeError("no contiguous free port range found")


def bench_registry_scale(n_instances: int = 10000, shard_counts=(1, 2, 4),
                         n_services: int = 64, client_threads: int = 8,
                         churn_s: float = 3.0, smoke: bool = False) -> Dict:
    """Control-plane write scaling across registry shards (DESIGN.md §12).

    For each shard count M, M single-node registry shards are spawned as
    *separate processes* (via ``launch.registry --shards M --shard-index
    k`` — the honest configuration: each shard quorum is its own
    leaseholder with its own event loop and its own interpreter).
    ``client_threads`` writer threads then register ``n_instances``
    instances across ``n_services`` service names through
    :class:`~repro.fabric.sharding.ShardedRegistryClient`, followed by a
    heartbeat-churn window (``fab.report`` load updates plus
    deregister/re-register cycles) with a sampler measuring resolve
    latency.  Reported per M: aggregate register and report throughput,
    p99 resolve latency, error count (must be 0).

    The headline assertion — >=2x aggregate write throughput at 4
    shards vs 1 — is a *parallel-scaling* claim, so it is enforced only
    where parallel execution is physically possible (>=4 usable cores,
    full mode).  Hosts below that still run and report, and the JSON
    records that the gate was skipped and why.
    """
    from repro.fabric.sharding import ShardedRegistryClient

    if smoke:
        n_instances, shard_counts, churn_s = 1000, (1, 2), 1.5
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                       "src")
    services = [f"svc-{i:03d}" for i in range(n_services)]
    out: Dict = {"name": "registry_scale", "instances": n_instances,
                 "services": n_services, "client_threads": client_threads,
                 "churn_s": churn_s, "points": []}

    for m in shard_counts:
        base = _free_port_base(m)
        spec = "|".join(f"tcp://127.0.0.1:{base + k}" for k in range(m))
        procs = [subprocess.Popen(
            [sys.executable, "-c", _SHARD_SERVER_SRC, src,
             "--listen", f"tcp://127.0.0.1:{base}", "--shards", str(m),
             "--shard-index", str(k), "--instance-ttl", "60",
             "--no-membership"],
            stdout=subprocess.DEVNULL, stderr=subprocess.STDOUT)
            for k in range(m)]
        cli = Engine("tcp://127.0.0.1:0")
        try:
            probe = ShardedRegistryClient(cli, spec, timeout=2.0)
            for shard_cli in probe.clients:
                _poll_until(lambda c=shard_cli: _reachable(c), 20.0,
                            "shard server up", label="registry_scale")

            errors: List[str] = []
            elock = threading.Lock()
            regs: List[List[Tuple[str, str]]] = [[] for _ in
                                                 range(client_threads)]
            start = threading.Barrier(client_threads + 1)

            def register_slice(t: int):
                c = ShardedRegistryClient(cli, spec, timeout=5.0)
                start.wait()
                for i in range(t, n_instances, client_threads):
                    svc = services[i % n_services]
                    try:
                        iid = c.register(svc, [f"tcp://10.0.0.1:{i}"],
                                         capacity=4, load=0.0)
                        regs[t].append((svc, iid))
                    except Exception as e:  # noqa: BLE001 — tallied
                        with elock:
                            errors.append(repr(e))

            threads = [threading.Thread(target=register_slice, args=(t,),
                                        daemon=True)
                       for t in range(client_threads)]
            for t in threads:
                t.start()
            start.wait()
            t0 = time.monotonic()
            for t in threads:
                t.join()
            reg_dt = time.monotonic() - t0

            # churn window: heartbeat load reports + re-register cycles
            # on every shard while a sampler times live resolves
            stop = threading.Event()
            report_n = [0] * client_threads

            def churn(t: int):
                c = ShardedRegistryClient(cli, spec, timeout=5.0)
                mine = regs[t]
                rng = random.Random(t)
                k = 0
                while not stop.is_set() and mine:
                    svc, iid = mine[rng.randrange(len(mine))]
                    try:
                        if k % 50 == 49:      # occasional re-register
                            c.register(svc, [f"tcp://10.0.0.1:{k}"],
                                       capacity=4, iid=iid)
                        else:
                            c.report(svc, iid, rng.random())
                        report_n[t] += 1
                    except Exception as e:  # noqa: BLE001 — tallied
                        with elock:
                            errors.append(repr(e))
                    k += 1

            lat_ms: List[float] = []

            def sample():
                c = ShardedRegistryClient(cli, spec, timeout=5.0)
                rng = random.Random(10_007)
                while not stop.is_set():
                    svc = services[rng.randrange(n_services)]
                    t1 = time.monotonic()
                    try:
                        c.resolve(svc, fresh=True)
                        lat_ms.append((time.monotonic() - t1) * 1e3)
                    except Exception as e:  # noqa: BLE001 — tallied
                        with elock:
                            errors.append(repr(e))

            churners = [threading.Thread(target=churn, args=(t,),
                                         daemon=True)
                        for t in range(client_threads)]
            sampler = threading.Thread(target=sample, daemon=True)
            c0 = time.monotonic()
            for t in churners:
                t.start()
            sampler.start()
            time.sleep(churn_s)
            stop.set()
            for t in churners:
                t.join(timeout=10.0)
            sampler.join(timeout=10.0)
            churn_dt = time.monotonic() - c0

            registered = sum(len(r) for r in regs)
            pt = {"shards": m,
                  "registered": registered,
                  "register_rps": registered / reg_dt,
                  "report_rps": sum(report_n) / churn_dt,
                  "resolve_p99_ms": (float(np.percentile(lat_ms, 99))
                                     if lat_ms else None),
                  "resolve_samples": len(lat_ms),
                  "errors": len(errors)}
            out["points"].append(pt)
            if errors:
                out.setdefault("error_samples", errors[:5])
            assert registered == n_instances, \
                f"registry_scale: {registered}/{n_instances} registered " \
                f"at {m} shards ({errors[:3]})"
            assert not errors, \
                f"registry_scale: {len(errors)} errors at {m} shards " \
                f"({errors[:3]})"
        finally:
            cli.shutdown()
            for p in procs:
                p.terminate()
            for p in procs:
                try:
                    p.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    p.kill()

    by_m = {pt["shards"]: pt for pt in out["points"]}
    if 1 in by_m and max(shard_counts) in by_m:
        hi = max(shard_counts)
        out["write_speedup_x"] = (by_m[hi]["register_rps"]
                                  / by_m[1]["register_rps"])
    cpus = len(os.sched_getaffinity(0)) if hasattr(os, "sched_getaffinity") \
        else (os.cpu_count() or 1)
    gate = (not smoke) and 4 in by_m and 1 in by_m and cpus >= 4
    out["scaling_gate"] = {
        "cpus": cpus, "asserted": gate,
        "reason": None if gate else
        ("smoke mode" if smoke else
         f"parallel-scaling assert needs >=4 usable cores, have {cpus}")}
    if gate:
        assert by_m[4]["register_rps"] >= 2.0 * by_m[1]["register_rps"], \
            f"registry_scale: 4-shard write throughput " \
            f"{by_m[4]['register_rps']:.0f}/s is not >=2x the 1-shard " \
            f"{by_m[1]['register_rps']:.0f}/s"
    return out


def bench_sm_burst(n_frames: int = 200) -> Dict:
    """Doorbell coalescing under burst: enqueue ``n_frames`` sm frames
    while the consumer is *not* progressing, and count FIFO doorbell
    writes.  The coalesced send path rings only on the ring's idle→busy
    transition (plus ring-full liveness probes), so a burst must cost
    O(1) bell syscalls, not one per frame — the ROADMAP item 4 claim.
    Asserted, not just measured: bells ≤ max(4, frames/10), and every
    frame still arrives once the consumer drains."""
    from repro.core.na import SMPlugin
    tag = uuid.uuid4().hex[:8]
    a = SMPlugin(f"sm://burst-a-{tag}")
    b = SMPlugin(f"sm://burst-b-{tag}")
    out: Dict = {"name": "sm_burst", "frames_sent": n_frames}
    try:
        got: List[bytes] = []
        for _ in range(n_frames):
            b.msg_recv_unexpected(
                lambda ret, src, t, data: got.append(bytes(data)))
        dst = a.addr_lookup(b.addr_self().uri)
        payload = b"y" * 64
        t0 = time.perf_counter()
        for i in range(n_frames):
            a.msg_send_unexpected(dst, payload, i, lambda ret: None)
        out["enqueue_us_per_frame"] = \
            (time.perf_counter() - t0) / n_frames * 1e6
        frames, bells = a.stat_frames, a.stat_bells
        deadline = time.monotonic() + 10.0
        while len(got) < n_frames and time.monotonic() < deadline:
            b.progress(0.05)
            a.progress(0.0)            # run send-side completions
        out.update(frames=frames, bells=bells,
                   delivered=len(got),
                   coalesce_x=frames / max(bells, 1))
        assert len(got) == n_frames, \
            f"sm_burst: {len(got)}/{n_frames} frames delivered"
        assert frames == n_frames, \
            f"sm_burst: counted {frames} tx frames, sent {n_frames}"
        assert bells <= max(4, n_frames // 10), \
            f"sm_burst: {bells} doorbell writes for {n_frames} queued " \
            f"frames — coalescing is not collapsing the burst"
    finally:
        a.finalize()
        b.finalize()
    return out


_SERVE_WORKER_SRC = textwrap.dedent("""
    import sys
    sys.path.insert(0, sys.argv[1])
    import jax
    import numpy as np
    from repro.core.executor import Engine
    from repro.configs.qwen1_5_0_5b import reduced
    from repro.models import Model
    from repro.serve.engine import ServeEngine
    from repro.services.gateway import ServingGateway
    uri, registry = sys.argv[2], sys.argv[3]
    chunk, cap, max_len = int(sys.argv[4]), int(sys.argv[5]), int(sys.argv[6])
    cfg = reduced()
    m = Model(cfg)
    pp = m.init(jax.random.PRNGKey(0))
    params = jax.tree_util.tree_map(
        lambda p: p.value, pp,
        is_leaf=lambda x: hasattr(x, "value") and hasattr(x, "axes"))
    serve = ServeEngine(m, params, max_len=max_len, n_slots=4,
                        chunk_tokens=chunk, session_cap=cap)
    # compile the chunk/decode/gather/scatter jits before serving (one
    # warm turn + one session resume) so XLA compile time never lands
    # inside a measured phase
    w = serve.generate([np.arange(8, dtype=np.int32)], max_new=2,
                       session_ids=["warm"])[0]
    p2 = np.concatenate([np.arange(8), np.asarray(w),
                         np.zeros(2)]).astype(np.int32)
    serve.generate([p2], max_new=2, session_ids=["warm"])
    with Engine(uri) as e:
        gw = ServingGateway(e, serve, registry=registry, service="gen-sess",
                            report_interval=0.2, shed_enabled=False)
        print("URI " + e.uri, flush=True)
        sys.stdin.read()
        gw.close()
""")


def bench_serve_session(n_replicas: int = 3, n_conversations: int = 8,
                        n_turns: int = 6, prompt_len: int = 384,
                        max_new: int = 2, smoke: bool = False) -> Dict:
    """Multi-turn serving over a routed pool: session-affine + KV-reuse
    vs naive re-prefill (tentpole proof for the session-affine data
    path).

    Both phases run against the SAME chunked-prefill gateways (chunking
    also bounds XLA recompiles, keeping the comparison honest); the only
    difference is the naive phase sends no ``session_id`` and routes
    every turn through the plain balancer, so every follow-up re-prefills
    its entire history on an arbitrary replica, while the affine phase
    routes follow-ups to the KV-holding replica and prefills only the
    suffix.  Asserts ≥2x multi-turn tokens/s, strictly lower follow-up
    TTFT p99, and — with a replica SIGKILLed mid-conversation — zero
    lost requests (affinity falls back to a fresh-prefill route)."""
    import concurrent.futures as cf
    from contextlib import ExitStack

    from repro.fabric import (RegistryService, RetryPolicy, ServicePool,
                              SessionAffinity)

    # a multi-turn chat is prefill-heavy by construction: a long shared
    # history (the part session reuse deletes) and a few new tokens per
    # turn — mirroring the regime the tentpole targets.  max_new stays
    # small on purpose: decode steps cost the same in both phases, so
    # they only dilute the prefill-reuse signal this bench isolates
    if smoke:
        n_conversations, n_turns = 6, 5
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    max_len = 512
    chunk, session_cap = 32, 8
    out: Dict = {"name": "serve_session", "replicas": n_replicas,
                 "conversations": n_conversations, "turns": n_turns,
                 "prompt_len": prompt_len, "max_new": max_new,
                 "chunk_tokens": chunk, "session_cap": session_cap}
    rng = random.Random(7)

    def fresh_tokens(n):
        return [rng.randrange(1, 500) for _ in range(n)]

    with Engine("tcp://127.0.0.1:0") as reg_engine:
        registry = RegistryService(reg_engine, instance_ttl=3.0)
        with ExitStack() as stack:
            procs = []
            for i in range(n_replicas):
                p = subprocess.Popen(
                    [sys.executable, "-c", _SERVE_WORKER_SRC, src,
                     "tcp://127.0.0.1:0", reg_engine.uri, str(chunk),
                     str(session_cap), str(max_len)],
                    stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True)

                def _stop(proc=p):
                    try:
                        proc.stdin.close()
                        proc.wait(timeout=10)
                    except Exception:
                        proc.kill()
                stack.callback(_stop)
                line = p.stdout.readline().strip()
                if not line.startswith("URI "):
                    raise RuntimeError(f"serve worker failed: {line!r}")
                procs.append(p)

            with Engine("tcp://127.0.0.1:0") as cli:
                # rr, not least: a turn fans all conversations out at
                # the same instant, so load-ranked placement is a race
                # on stale signals — round-robin spreads turn-0 evenly
                # and the affinity layer keeps follow-ups put
                # fixed credits: gen.generate intentionally holds a call
                # open for a full generation, which the adaptive gate's
                # latency heuristic would misread as congestion and
                # serialize conversations per replica
                pool = ServicePool(cli, reg_engine.uri, "gen-sess",
                                   balancer="rr",
                                   credits_per_target=8,
                                   adaptive_credits=False,
                                   policy=RetryPolicy(attempts=4,
                                                      rpc_timeout=120.0))
                pool.call("gen.stats", {}, timeout=30)        # warm view

                def run_phase(affine, tag, extra_turns=0, kill_at=None):
                    """One full pass of multi-turn conversations; returns
                    throughput + TTFT stats.  ``kill_at`` SIGKILLs a
                    replica before that turn index (affine fallback
                    path)."""
                    aff = SessionAffinity(pool) if affine else None
                    hist = [fresh_tokens(prompt_len)
                            for _ in range(n_conversations)]
                    ttft_all, ttft_follow = [], []
                    new_tokens = 0
                    turns = n_turns + extra_turns

                    def one_turn(ci, t):
                        sid = f"{tag}-conv{ci}"
                        arg = {"tokens": hist[ci], "max_new": max_new,
                               "session_id": sid if affine else None}
                        if affine:
                            res, _iid = aff.call_routed(
                                sid, "gen.generate", arg, timeout=180)
                        else:
                            res = pool.call("gen.generate", arg,
                                            timeout=180)
                        return ci, res

                    t0 = time.perf_counter()
                    for t in range(turns):
                        if kill_at is not None and t == kill_at:
                            procs[0].kill()   # replica death mid-dialogue
                        with cf.ThreadPoolExecutor(n_conversations) as tp:
                            futs = [tp.submit(one_turn, ci, t)
                                    for ci in range(n_conversations)]
                            for f in futs:
                                ci, res = f.result(timeout=300)
                                assert res["done"], \
                                    f"turn {t} conv {ci} incomplete"
                                assert len(res["tokens"]) == max_new
                                hist[ci] = (hist[ci] + res["tokens"]
                                            + fresh_tokens(4))
                                new_tokens += len(res["tokens"])
                                ttft_all.append(res["ttft_ms"])
                                if t > 0:
                                    ttft_follow.append(res["ttft_ms"])
                    wall = time.perf_counter() - t0
                    srt = sorted(ttft_follow)
                    return {"tokens_per_s": new_tokens / wall,
                            "wall_s": wall,
                            "turns_completed": turns * n_conversations,
                            "ttft_p50_ms": srt[len(srt) // 2],
                            "ttft_p99_ms": srt[min(int(len(srt) * 0.99),
                                                   len(srt) - 1)],
                            "ttft_max_ms": max(ttft_all)}

                # naive first (cold session tables on both phases would
                # only help naive; running it first also leaves the
                # affine phase a warm steady-state view)
                out["naive"] = run_phase(False, "naive")
                out["affine"] = run_phase(True, "affine")

                # server-side proof the win came from prefix reuse
                hits = misses = saved = 0
                for rep in pool.replicas():
                    try:
                        st = pool.call_on(rep.iid, "gen.stats", {},
                                          timeout=10)
                    except Exception:
                        continue
                    hits += st["prefix_hits"]
                    misses += st["prefix_misses"]
                    saved += st["prefix_tokens_saved"]
                out["prefix_hits"] = hits
                out["prefix_misses"] = misses
                out["prefix_tokens_saved"] = saved

                # replica-kill: fresh affine conversations, one replica
                # SIGKILLed between turns 1 and 2 — every turn must still
                # complete (the affinity layer re-homes the session and
                # the engine re-prefills from scratch)
                out["killed_replica"] = True
                kill = run_phase(True, "kill", extra_turns=0, kill_at=2)
                out["kill_phase"] = {
                    "turns_completed": kill["turns_completed"],
                    "turns_expected": n_turns * n_conversations,
                    "tokens_per_s": kill["tokens_per_s"]}

        registry.close()

    out["speedup_tokens_per_s"] = (out["affine"]["tokens_per_s"]
                                   / max(out["naive"]["tokens_per_s"],
                                         1e-9))
    out["ttft_p99_reduction_x"] = (out["naive"]["ttft_p99_ms"]
                                   / max(out["affine"]["ttft_p99_ms"],
                                         1e-9))
    assert out["speedup_tokens_per_s"] >= 2.0, \
        f"serve_session: affine+chunked is only " \
        f"{out['speedup_tokens_per_s']:.2f}x naive tokens/s (need >=2x)\n" \
        f"  naive:  {out['naive']}\n  affine: {out['affine']}\n" \
        f"  hits={out['prefix_hits']} misses={out['prefix_misses']} " \
        f"saved={out['prefix_tokens_saved']}"
    assert out["affine"]["ttft_p99_ms"] < out["naive"]["ttft_p99_ms"], \
        f"serve_session: follow-up TTFT p99 {out['affine']['ttft_p99_ms']:.1f}ms " \
        f"not below naive {out['naive']['ttft_p99_ms']:.1f}ms"
    assert out["prefix_hits"] > 0, \
        "serve_session: no server-side prefix hits recorded"
    assert (out["kill_phase"]["turns_completed"]
            == out["kill_phase"]["turns_expected"]), \
        f"serve_session: lost requests across replica kill " \
        f"({out['kill_phase']})"
    return out


def run_all(verbose=True, transports=("self", "sm", "tcp"),
            smoke=False, only=None) -> List[Dict]:
    unknown = [t for t in transports if t not in ("self", "sm", "tcp")]
    if unknown:
        raise SystemExit(f"unknown transport(s) {unknown}; "
                         f"choose from self, sm, tcp")
    known_benches = ("latency", "bandwidth", "rate", "pool", "overload",
                     "registry_failover", "gossip_churn", "cached_resolve",
                     "trace_overhead", "registry_scale", "sm_burst",
                     "serve_session")
    if only:
        bad = [b for b in only if b not in known_benches]
        if bad:
            raise SystemExit(f"unknown bench(es) {bad}; "
                             f"choose from {known_benches}")

    def want(name):
        # default set keeps the PR-2 behavior: the chaos/scale scenarios
        # (overload, registry_failover, gossip_churn, cached_resolve)
        # are opt-in
        return (name in only if only
                else name not in ("overload", "registry_failover",
                                  "gossip_churn", "cached_resolve",
                                  "trace_overhead", "registry_scale",
                                  "serve_session"))

    iters = 50 if smoke else 200
    sizes = (4 << 10, 1 << 20) if smoke else \
        (4 << 10, 64 << 10, 1 << 20, 16 << 20, 64 << 20)
    results = []
    if want("latency"):
        results.append(bench_latency(transports=transports, iters=iters))
    if want("bandwidth"):
        for t in transports:
            if t in ("sm", "tcp"):
                results.append(bench_bandwidth(sizes=sizes, transport=t))
    if want("rate") and not smoke:
        results.append(bench_rate())
    if want("pool"):
        results.append(bench_pool(n_calls=150 if smoke else 450))
    if want("overload"):
        results.append(bench_pool_overload(
            n_calls=160 if smoke else 320))
    if want("registry_failover"):
        results.append(bench_registry_failover(
            duration_s=5.0 if smoke else 8.0))
    if want("gossip_churn"):
        results.append(bench_gossip_churn(
            idle_s=3.0 if smoke else 6.0))
    if want("cached_resolve"):
        results.append(bench_cached_resolve(
            n_reads=100 if smoke else 250))
    if want("trace_overhead"):
        results.append(bench_trace_overhead(
            n_calls=150 if smoke else 450))
    if want("registry_scale"):
        results.append(bench_registry_scale(smoke=smoke))
    if want("sm_burst"):
        results.append(bench_sm_burst(n_frames=100 if smoke else 200))
    if want("serve_session"):
        results.append(bench_serve_session(smoke=smoke))
    if verbose:
        lat = next((r for r in results if r["name"] == "rpc_latency"), None)
        if lat is not None:
            parts = [f"raw tcp rtt {lat['raw_tcp_rtt_us']:.0f}us"]
            for t in transports:
                parts.append(f"mercury {t} {lat[f'{t}_rtt_us']:.0f}us "
                             f"(inline {lat[f'{t}_inline_rtt_us']:.0f}us)")
            print("[latency] " + " | ".join(parts))
            if "sm_speedup_vs_tcp" in lat:
                print(f"[latency] sm is {lat['sm_speedup_vs_tcp']:.2f}x "
                      f"faster than tcp loopback for small RPCs")
            if "self_local_speedup_x" in lat:
                print(f"[latency] self-tier dispatch is "
                      f"{lat['self_local_speedup_x']:.2f}x faster than "
                      f"the co-located wire path "
                      f"({lat['self_wire_rtt_us']:.0f}us)")
        for res in results:
            if res["name"] != "bulk_bandwidth":
                continue
            print(f"[bandwidth/{res['transport']}] "
                  f"(size, mode, chunk, inflight) -> MB/s")
            for p in res["points"]:
                if p["mode"] == "bulk":
                    print(f"   {p['size'] >> 10:8d}KiB bulk  "
                          f"c={p['chunk'] >> 10}KiB "
                          f"i={p['inflight']}  {p['MBps']:8.0f}")
                else:
                    print(f"   {p['size'] >> 10:8d}KiB eager              "
                          f"{p['MBps']:8.0f}")
        for res in results:
            if res["name"] == "rpc_rate":
                print("[rate] inflight -> req/s")
                for p in res["points"]:
                    print(f"   {p['inflight']:4d} -> {p['rps']:7.0f}")
            if res["name"] == "routed_pool":
                print(f"[pool] 1 client -> {res['workers']} replicas "
                      f"(tiers {res.get('pool_tiers')}), "
                      f"{res['work_ms']:.0f}ms/handler, "
                      f"{res['concurrency']} in flight:")
                print(f"   single endpoint {res['single_rps']:7.0f} rps | "
                      f"routed pool {res['pool_rps']:7.0f} rps | "
                      f"{res['speedup_vs_single']:.2f}x  "
                      f"(calls/replica {res['pool_calls_per_replica']})")
            if res["name"] == "registry_failover":
                print(f"[registry_failover] {res['registries']}-replica "
                      f"quorum, leaseholder killed mid-run under "
                      f"{res['concurrency']}-way routed load:")
                print(f"   {res['calls_before_kill']} calls before / "
                      f"{res['calls_after_kill']} after the kill | "
                      f"resolution errors {res['resolution_errors']} | "
                      f"client failover {res['client_failover_s'] * 1e3:.0f}"
                      f"ms (refresh {res['refresh_interval'] * 1e3:.0f}ms) | "
                      f"lease takeover {res['leader_takeover_s'] * 1e3:.0f}"
                      f"ms | view resync {res['view_resync_s'] * 1e3:.0f}ms")
            if res["name"] == "gossip_churn":
                print(f"[gossip_churn] {res['instances']} instances on a "
                      f"{res['replicas']}-replica quorum "
                      f"(gossip every {res['gossip_interval'] * 1e3:.0f}ms):")
                for proto in ("full", "delta"):
                    v = res[proto]
                    print(f"   {proto:6s} idle "
                          f"{v['idle_bytes_per_round']:9.0f} B/round "
                          f"(snapshots {v['idle_snapshot_pushes']}, "
                          f"deltas {v['idle_delta_pushes']}) | churn "
                          f"{v['churn_bytes_per_round']:9.0f} B/round")
                print(f"   delta is {res['idle_reduction_x']:.0f}x "
                      f"cheaper idle, {res['churn_reduction_x']:.1f}x "
                      f"under {res['full']['churn_registrations']}-"
                      f"instance churn")
            if res["name"] == "cached_resolve":
                print(f"[cached_resolve] {res['threads']} threads x "
                      f"{res['reads_per_thread']} resolves each:")
                print(f"   uncached {res['uncached_roundtrips']:5d} "
                      f"round-trips ({res['uncached_rps']:7.0f} rps) | "
                      f"cached {res['cached_roundtrips']:3d} round-trips "
                      f"({res['cached_rps']:7.0f} rps)")
                print(f"   {res['roundtrip_reduction_x']:.0f}x fewer "
                      f"registry round-trips | stale reads "
                      f"{res['stale_reads']} across epoch bump, foreign "
                      f"write, and registry restart")
            if res["name"] == "trace_overhead":
                print(f"[trace_overhead] routed-pool RTT over "
                      f"{res['calls_per_mode']} calls/mode "
                      f"(per-call interleaved):")
                print(f"   off {res['off_rtt_us']:.0f}us | unsampled "
                      f"{res['unsampled_paired_delta_us']:+.1f}us "
                      f"({res['unsampled_overhead_pct']:+.1f}%) | sampled "
                      f"{res['sampled_paired_delta_us']:+.1f}us "
                      f"({res['sampled_overhead_pct']:+.1f}%)  "
                      f"[paired medians]")
                rs = res["reassembly"]
                print(f"   hedged call reassembled via dbg.trace: "
                      f"{rs['span_count']} spans, {rs['processes']} "
                      f"processes, {rs['roots']} root, {rs['attempts']} "
                      f"attempts ({rs['canceled']} canceled)")
            if res["name"] == "registry_scale":
                print(f"[registry_scale] {res['instances']} instances "
                      f"across {res['services']} services, "
                      f"{res['client_threads']} writer threads, "
                      f"{res['churn_s']:.1f}s churn window:")
                for pt in res["points"]:
                    p99 = (f"{pt['resolve_p99_ms']:.1f}ms"
                           if pt["resolve_p99_ms"] is not None else "n/a")
                    print(f"   shards={pt['shards']}  register "
                          f"{pt['register_rps']:7.0f}/s | report "
                          f"{pt['report_rps']:7.0f}/s | p99 resolve "
                          f"{p99} ({pt['resolve_samples']} samples) | "
                          f"errors {pt['errors']}")
                gate = res["scaling_gate"]
                if "write_speedup_x" in res:
                    tail = (f"(>=2x gate asserted, {gate['cpus']} cores)"
                            if gate["asserted"]
                            else f"(gate skipped: {gate['reason']})")
                    print(f"   write speedup "
                          f"{res['write_speedup_x']:.2f}x {tail}")
            if res["name"] == "sm_burst":
                print(f"[sm_burst] {res['frames_sent']} frames queued "
                      f"against a sleeping consumer: {res['bells']} "
                      f"doorbell writes ({res['coalesce_x']:.0f}x "
                      f"coalesced), {res['delivered']} delivered")
            if res["name"] == "serve_session":
                for variant in ("naive", "affine"):
                    v = res[variant]
                    print(f"[serve_session] {variant:6s} "
                          f"{v['tokens_per_s']:7.1f} tok/s | follow-up "
                          f"TTFT p50 {v['ttft_p50_ms']:.0f}ms "
                          f"p99 {v['ttft_p99_ms']:.0f}ms")
                print(f"[serve_session] affine+chunked is "
                      f"{res['speedup_tokens_per_s']:.2f}x tokens/s, "
                      f"TTFT p99 {res['ttft_p99_reduction_x']:.1f}x lower "
                      f"| prefix hits {res['prefix_hits']} "
                      f"({res['prefix_tokens_saved']} tokens saved) | "
                      f"replica-kill: "
                      f"{res['kill_phase']['turns_completed']}/"
                      f"{res['kill_phase']['turns_expected']} turns "
                      f"survived")
            if res["name"] == "routed_pool_overload":
                print(f"[overload] {res['workers']}x{res['worker_threads']}"
                      f" handlers @ {res['work_ms']:.0f}ms, "
                      f"{res['concurrency']} callers, "
                      f"{res['deadline_ms']:.0f}ms deadlines "
                      f"(capacity ~{res['capacity_rps']:.0f} rps):")
                for variant in ("static", "adaptive"):
                    v = res[variant]
                    p99 = (f"{v['p99_ms']:.0f}ms" if v["p99_ms"] is not None
                           else "n/a")
                    print(f"   {variant:8s} goodput {v['goodput_rps']:6.1f}"
                          f" rps | miss rate {v['miss_rate']:.1%} | "
                          f"p99(good) {p99} | credits "
                          f"{v['replica_credits']}")
    return results


if __name__ == "__main__":
    import argparse
    import json
    ap = argparse.ArgumentParser(description="Mercury core microbenchmarks")
    ap.add_argument("--transports", default="self,sm,tcp",
                    help="comma-separated subset of self,sm,tcp")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced iterations/sizes (CI)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write results as JSON (CI perf artifact)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of "
                         "latency,bandwidth,rate,pool,overload,"
                         "registry_failover,gossip_churn,cached_resolve,"
                         "trace_overhead,registry_scale,sm_burst,"
                         "serve_session")
    args = ap.parse_args()
    res = run_all(transports=tuple(args.transports.split(",")),
                  smoke=args.smoke,
                  only=tuple(args.only.split(",")) if args.only else None)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2)
        print(f"[json] wrote {args.json}")
