"""EXPERIMENTS.md table generator: §Dry-run and §Roofline fragments from
experiments/dryrun/*.json, plus variant (hillclimb) comparisons.

    PYTHONPATH=src python -m benchmarks.report > experiments/report.md
"""
from __future__ import annotations

import json
from pathlib import Path

from . import roofline

DRYRUN = Path("experiments/dryrun")
GB = 1 << 30


def dryrun_table(mesh: str) -> str:
    rows = []
    for f in sorted(DRYRUN.glob(f"*_{mesh}.json")):
        if "__" in f.name:
            continue
        r = json.loads(f.read_text())
        mem = r.get("memory", {})
        rows.append(
            f"| {r['arch']} | {r['shape']} | {'✓' if r['ok'] else '✗'} | "
            f"{r.get('compile_s', '—')} | "
            f"{mem.get('argument_size_in_bytes', 0) / GB:.2f} | "
            f"{mem.get('temp_size_in_bytes', 0) / GB:.2f} | "
            f"{r.get('state_bytes_analytic', 0) / GB:.2f} |")
    hdr = ("| arch | shape | compiled | s | args GB/dev | temp GB/dev | "
           "state GB/dev (analytic) |\n|---|---|---|---|---|---|---|\n")
    return hdr + "\n".join(rows)


def variant_table() -> str:
    out = []
    for f in sorted(DRYRUN.glob("*__*.json")):
        v = json.loads(f.read_text())
        base_name = f.name.split("__")[0] + ".json"
        b = json.loads((DRYRUN / base_name).read_text())
        fb, fv = b.get("cost_fit"), v.get("cost_fit")
        if not (fb and fv):
            continue
        out.append(
            f"| {v['arch']} {v['shape']} | {v.get('variant')} | "
            f"{fb['flops']:.3g}→{fv['flops']:.3g} | "
            f"{fb['bytes']:.3g}→{fv['bytes']:.3g} | "
            f"{fb['coll_wire']:.3g}→{fv['coll_wire']:.3g} | "
            f"{b['memory']['temp_size_in_bytes'] / GB:.1f}→"
            f"{v['memory']['temp_size_in_bytes'] / GB:.1f} |")
    hdr = ("| cell | variant | flops/dev | bytes/dev | coll wire/dev | "
           "temp GB/dev |\n|---|---|---|---|---|---|\n")
    return hdr + "\n".join(out)


def main():
    print("## Dry-run (single-pod 16×16 = 256 chips)\n")
    print(dryrun_table("single"))
    print("\n## Dry-run (multi-pod 2×16×16 = 512 chips)\n")
    print(dryrun_table("multi"))
    print("\n## Roofline (single-pod)\n")
    rows = roofline.load_all("single")
    print(roofline.table(rows))
    print("\n## Variants (hillclimb measurements)\n")
    print(variant_table())


if __name__ == "__main__":
    main()
