"""Service-level benchmarks: checkpoint push/pull throughput (bulk layer
under a real workload), datafeed eager/bulk crossover, serving gateway
tokens/s vs slot count."""
from __future__ import annotations

import time
from typing import Dict

import jax
import numpy as np

from repro import configs
from repro.core.executor import Engine
from repro.data.pipeline import SyntheticSource
from repro.models import Model, unzip
from repro.serve.engine import ServeEngine
from repro.services import (CheckpointClient, CheckpointServer,
                            DataFeedClient, DataFeedServer, ServingGateway)


def bench_checkpoint(sizes_mb=(4, 32, 128)) -> Dict:
    out: Dict = {"name": "checkpoint", "points": []}
    with Engine("tcp://127.0.0.1:0") as srv_e, \
            Engine("tcp://127.0.0.1:0") as cli_e:
        CheckpointServer(srv_e)
        cli = CheckpointClient(cli_e, srv_e.uri)
        for mb in sizes_mb:
            n = mb * (1 << 20) // 4
            tree = {"w": np.random.default_rng(0)
                    .standard_normal(n).astype(np.float32)}
            t0 = time.perf_counter()
            cli.save("bench", mb, tree)
            t_save = time.perf_counter() - t0
            tpl = {"w": np.zeros(n, np.float32)}
            t0 = time.perf_counter()
            restored, _ = cli.restore("bench", tpl, step=mb)
            t_load = time.perf_counter() - t0
            assert np.array_equal(restored["w"], tree["w"])
            out["points"].append({
                "MB": mb,
                "save_MBps": mb / t_save,
                "restore_MBps": mb / t_load,
            })
    return out


def bench_datafeed(batch_sizes=(2, 16, 64)) -> Dict:
    """Step-fetch latency across the eager/bulk crossover."""
    out: Dict = {"name": "datafeed", "points": []}
    with Engine("tcp://127.0.0.1:0") as fe, Engine("tcp://127.0.0.1:0") as tr:
        for bs in batch_sizes:
            src = SyntheticSource(vocab=32000, seq_len=1024,
                                  batch_per_host=bs)
            DataFeedServer(fe, src)
            cli = DataFeedClient(tr, [fe.uri], depth=2)
            cli.get(0)                                   # warm + prefetch
            t0 = time.perf_counter()
            for s in range(1, 9):
                cli.get(s)
            dt = (time.perf_counter() - t0) / 8
            nbytes = sum(v.nbytes for v in src.batch_at(0).values())
            out["points"].append({
                "batch": bs, "batch_KB": nbytes >> 10,
                "mode": "eager" if nbytes <= 256 * 1024 else "bulk",
                "ms_per_step": dt * 1e3,
                "MBps": nbytes / dt / 1e6})
    return out


def bench_serving(slot_counts=(1, 2, 4)) -> Dict:
    """Continuous-batching throughput (decode steps amortized over slots)."""
    cfg = configs.reduced("qwen1.5-0.5b")
    model = Model(cfg)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    out: Dict = {"name": "serving", "points": []}
    rng = np.random.default_rng(0)
    for slots in slot_counts:
        eng = ServeEngine(model, params, max_len=96, n_slots=slots)
        prompts = [rng.integers(1, cfg.vocab, size=6) for _ in range(8)]
        eng.generate(prompts[:1], max_new=2)             # compile warm-up
        t0 = time.perf_counter()
        outs = eng.generate(prompts, max_new=16)
        dt = time.perf_counter() - t0
        toks = sum(len(o) for o in outs)
        out["points"].append({"slots": slots, "tok_s": toks / dt})
    return out


def run_all(verbose=True):
    results = [bench_checkpoint(), bench_datafeed(), bench_serving()]
    if verbose:
        print("[checkpoint] MB -> save MB/s, restore MB/s")
        for p in results[0]["points"]:
            print(f"   {p['MB']:4d} -> {p['save_MBps']:7.0f}, "
                  f"{p['restore_MBps']:7.0f}")
        print("[datafeed] batch -> KB, mode, ms/step")
        for p in results[1]["points"]:
            print(f"   {p['batch']:3d} -> {p['batch_KB']:7d}KB {p['mode']:5s}"
                  f" {p['ms_per_step']:7.1f}ms {p['MBps']:6.0f}MB/s")
        print("[serving] slots -> tok/s")
        for p in results[2]["points"]:
            print(f"   {p['slots']:2d} -> {p['tok_s']:6.1f}")
    return results
