"""Roofline analysis from the dry-run JSON records.

Per (arch × shape), single-pod mesh (256 chips of TPU v5e):
  compute   = HLO_FLOPs / peak_FLOPs                  [per chip, seconds]
  memory    = HLO_bytes / HBM_bw                      [per chip, seconds]
  collective= wire_bytes / (links_per_ring × link_bw) [per chip, seconds]

FLOPs/bytes/wire come from the dry-run's 2-point unrolled-depth linear
fit (exact at full depth; see launch/dryrun.py).  The memory term is
reported twice:
  * ``mem_hlo``   — straight XLA "bytes accessed" (includes the S×T score
    materialization of the *CPU-lowered* attention; an upper bound);
  * ``mem_adj``   — kernel-adjusted: the attention-score materialization
    bytes are replaced by the Pallas flash kernel's actual HBM traffic
    (q,k,v read once per q-block pass + o written), which is what the TPU
    target executes.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from repro import configs
from repro.configs.base import SHAPES
from repro.launch.mesh import HW

DRYRUN_DIR = Path("experiments/dryrun")
CHIPS_SINGLE = 256


def attention_adjustment(arch: str, shape_name: str) -> Dict[str, float]:
    """Estimate (per device) the cost-mode attention materialization bytes
    and the flash-kernel replacement traffic."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        # decode materializes (B,H,1,T) logits — tiny; no adjustment
        return {"mat": 0.0, "flash": 0.0}
    n_dev = CHIPS_SINGLE
    # per-kind effective kv length
    mat = 0.0
    flash = 0.0
    fwd_bwd = 3.0 if shape.kind == "train" else 1.0     # bwd ~2x fwd
    # ~6 materialized (B,H,S,T)-sized f32 tensors across fwd+bwd softmax
    K_MAT = 6.0 if shape.kind == "train" else 3.0
    D = cfg.hd
    for i in range(cfg.n_layers):
        kind = cfg.kind_at(i)
        if kind not in ("attn", "local", "global"):
            continue
        T_eff = min(2 * cfg.window, S) if kind == "local" else S
        mat += B * cfg.n_heads * S * T_eff * 4.0 * K_MAT
        # flash: q read once, k/v read once per q-block sweep (block 128),
        # o written once — per head-dim D bytes bf16
        passes = max(S // 128, 1)
        flash += fwd_bwd * B * 2.0 * (
            cfg.n_heads * S * D + cfg.n_kv_heads * T_eff * D * 1) \
            + B * cfg.n_kv_heads * T_eff * D * 2.0 * passes * 0.0
        # conservative flash traffic: q+o (+dq etc) once, k/v once per pass
        flash += fwd_bwd * B * cfg.n_kv_heads * T_eff * D * 2.0
    if cfg.n_enc_layers:
        F = cfg.frontend_seq
        mat += cfg.n_enc_layers * B * cfg.n_heads * F * F * 4.0 * K_MAT
        mat += cfg.n_layers * B * cfg.n_heads * S * F * 4.0 * K_MAT
    return {"mat": mat / n_dev, "flash": flash / n_dev}


def analyze_record(rec: dict) -> Optional[dict]:
    if not rec.get("ok") or "cost_fit" not in rec:
        return None
    arch, shape_name = rec["arch"], rec["shape"]
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    fit = rec["cost_fit"]

    flops = fit["flops"]
    bytes_hlo = fit["bytes"]
    wire = fit["coll_wire"]

    adj = attention_adjustment(arch, shape_name)
    bytes_adj = max(bytes_hlo - adj["mat"] + adj["flash"], 0.0)

    t_compute = flops / HW["peak_flops_bf16"]
    t_mem_hlo = bytes_hlo / HW["hbm_bw"]
    t_mem_adj = bytes_adj / HW["hbm_bw"]
    t_coll = wire / (HW["ici_links_per_ring"] * HW["ici_link_bw"])

    terms = {"compute": t_compute, "memory": t_mem_adj,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # roofline fraction: useful-compute time / bound time
    tokens = shape.tokens
    if shape.kind == "decode":
        tokens = shape.global_batch
    n_active = cfg.active_param_count()
    model_flops_global = (6 if shape.kind == "train" else 2) \
        * n_active * tokens
    model_flops = model_flops_global / CHIPS_SINGLE
    t_useful = model_flops / HW["peak_flops_bf16"]
    if shape.kind == "decode":
        # decode is bandwidth-bound by construction: utilization = the
        # unavoidable traffic (params once + cache once per step) over
        # the achieved bound
        ideal_bytes = (2.0 * n_active
                       + rec.get("cache_bytes_analytic", 0)
                       * CHIPS_SINGLE) / CHIPS_SINGLE
        t_useful = ideal_bytes / HW["hbm_bw"]
    frac = t_useful / bound if bound > 0 else 0.0

    lever = {
        "compute": "cut non-useful FLOPs (remat policy, capacity factor, "
                   "padding) or raise MXU utilization (tile alignment)",
        "memory": "fuse/stream the dominant materialization (flash-style "
                  "blocking), cast accumulations bf16, shard longer dims",
        "collective": "reshard to cut the dominant collective (less TP "
                      "for small models, sequence-parallel boundaries, "
                      "overlap via scan structure)",
    }[dominant]

    return {
        "arch": arch, "shape": shape_name,
        "flops_dev": flops, "bytes_dev_hlo": bytes_hlo,
        "bytes_dev_adj": bytes_adj, "wire_dev": wire,
        "t_compute": t_compute, "t_mem_hlo": t_mem_hlo,
        "t_mem_adj": t_mem_adj, "t_coll": t_coll,
        "dominant": dominant,
        "model_flops_dev": model_flops,
        "useful_ratio": model_flops / flops if flops else 0.0,
        "roofline_frac": frac,
        "lever": lever,
        "coll_mix": rec.get("coll_mix_k2", {}),
        "memory_analysis": rec.get("memory", {}),
    }


def load_all(mesh: str = "single") -> List[dict]:
    rows = []
    for f in sorted(DRYRUN_DIR.glob(f"*_{mesh}.json")):
        rec = json.loads(f.read_text())
        row = analyze_record(rec)
        if row:
            rows.append(row)
    return rows


def table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s (hlo→adj) | coll s | "
           "dominant | 6ND/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']:.3g} | "
            f"{r['t_mem_hlo']:.3g}→{r['t_mem_adj']:.3g} | "
            f"{r['t_coll']:.3g} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} |")
    return hdr + "\n".join(lines)


def main():
    rows = load_all("single")
    print(table(rows))
    print()
    for r in sorted(rows, key=lambda r: r["roofline_frac"])[:5]:
        print(f"worst: {r['arch']} {r['shape']} frac={r['roofline_frac']:.3f}"
              f" dominant={r['dominant']} -> {r['lever']}")


if __name__ == "__main__":
    main()
