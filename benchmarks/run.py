"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

Runs the Mercury microbenchmarks (latency / bandwidth / rate — one per
CLUSTER'13 evaluation axis), the service-level benchmarks (checkpoint,
datafeed, serving), and prints the roofline table if dry-run records
exist.  Results land in experiments/bench/.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from . import bench_core, bench_services

OUT = Path("experiments/bench")


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    all_results = []

    print("=" * 72)
    print("Mercury microbenchmarks (paper evaluation axes)")
    print("=" * 72)
    all_results += bench_core.run_all()

    print("=" * 72)
    print("Service benchmarks (built on the RPC+bulk substrate)")
    print("=" * 72)
    all_results += bench_services.run_all()

    for r in all_results:
        (OUT / f"{r['name']}.json").write_text(json.dumps(r, indent=1))

    # roofline table (needs dry-run records)
    try:
        from . import roofline
        rows = roofline.load_all("single")
        if rows:
            print("=" * 72)
            print(f"Roofline (single-pod, {len(rows)} cells) — "
                  "full table in EXPERIMENTS.md")
            print("=" * 72)
            print(roofline.table(rows))
    except Exception as e:                                # pragma: no cover
        print(f"(roofline table skipped: {e})")
    print("benchmarks complete; json in", OUT)


if __name__ == "__main__":
    main()
