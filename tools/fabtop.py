#!/usr/bin/env python
"""fabtop — a live console for the fabric's telemetry plane.

Polls ``fab.metrics`` (and, best-effort, ``gen.stats``) on every target
and renders one refreshing screen: counters as rates, histograms as
count/avg/p~99, per-gateway serve stats when available.  Dependency-free
(ANSI escapes only); any engine that is up answers — gateways, registry
nodes, checkpoint servers — because every listening Engine registers
``fab.metrics``/``dbg.trace``.

Usage:
  PYTHONPATH=src python tools/fabtop.py tcp://127.0.0.1:7701,tcp://127.0.0.1:7702
  PYTHONPATH=src python tools/fabtop.py --once tcp://10.0.0.1:7700
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.core.executor import Engine

CLEAR = "\x1b[2J\x1b[H"
BOLD = "\x1b[1m"
DIM = "\x1b[2m"
RESET = "\x1b[0m"


def fetch(client: Engine, target: str, timeout: float) -> dict:
    out = {"uri": target, "ok": False}
    try:
        m = client.call(target, "fab.metrics", {}, timeout=timeout)
        out.update(ok=True, pid=m.get("pid"), engine_uri=m.get("uri"),
                   metrics=m.get("metrics", {}))
    except Exception as e:
        out["error"] = f"{type(e).__name__}: {e}"
        return out
    try:  # best-effort: only gateways serve gen.stats
        out["gen"] = client.call(target, "gen.stats", {}, timeout=timeout)
    except Exception:
        pass
    return out


def _fmt_val(v) -> str:
    if isinstance(v, float):
        return f"{v:,.2f}"
    return f"{v:,}"


def _rate(cur: dict, prev: dict, key: str, dt: float) -> str:
    if not prev or dt <= 0:
        return ""
    d = cur.get(key, 0) - prev.get(key, 0)
    return f" ({d / dt:,.1f}/s)" if d else ""


def render(snaps: list, prevs: dict, dt: float, verbose: bool) -> str:
    lines = [f"{BOLD}fabtop{RESET}  {time.strftime('%H:%M:%S')}   "
             f"{len([s for s in snaps if s['ok']])}/{len(snaps)} targets up"]
    for s in snaps:
        lines.append("")
        if not s["ok"]:
            lines.append(f"{BOLD}{s['uri']}{RESET}  {DIM}DOWN "
                         f"{s.get('error', '')}{RESET}")
            continue
        lines.append(f"{BOLD}{s['uri']}{RESET}  pid={s['pid']}")
        m = s.get("metrics", {})
        prev = prevs.get(s["uri"], {})
        ctr, pctr = m.get("counters", {}), prev.get("counters", {})
        if ctr:
            lines.append(f"  {DIM}counters{RESET}")
            for k, v in ctr.items():
                if not verbose and not v:
                    continue
                lines.append(f"    {k:<40} {_fmt_val(v):>12}"
                             f"{_rate(ctr, pctr, k, dt)}")
        gauges = m.get("gauges", {})
        live = {k: v for k, v in gauges.items() if verbose or v}
        if live:
            lines.append(f"  {DIM}gauges{RESET}")
            for k, v in live.items():
                lines.append(f"    {k:<40} {_fmt_val(v):>12}")
        hists = m.get("histograms", {})
        live_h = {k: h for k, h in hists.items()
                  if verbose or h.get("count")}
        if live_h:
            lines.append(f"  {DIM}histograms{RESET}")
            for k, h in live_h.items():
                lines.append(
                    f"    {k:<40} n={h['count']:<8} avg={h['avg']:<10} "
                    f"max={h['max']}")
        gen = s.get("gen")
        if gen:
            lines.append(f"  {DIM}gen.stats{RESET}  "
                         f"load={gen.get('load')} "
                         f"queued={gen.get('queued')} "
                         f"active={gen.get('active_slots')} "
                         f"admitted={gen.get('admitted')} "
                         f"shed={gen.get('shed')} "
                         f"ema_service_ms={gen.get('ema_service_ms', 0):.1f}")
            # serving-path pressure: slot occupancy as a bar gauge, the
            # KV-session table and its reuse effectiveness next to it
            occ = float(gen.get("occupancy", 0.0))
            filled = int(round(occ * 10))
            bar = "#" * filled + "." * (10 - filled)
            lines.append(f"  {DIM}serve{RESET}      "
                         f"occupancy=[{bar}] {occ * 100:3.0f}% "
                         f"pinned={gen.get('pinned_sessions', 0)}"
                         f"/{gen.get('session_capacity', 0)} "
                         f"prefix_hit_rate="
                         f"{float(gen.get('prefix_hit_rate', 0.0)):.2f} "
                         f"(hits={gen.get('prefix_hits', 0)} "
                         f"miss={gen.get('prefix_misses', 0)} "
                         f"saved={gen.get('prefix_tokens_saved', 0)}tok "
                         f"evict={gen.get('session_evictions', 0)})")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="live console over fab.metrics / gen.stats")
    ap.add_argument("targets",
                    help="comma-separated engine URIs to poll "
                         "(tcp://host:port,...)")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="refresh period in seconds (default 1.0)")
    ap.add_argument("--timeout", type=float, default=2.0,
                    help="per-target RPC timeout (default 2.0)")
    ap.add_argument("--once", action="store_true",
                    help="print one snapshot (no clear, no loop) and exit")
    ap.add_argument("--verbose", action="store_true",
                    help="include zero-valued instruments")
    args = ap.parse_args(argv)
    targets = [t.strip() for t in args.targets.split(",") if t.strip()]
    if not targets:
        ap.error("no targets")

    prevs: dict = {}
    last_t = time.monotonic()
    with Engine("tcp://127.0.0.1:0") as client:
        while True:
            snaps = [fetch(client, t, args.timeout) for t in targets]
            now = time.monotonic()
            out = render(snaps, prevs, now - last_t, args.verbose)
            last_t = now
            prevs = {s["uri"]: s.get("metrics", {})
                     for s in snaps if s["ok"]}
            if args.once:
                print(out)
                return 0
            sys.stdout.write(CLEAR + out + "\n")
            sys.stdout.flush()
            try:
                time.sleep(args.interval)
            except KeyboardInterrupt:
                return 0


if __name__ == "__main__":
    sys.exit(main())
