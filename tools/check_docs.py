#!/usr/bin/env python
"""Docs checker (the CI `docs` job).

For each markdown file given (default: the repo's maintained docs):

  * every fenced ```python block containing doctest prompts (`>>>`) is
    executed through :mod:`doctest` with a fresh globals dict — the
    snippets in DESIGN.md / docs/OPERATIONS.md are living examples, not
    decoration;
  * every other ```python block is compiled (syntax check) so renames
    and API drift rot loudly;
  * every intra-repo markdown link ``[text](path)`` is resolved
    relative to the file and must exist; same-file anchors
    (``[...](#heading)``) must match a heading.

Usage:
    python tools/check_docs.py                 # default file set
    python tools/check_docs.py DESIGN.md ...   # explicit files
Exits nonzero listing every failure.
"""
from __future__ import annotations

import doctest
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

DEFAULT_FILES = ["DESIGN.md", "docs/OPERATIONS.md", "examples/README.md",
                 "ROADMAP.md"]

_FENCE = re.compile(r"^```(\w*)[ \t]*\n(.*?)^```[ \t]*$",
                    re.MULTILINE | re.DOTALL)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}[ \t]+(.+?)[ \t]*$", re.MULTILINE)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, spaces→dashes, drop
    everything that is not alphanumeric/dash/underscore."""
    s = heading.strip().lower().replace(" ", "-")
    return re.sub(r"[^a-z0-9\-_]", "", s)


def check_snippets(path: pathlib.Path, text: str, errors: list) -> int:
    n = 0
    for m in _FENCE.finditer(text):
        lang, body = m.group(1).lower(), m.group(2)
        if lang not in ("python", "py"):
            continue
        n += 1
        lineno = text[:m.start()].count("\n") + 1
        where = f"{path}:{lineno}"
        if ">>>" in body:
            parser = doctest.DocTestParser()
            try:
                test = parser.get_doctest(body, {"__name__": "__main__"},
                                          where, str(path), lineno)
            except ValueError as e:
                errors.append(f"{where}: malformed doctest: {e}")
                continue
            runner = doctest.DocTestRunner(verbose=False)

            out: list = []
            runner.run(test, out=out.append)
            if runner.failures:
                errors.append(f"{where}: {runner.failures} doctest "
                              f"failure(s):\n" + "".join(out))
        else:
            try:
                compile(body, where, "exec")
            except SyntaxError as e:
                errors.append(f"{where}: snippet does not parse: {e}")
    return n


def check_links(path: pathlib.Path, text: str, errors: list) -> int:
    anchors = {_slug(h) for h in _HEADING.findall(text)}
    n = 0
    for m in _LINK.finditer(text):
        target = m.group(1)
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:, ...
            continue
        n += 1
        lineno = text[:m.start()].count("\n") + 1
        where = f"{path}:{lineno}"
        base, _, frag = target.partition("#")
        if not base:                                   # same-file anchor
            if frag and _slug(frag) not in anchors:
                errors.append(f"{where}: anchor #{frag} matches no "
                              f"heading in {path.name}")
            continue
        dest = (path.parent / base).resolve()
        if not dest.exists():
            errors.append(f"{where}: link target {target!r} does not "
                          f"exist (resolved {dest})")
    return n


def main(argv=None) -> int:
    files = [pathlib.Path(f) for f in (argv or sys.argv[1:])] or \
        [ROOT / f for f in DEFAULT_FILES]
    errors: list = []
    snippets = links = 0
    for path in files:
        if not path.exists():
            errors.append(f"{path}: file not found")
            continue
        text = path.read_text(encoding="utf-8")
        snippets += check_snippets(path, text, errors)
        links += check_links(path, text, errors)
    print(f"[check_docs] {len(files)} files, {snippets} python snippets, "
          f"{links} intra-repo links")
    if errors:
        for e in errors:
            print(f"[check_docs] FAIL {e}", file=sys.stderr)
        return 1
    print("[check_docs] OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
