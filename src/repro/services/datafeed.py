"""Data-feed service: a feeder process hosts the token pipeline; trainer
processes fetch batches over RPC.

Small batches ride inline in the RPC response (eager); large ones go
through a bulk descriptor the trainer pulls one-sidedly — the
eager/rendezvous crossover is a constructor knob and is *benchmarked* in
``benchmarks/bench_bulk.py`` (the paper's bulk-vs-eager trade-off).

The client keeps ``depth`` requests outstanding (async prefetch), so one
slow feeder response never stalls the training step; combined with
``replicated_call`` over several feeders it is the datapath side of
straggler mitigation.
"""
from __future__ import annotations

import collections
import threading
from typing import Dict, List, Optional

import numpy as np

from ..core.bulk import BulkDescriptor
from ..core.executor import Engine
from .base import alloc_from_manifest, manifest_of

EAGER_LIMIT = 256 * 1024


class DataFeedServer:
    def __init__(self, engine: Engine, source, eager_limit: int = EAGER_LIMIT,
                 keep: int = 8, registry: Optional[str] = None,
                 service: str = "feed"):
        self.engine = engine
        self.source = source                     # needs .batch_at(step)
        self.eager_limit = eager_limit
        self._exposed = collections.OrderedDict()  #: guarded-by _lock
        self._keep = keep
        self._lock = threading.Lock()
        engine.register("feed.get", self._get)
        engine.register("feed.spec", self._spec)
        self.instance = None
        if registry is not None:
            from ..fabric.registry import ServiceInstance
            self.instance = ServiceInstance(engine, registry, service)

    def close(self) -> None:
        if self.instance is not None:
            self.instance.close()

    def _spec(self, _req):
        b = self.source.batch_at(0)
        return {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in b.items()}

    def _get(self, req):
        step = int(req["step"])
        batch = self.source.batch_at(step)
        total = sum(v.nbytes for v in batch.values())
        if total <= self.eager_limit:
            return {"mode": "eager", "step": step, "batch": batch}
        with self._lock:
            if step not in self._exposed:
                named = {k: np.ascontiguousarray(v)
                         for k, v in batch.items()}
                handle = self.engine.expose(list(named.values()),
                                            read=True, write=False)
                self._exposed[step] = (named, handle)
                while len(self._exposed) > self._keep:
                    _, (_, old) = self._exposed.popitem(last=False)
                    old.free()
            named, handle = self._exposed[step]
        return {"mode": "bulk", "step": step,
                "manifest": manifest_of(named),
                "desc": handle.descriptor().to_bytes(),
                "origin": self.engine.uri}


class DataFeedClient:
    def __init__(self, engine: Engine, feeders: Optional[List[str]] = None,
                 depth: int = 2, registry: Optional[str] = None,
                 service: str = "feed"):
        """``feeders`` is an explicit URI list, or pass ``registry=`` to
        resolve every live instance of ``service`` by name."""
        self.engine = engine
        if feeders is None:
            if registry is None:
                raise ValueError("need feeders or registry")
            from ..fabric.registry import resolve_service_uris
            feeders = resolve_service_uris(engine, registry, service)
        self.feeders = feeders
        self.depth = depth
        self._pending: Dict[int, object] = {}
        self._next_issue = 0

    def _issue(self, step: int):
        feeder = self.feeders[step % len(self.feeders)]
        self._pending[step] = self.engine.call_async(
            feeder, "feed.get", {"step": step}, timeout=60.0)

    def get(self, step: int) -> Dict[str, np.ndarray]:
        # keep the window [step, step+depth) outstanding
        for s in range(step, step + self.depth):
            if s not in self._pending and s >= self._next_issue:
                self._issue(s)
                self._next_issue = max(self._next_issue, s + 1)
        fut = self._pending.pop(step, None)
        if fut is None:
            self._issue(step)
            fut = self._pending.pop(step)
        rsp = fut.result(timeout=120.0)
        if rsp["mode"] == "eager":
            return {k: np.asarray(v) for k, v in rsp["batch"].items()}
        man = rsp["manifest"]
        named = alloc_from_manifest(man)
        local = self.engine.expose(list(named.values()), read=False,
                                   write=True)
        try:
            self.engine.pull(rsp["origin"],
                             BulkDescriptor.from_bytes(rsp["desc"]), local)
        finally:
            local.free()
        return named
