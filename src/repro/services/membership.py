"""Membership / heartbeat service — the fault-tolerance control plane.

A coordinator tracks live members; an *epoch* counter bumps whenever the
member set changes (join, leave, heartbeat timeout).  Training drivers
poll the epoch each step: on change they rebuild the mesh from the
survivors and restore from the checkpoint service (elastic scaling +
node-failure recovery, exercised in tests and the elastic example).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

from ..core.executor import Engine


class MembershipServer:
    def __init__(self, engine: Engine, heartbeat_timeout: float = 2.0,
                 sweep_interval: float = 0.5):
        self.engine = engine
        self.timeout = heartbeat_timeout
        self.members: Dict[str, dict] = {}     # member_id -> info
        self.epoch = 0
        self._lock = threading.Lock()
        self._stop = threading.Event()
        engine.register("mem.join", self._join)
        engine.register("mem.leave", self._leave)
        engine.register("mem.heartbeat", self._heartbeat)
        engine.register("mem.view", self._view)
        self._sweeper = threading.Thread(
            target=self._sweep_loop, args=(sweep_interval,), daemon=True)
        self._sweeper.start()

    def _join(self, req):
        mid = req["member_id"]
        with self._lock:
            self.members[mid] = {
                "uri": req.get("uri", ""), "meta": req.get("meta", {}),
                "last": time.monotonic(),
            }
            self.epoch += 1
            return self._view_locked()

    def _leave(self, req):
        with self._lock:
            if self.members.pop(req["member_id"], None) is not None:
                self.epoch += 1
            return self._view_locked()

    def _heartbeat(self, req):
        with self._lock:
            m = self.members.get(req["member_id"])
            if m is None:
                # expired member re-announcing: treat as join
                self.members[req["member_id"]] = {
                    "uri": req.get("uri", ""), "meta": {},
                    "last": time.monotonic()}
                self.epoch += 1
            else:
                m["last"] = time.monotonic()
            return self._view_locked()

    def _view(self, _req):
        with self._lock:
            return self._view_locked()

    def _view_locked(self):
        return {"epoch": self.epoch,
                "members": sorted(self.members.keys()),
                "uris": {k: v["uri"] for k, v in self.members.items()}}

    def _sweep_loop(self, interval: float):
        while not self._stop.is_set():
            time.sleep(interval)
            now = time.monotonic()
            with self._lock:
                dead = [k for k, v in self.members.items()
                        if now - v["last"] > self.timeout]
                for k in dead:
                    del self.members[k]
                if dead:
                    self.epoch += 1

    def stop(self):
        self._stop.set()


class MembershipClient:
    def __init__(self, engine: Engine, server_uri: str, member_id: str,
                 heartbeat_interval: float = 0.5,
                 on_change: Optional[Callable[[dict], None]] = None):
        self.engine = engine
        self.server = server_uri
        self.member_id = member_id
        self.interval = heartbeat_interval
        self.on_change = on_change
        self.view: dict = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def join(self, meta: Optional[dict] = None) -> dict:
        self.view = self.engine.call(self.server, "mem.join", {
            "member_id": self.member_id, "uri": self.engine.uri,
            "meta": meta or {}})
        self._thread = threading.Thread(target=self._beat, daemon=True)
        self._thread.start()
        return self.view

    def _beat(self):
        while not self._stop.is_set():
            time.sleep(self.interval)
            try:
                view = self.engine.call(self.server, "mem.heartbeat",
                                        {"member_id": self.member_id,
                                         "uri": self.engine.uri},
                                        timeout=5.0)
            except Exception:
                continue
            if view["epoch"] != self.view.get("epoch") and self.on_change:
                self.on_change(view)
            self.view = view

    def current_view(self) -> dict:
        return self.engine.call(self.server, "mem.view", {})

    def leave(self):
        self._stop.set()
        try:
            self.engine.call(self.server, "mem.leave",
                             {"member_id": self.member_id}, timeout=5.0)
        except Exception:
            pass
