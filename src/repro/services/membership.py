"""Membership / heartbeat service — the fault-tolerance control plane.

A coordinator tracks live members; an *epoch* counter bumps whenever the
member set changes (join, leave, heartbeat timeout).  Training drivers
poll the epoch each step: on change they rebuild the mesh from the
survivors and restore from the checkpoint service (elastic scaling +
node-failure recovery, exercised in tests and the elastic example).

Views also carry a per-run **nonce** (the same scheme the registry uses,
DESIGN.md §7/§8): epochs are only comparable within one coordinator run,
so a driver that compares ``view["epoch"]`` across a coordinator restart
can detect the reset (nonce changed → resync) instead of treating the
reset-to-small epoch as stale forever.  The replicated registry's gossip
stream is keyed the same way.
"""
from __future__ import annotations

import threading
import time
import uuid
from typing import Callable, Dict, List, Optional

from ..core.executor import Engine


class MembershipServer:
    def __init__(self, engine: Engine, heartbeat_timeout: float = 2.0,
                 sweep_interval: float = 0.5):
        self.engine = engine
        self.timeout = heartbeat_timeout
        self.members: Dict[str, dict] = {}     # member_id -> info
        self.epoch = 0
        # run nonce: epochs are only comparable within one coordinator
        # run (see module docstring)
        self.nonce = uuid.uuid4().hex[:12]
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._expire_cbs: List[Callable[[List[str]], None]] = []
        engine.register("mem.join", self._join)
        engine.register("mem.leave", self._leave)
        engine.register("mem.heartbeat", self._heartbeat)
        engine.register("mem.view", self._view)
        self._sweeper = threading.Thread(
            target=self._sweep_loop, args=(sweep_interval,), daemon=True)
        self._sweeper.start()

    def _join(self, req):
        mid = req["member_id"]
        with self._lock:
            self.members[mid] = {
                "uri": req.get("uri", ""), "meta": req.get("meta", {}),
                "last": time.monotonic(),
            }
            self.epoch += 1
            return self._view_locked()

    def _leave(self, req):
        with self._lock:
            left = self.members.pop(req["member_id"], None) is not None
            if left:
                self.epoch += 1
            view = self._view_locked()
        if left:
            self._fire_expired([req["member_id"]])
        return view

    def _heartbeat(self, req):
        with self._lock:
            m = self.members.get(req["member_id"])
            if m is None:
                # expired member re-announcing: treat as join
                self.members[req["member_id"]] = {
                    "uri": req.get("uri", ""), "meta": {},
                    "last": time.monotonic()}
                self.epoch += 1
            else:
                m["last"] = time.monotonic()
            return self._view_locked()

    def _view(self, _req):
        with self._lock:
            return self._view_locked()

    def _view_locked(self):
        return {"epoch": self.epoch, "nonce": self.nonce,
                "members": sorted(self.members.keys()),
                "uris": {k: v["uri"] for k, v in self.members.items()}}

    # -- expiry hooks (e.g. the service registry reaping instances whose
    # member died) -----------------------------------------------------------
    def on_expire(self, cb: Callable[[List[str]], None]) -> None:
        """Register ``cb(dead_member_ids)``; fired after a heartbeat
        sweep or an explicit leave removed members (outside the lock)."""
        self._expire_cbs.append(cb)

    def _fire_expired(self, dead: List[str]) -> None:
        for cb in self._expire_cbs:
            try:
                cb(dead)
            except Exception:
                pass                      # hooks must not kill the sweeper

    def _sweep_loop(self, interval: float):
        # Event.wait (not sleep) so close() can interrupt and join promptly
        while not self._stop.wait(interval):
            now = time.monotonic()
            with self._lock:
                dead = [k for k, v in self.members.items()
                        if now - v["last"] > self.timeout]
                for k in dead:
                    del self.members[k]
                if dead:
                    self.epoch += 1
            if dead:
                self._fire_expired(dead)

    def close(self):
        """Graceful stop: joins the sweeper thread (idempotent) — daemon
        teardown alone leaks the thread across tests."""
        self._stop.set()
        if self._sweeper.is_alive():
            self._sweeper.join(timeout=2.0)

    stop = close


class MembershipClient:
    def __init__(self, engine: Engine, server_uri: str, member_id: str,
                 heartbeat_interval: float = 0.5,
                 on_change: Optional[Callable[[dict], None]] = None):
        self.engine = engine
        self.server = server_uri
        self.member_id = member_id
        self.interval = heartbeat_interval
        self.on_change = on_change
        self.view: dict = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def join(self, meta: Optional[dict] = None) -> dict:
        self.view = self.engine.call(self.server, "mem.join", {
            "member_id": self.member_id, "uri": self.engine.uri,
            "meta": meta or {}})
        self._thread = threading.Thread(target=self._beat, daemon=True)
        self._thread.start()
        return self.view

    def _beat(self):
        while not self._stop.wait(self.interval):
            try:
                view = self.engine.call(self.server, "mem.heartbeat",
                                        {"member_id": self.member_id,
                                         "uri": self.engine.uri},
                                        timeout=5.0)
            except Exception:
                continue
            if view["epoch"] != self.view.get("epoch") and self.on_change:
                self.on_change(view)
            self.view = view

    def current_view(self) -> dict:
        return self.engine.call(self.server, "mem.view", {})

    def leave(self):
        self._stop.set()
        if self._thread is not None and self._thread.is_alive():
            self._thread.join(timeout=2.0)
        try:
            self.engine.call(self.server, "mem.leave",
                             {"member_id": self.member_id}, timeout=5.0)
        except Exception:
            pass

    close = leave
