"""Membership / heartbeat service — the fault-tolerance control plane.

A coordinator tracks live members; an *epoch* counter bumps whenever the
member set changes (join, leave, heartbeat timeout).  Training drivers
poll the epoch each step: on change they rebuild the mesh from the
survivors and restore from the checkpoint service (elastic scaling +
node-failure recovery, exercised in tests and the elastic example).

The member table is a
:class:`~repro.fabric.replication.ReplicatedTable`.  Standalone
(``MembershipServer(engine)``) it rides a private single-node
replication core — the original per-node coordinator, wire API
unchanged.  Passed the core of a registry quorum (``core=``, wired by
``RegistryService(serve_membership=True)``) the member table is
**replicated across the quorum** alongside the instance table: one
leader lease, one delta-gossip stream, follower-served ``mem.view``
reads, writes (``mem.join``/``mem.leave``/``mem.heartbeat``) proxied
one hop to the leaseholder.  Member liveness and expiry hooks then
survive leaseholder death: a takeover refreshes every member's
heartbeat stamp (no mass-expiry) and subsequent expiries fire on the
new leader — exactly once, since only the leaseholder sweeps.

Views carry a per-run **nonce** (the same scheme the registry uses,
DESIGN.md §7/§8): epochs are only comparable within one coordinator run
*or lease tenure*, so a driver comparing ``view["epoch"]`` across a
coordinator restart or a quorum failover can detect the reset (nonce
changed → resync) instead of treating the reset-to-small epoch as stale
forever.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

from ..core.executor import Engine
from ..fabric.readcache import ReadCache
from ..fabric.replication import QuorumCaller, ReplicationCore


class MembershipServer:
    """Hosts the ``mem.*`` RPCs.  ``core=None`` runs the classic
    single-node coordinator; pass a quorum's
    :class:`~repro.fabric.replication.ReplicationCore` to serve the
    member table replicated (every quorum node hosts ``mem.*``)."""

    def __init__(self, engine: Engine, heartbeat_timeout: float = 2.0,
                 sweep_interval: float = 0.5,
                 core: Optional[ReplicationCore] = None):
        self.engine = engine
        self.timeout = heartbeat_timeout
        self._owns_core = core is None
        if core is None:
            core = ReplicationCore(engine, sweep_interval=sweep_interval)
        self.core = core
        self.table = core.table("members", ttl=heartbeat_timeout)
        self._expire_cbs: List[Callable[[List[str]], None]] = []
        self.table.on_expire(self._fire_expired)
        # mem.join/leave/heartbeat proxy to the leaseholder in quorum
        # mode — nested blocking calls, so they stay off the progress
        # thread; mem.view is a pure local read
        engine.register("mem.join", self._join)
        engine.register("mem.leave", self._leave)
        engine.register("mem.heartbeat", self._heartbeat)
        engine.register("mem.view", self._view, inline=True)

    # -- compat --------------------------------------------------------------
    @property
    def members(self) -> Dict[str, dict]:
        return dict(self.table.items())

    @property
    def epoch(self) -> int:
        return self.table.epoch

    @property
    def nonce(self) -> str:
        return self.core.nonce

    @property
    def _sweeper(self) -> threading.Thread:
        return self.core._sweeper

    # -- handlers ------------------------------------------------------------
    def _view_locked(self):
        with self.core._lock:
            items = self.table.items()
            return {"epoch": self.table.epoch, "nonce": self.core.nonce,
                    "members": sorted(k for k, _ in items),
                    "uris": {k: v["uri"] for k, v in items}}

    def _join(self, req):
        lead = self.core.leader_for_writes()
        if lead is not None:
            return self.core.proxy(lead, "mem.join", req)
        mid = req["member_id"]
        with self.core._lock:
            self.table.put(mid, {"uri": req.get("uri", ""),
                                 "meta": req.get("meta", {})})
            return self._view_locked()

    def _leave(self, req):
        lead = self.core.leader_for_writes()
        if lead is not None:
            return self.core.proxy(lead, "mem.leave", req)
        with self.core._lock:
            left = self.table.delete(req["member_id"])
            view = self._view_locked()
        if left:
            self._fire_expired([req["member_id"]])
        return view

    def _heartbeat(self, req):
        lead = self.core.leader_for_writes()
        if lead is not None:
            return self.core.proxy(lead, "mem.heartbeat", req)
        mid = req["member_id"]
        with self.core._lock:
            if not self.table.update(mid):
                # expired member re-announcing: treat as a join —
                # preserving any metadata it carries, exactly like
                # _join does (a re-join with meta={} would silently
                # drop the member's registered metadata)
                self.table.put(mid, {"uri": req.get("uri", ""),
                                     "meta": req.get("meta", {})})
            return self._view_locked()

    def _view(self, _req):
        return self._view_locked()

    # -- expiry hooks (e.g. the service registry reaping instances whose
    # member died) -----------------------------------------------------------
    def on_expire(self, cb: Callable[[List[str]], None]) -> None:
        """Register ``cb(dead_member_ids)``; fired after a heartbeat
        sweep or an explicit leave removed members (outside the lock,
        on the node holding the lease)."""
        self._expire_cbs.append(cb)

    def _fire_expired(self, dead: List[str]) -> None:
        for cb in self._expire_cbs:
            try:
                cb(dead)
            except Exception:
                pass                      # hooks must not kill the sweeper

    def close(self):
        """Graceful stop (idempotent).  A private single-node core is
        closed (joining its sweeper); a shared quorum core belongs to
        the RegistryService that created it."""
        if self._owns_core:
            self.core.close()

    stop = close


class MembershipClient:
    """Member-side wrapper over ``mem.*``.  ``server_uri`` may be one
    coordinator endpoint or a whole quorum address set (comma-separated
    or list): calls stick to the replica that last answered and rotate
    on dead-peer detection — any quorum node serves views and proxies
    writes to the leaseholder.  Heartbeats carry the member's join
    metadata so an expiry-then-reannounce round trip (e.g. a long GC
    pause) restores it instead of rejoining with ``meta={}``.

    ``cache_ttl > 0`` turns on the idempotent read cache for
    ``mem.view`` (DESIGN.md §9): repeat ``current_view()`` calls within
    the TTL are served locally, evicted the moment any view the client
    sees — including its own heartbeats — carries a newer
    ``(nonce, epoch)``."""

    def __init__(self, engine: Engine, server_uri, member_id: str,
                 heartbeat_interval: float = 0.5,
                 on_change: Optional[Callable[[dict], None]] = None,
                 cache_ttl: float = 0.0):
        from ..fabric.sharding import membership_home
        self.engine = engine
        # membership is unsharded and rides shard 0 (DESIGN.md §12), so
        # a sharded registry spec reduces to its home shard here
        self._caller = QuorumCaller(engine, membership_home(server_uri),
                                    timeout=5.0)
        self.member_id = member_id
        self.interval = heartbeat_interval
        self.on_change = on_change
        self.cache = ReadCache(ttl=cache_ttl)
        self.meta: dict = {}
        self.view: dict = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    @property
    def server(self) -> str:
        """The currently preferred endpoint (observability/tests)."""
        return self._caller.current

    @staticmethod
    def _token_of(view: dict):
        return view.get("nonce"), view["epoch"]

    def join(self, meta: Optional[dict] = None) -> dict:
        self.meta = meta or {}
        self.view = self._caller.call("mem.join", {
            "member_id": self.member_id, "uri": self.engine.uri,
            "meta": self.meta})
        self.cache.observe(*self._token_of(self.view))
        self._thread = threading.Thread(target=self._beat, daemon=True)
        self._thread.start()
        return self.view

    def _beat(self):
        while not self._stop.wait(self.interval):
            try:
                view = self._caller.call("mem.heartbeat",
                                         {"member_id": self.member_id,
                                          "uri": self.engine.uri,
                                          "meta": self.meta})
            except Exception:
                continue
            self.cache.observe(*self._token_of(view))
            # epochs are only comparable within one (nonce) stream: a
            # coordinator restart or quorum failover mints a new nonce
            # and must fire on_change even if the epoch looks equal/lower
            changed = (view["epoch"] != self.view.get("epoch")
                       or view.get("nonce") != self.view.get("nonce"))
            if changed and self.on_change:
                self.on_change(view)
            self.view = view

    def current_view(self, fresh: bool = False) -> dict:
        return self.cache.get_or_call(
            "mem.view", {}, lambda: self._caller.call("mem.view", {}),
            fresh=fresh, token_of=self._token_of)

    def leave(self):
        self._stop.set()
        if self._thread is not None and self._thread.is_alive():
            self._thread.join(timeout=2.0)
        try:
            self._caller.call("mem.leave", {"member_id": self.member_id})
        except Exception:
            pass

    close = leave
