"""Service substrate: pytree <-> named-buffer codecs shared by the
checkpoint and datafeed services, the replicated-call straggler
mitigation helper, and the deadline-aware admission controller shared by
every server-side handler path.

Every service node is just a :class:`repro.core.executor.Engine` — origin
and target at once (paper C4); these helpers keep the services thin.
"""
from __future__ import annotations

import concurrent.futures as cf
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from ..core.executor import Engine, RemoteError
from ..core.types import MercuryError, Ret
from ..kernels import ops as kops
from ..telemetry import metrics as _metrics

# unified metrics: process-wide admission totals + service-time shape
# (per-controller detail stays in stats(); fab.metrics exports these)
_M_ADMITTED = _metrics.counter("service.admission.admitted")
_M_SHED = _metrics.counter("service.admission.shed")
_M_SERVICE_MS = _metrics.histogram("service.admission.service_ms")
_M_TURNAROUND_MS = _metrics.histogram("service.admission.turnaround_ms")


def flatten_named(tree) -> Dict[str, np.ndarray]:
    """Pytree → {path: ndarray} with deterministic, reversible keys."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def unflatten_named(template, named: Dict[str, np.ndarray]):
    """Rebuild a tree shaped like ``template`` from {path: ndarray}."""
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_leaves:
        key = jax.tree_util.keystr(path)
        if key not in named:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = named[key]
        want = np.asarray(leaf)
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {want.shape}")
        leaves.append(arr.astype(want.dtype, copy=False))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def checksum_of(arr: np.ndarray) -> int:
    """Fletcher-64 over the raw bytes (padded to a u32 boundary)."""
    raw = np.ascontiguousarray(arr).view(np.uint8).ravel()
    pad = (-raw.size) % 4
    if pad:
        raw = np.concatenate([raw, np.zeros(pad, np.uint8)])
    return kops.fletcher64(raw.view(np.uint32), impl="xla")


def manifest_of(named: Dict[str, np.ndarray]) -> dict:
    return {
        "keys": list(named.keys()),
        "shapes": [list(v.shape) for v in named.values()],
        "dtypes": [str(v.dtype) for v in named.values()],
        "nbytes": [int(v.nbytes) for v in named.values()],
        # hex (Fletcher-64 exceeds the signed-i64 wire int)
        "checksums": [f"{checksum_of(v):016x}" for v in named.values()],
    }


def alloc_from_manifest(man: dict) -> Dict[str, np.ndarray]:
    return {k: np.empty(tuple(s), dtype=np.dtype(d))
            for k, s, d in zip(man["keys"], man["shapes"], man["dtypes"])}


def verify_manifest(man: dict, named: Dict[str, np.ndarray]) -> None:
    for k, want in zip(man["keys"], man["checksums"]):
        got = f"{checksum_of(named[k]):016x}"
        if got != want:
            raise MercuryError(Ret.CHECKSUM_ERROR,
                               f"shard {k}: {got} != {want}")


# ---------------------------------------------------------------------------
# deadline-aware admission control (server side)
# ---------------------------------------------------------------------------
class AdmissionController:
    """Shed work a server cannot finish within the caller's deadline.

    The caller's remaining deadline budget rides the request header
    (``RequestHeader.budget_ms`` — see ``Handle.remaining_budget``).  The
    server keeps an EWMA of observed per-request service time and
    estimates the wait a newly admitted request would see from the
    current backlog::

        est = ema_service × (backlog ÷ parallelism) + ema_service

    (queue-wait plus the request's own service time).  If ``est``
    exceeds the caller's remaining budget the request is **shed** with
    ``Ret.OVERLOAD`` before any work happens — a sub-millisecond
    fast-fail the client pool retries on another replica immediately —
    instead of burning queue capacity on a request whose answer nobody
    will be waiting for.  Mercury's facility argument, mRPC's placement
    argument: this policy lives in the RPC service layer, not in each
    application.

    Callers with no deadline (``budget is None``) are always admitted;
    so is everything until ``min_samples`` completions have been
    observed (no estimate yet — shedding on a guess is worse than
    queueing).

    The EWMA that feeds the estimate is **pure service time** — the span
    a request actually occupied an execution slot (admit→done), not
    submit→done.  The distinction matters right after a burst: queue
    wait is already priced in via the ``backlog`` term, so folding it
    into the EWMA as well double-counts queueing and over-sheds until
    the EWMA re-converges.  ``turnaround_s`` (submit→done, queue wait
    included) is tracked separately for observability
    (``ema_turnaround_ms`` in :meth:`stats`).
    """

    def __init__(self, ewma_alpha: float = 0.2, min_samples: int = 3,
                 safety: float = 1.0):
        self.ewma_alpha = ewma_alpha
        self.min_samples = min_samples
        self.safety = safety      # >1.0 sheds earlier, <1.0 later
        self.ema_service = 0.0  #: guarded-by _lock (s/request, occupancy)
        self.ema_turnaround = 0.0  #: guarded-by _lock (submit→done)
        self.samples = 0  #: guarded-by _lock
        self.admitted = 0  #: guarded-by _lock
        self.shed = 0  #: guarded-by _lock
        self._lock = threading.Lock()

    def observe(self, service_s: float,
                turnaround_s: Optional[float] = None) -> None:
        """Record one completed request: ``service_s`` is the pure
        service time (slot occupancy, admit→done); ``turnaround_s``
        optionally records submit→done for observability.  Only
        ``service_s`` feeds the shedding estimate."""
        if service_s < 0:
            return
        _M_SERVICE_MS.observe(service_s * 1e3)
        if turnaround_s is not None and turnaround_s >= 0:
            _M_TURNAROUND_MS.observe(turnaround_s * 1e3)
        with self._lock:
            a = self.ewma_alpha
            self.ema_service = (service_s if not self.samples
                                else a * service_s
                                + (1 - a) * self.ema_service)
            if turnaround_s is not None and turnaround_s >= 0:
                self.ema_turnaround = (
                    turnaround_s if not self.samples
                    else a * turnaround_s + (1 - a) * self.ema_turnaround)
            self.samples += 1

    def estimate_wait(self, backlog: int, parallelism: int) -> float:
        """Estimated completion time (queue-wait + service) for a new
        request given ``backlog`` outstanding work items and
        ``parallelism`` concurrent executors; 0.0 until enough samples."""
        with self._lock:
            if self.samples < self.min_samples:
                return 0.0
            waves = backlog / max(parallelism, 1)
            return self.ema_service * (waves + 1.0)

    def admit(self, budget: Optional[float], backlog: int,
              parallelism: int) -> None:
        """Raise ``MercuryError(Ret.OVERLOAD)`` if the request cannot be
        finished within ``budget`` seconds; otherwise count it admitted.
        ``budget=None`` (caller set no deadline) always admits."""
        est = self.estimate_wait(backlog, parallelism)
        with self._lock:
            if (budget is not None and est * self.safety > budget):
                self.shed += 1
                _M_SHED.inc()
                raise MercuryError(
                    Ret.OVERLOAD,
                    f"estimated completion {est * 1e3:.0f}ms exceeds the "
                    f"caller's remaining budget {budget * 1e3:.0f}ms "
                    f"(backlog {backlog}, ema {self.ema_service * 1e3:.0f}"
                    f"ms)")
            self.admitted += 1
            _M_ADMITTED.inc()

    def stats(self) -> dict:
        with self._lock:
            return {"ema_service_ms": self.ema_service * 1e3,
                    "ema_turnaround_ms": self.ema_turnaround * 1e3,
                    "admission_samples": self.samples,
                    "admitted": self.admitted, "shed": self.shed}


# ---------------------------------------------------------------------------
# straggler mitigation: replicated issue, first-wins
# ---------------------------------------------------------------------------
def replicated_call(engine: Engine, targets: Sequence[str], name: str,
                    arg: Any = None, timeout: float = 30.0) -> Any:
    """Issue the same RPC to every target; first success wins, the rest
    are abandoned (their handles are canceled at transport level when the
    engine GC's them).  Raises the last error if all fail."""
    if not targets:
        raise MercuryError(Ret.INVALID_ARG, "no targets")
    futs = [engine.call_async(t, name, arg, timeout=timeout)
            for t in targets]
    last_err: Optional[Exception] = None
    done_any = threading.Event()
    result_box: dict = {}

    def watch(f):
        nonlocal last_err
        try:
            r = f.result()
            if not done_any.is_set():
                result_box["v"] = r
                done_any.set()
        except Exception as e:
            last_err = e
            if all(fu.done() for fu in futs) and not done_any.is_set():
                done_any.set()

    for f in futs:
        f.add_done_callback(watch)
    done_any.wait(timeout + 5.0)
    if "v" in result_box:
        return result_box["v"]
    raise last_err or MercuryError(Ret.TIMEOUT, name)
