from .base import (AdmissionController, alloc_from_manifest, checksum_of,
                   flatten_named, manifest_of, replicated_call,
                   unflatten_named, verify_manifest)
from .checkpoint import CheckpointClient, CheckpointServer
from .datafeed import DataFeedClient, DataFeedServer
from .gateway import ServingGateway
from .membership import MembershipClient, MembershipServer

__all__ = [
    "CheckpointClient", "CheckpointServer", "DataFeedClient",
    "DataFeedServer", "MembershipClient", "MembershipServer",
    "ServingGateway", "AdmissionController", "replicated_call",
    "flatten_named", "unflatten_named",
    "manifest_of", "alloc_from_manifest", "verify_manifest", "checksum_of",
]
