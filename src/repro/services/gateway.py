"""Serving gateway: the Mercury RPC front door for the ServeEngine.

RPCs:
  ``gen.submit``   {tokens, max_new, temperature, eos_id[, frontend]
                   [, session_id]} → {rid}      (non-blocking enqueue)
                   ``session_id`` keys the engine's KV-session table: a
                   follow-up turn whose prompt extends the cached history
                   resumes from the pinned KV instead of re-prefilling
                   (see serve/engine.py); the fabric's SessionAffinity
                   layer keeps follow-ups on the KV-holding replica
  ``gen.submit_bulk`` {desc, count, ...} — the prompt tokens stay in the
                   client's registered memory; the gateway pulls them
                   one-sidedly (zero-copy on sm/self transports) instead
                   of carrying them in the eager message
  ``gen.result``   {rid[, wait, timeout]} → {tokens, done} — with
                   ``wait`` the response is sent *event-driven* from the
                   request's done callback (deadline timer for the
                   timeout), so a parked waiter costs no handler thread
  ``gen.generate`` blocking submit+wait (handler parks on the request's
                   done event — it runs on the engine's handler pool, so
                   the progress thread keeps spinning: exactly the
                   multithreaded-executor shim of paper C5)
  ``gen.stats``    → queue/slot utilization + load (the fabric's
                   piggybacked balancing signal) + admission stats

A background thread drives ``ServeEngine.step()`` whenever work exists
(woken by the engine's work event — no idle polling); with ``registry=``
(one endpoint or the comma-separated replica set of a registry quorum —
see DESIGN.md §8) the gateway self-registers as an instance of service
``service`` and reports its load, making it routable through a
:class:`~repro.fabric.pool.ServicePool`.

**Deadline-aware admission control**: every submit path (``gen.submit``,
``gen.submit_bulk``, ``gen.generate``) runs through a shared
:class:`~repro.services.base.AdmissionController` first.  The caller's
remaining deadline budget arrives in the request header
(``Handle.remaining_budget``); if the gateway's backlog × EWMA service
time says the request cannot finish in that budget, it is shed with
``Ret.OVERLOAD`` *before* touching the serve queue — an overloaded
server spends its capacity on requests that can still make their
deadlines, and the client pool re-routes the shed ones immediately.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

import numpy as np

from ..core.bulk import BulkDescriptor
from ..core.executor import Engine
from ..core.types import Ret
from ..serve.engine import Request, ServeEngine
from ..telemetry import metrics as _metrics
from ..telemetry import trace as _trace
from .base import AdmissionController

# unified metrics: gateway serve-path totals (fab.metrics exports these;
# the per-gateway view stays in gen.stats)
_M_SUBMITS = _metrics.counter("service.gateway.submits")
_M_COMPLETIONS = _metrics.counter("service.gateway.completions")
_M_TOKENS_OUT = _metrics.counter("service.gateway.tokens_out")
_M_QUEUE_MS = _metrics.histogram("service.gateway.queue_ms")
_M_SERVICE_MS = _metrics.histogram("service.gateway.service_ms")


class ServingGateway:
    def __init__(self, engine: Engine, serve: ServeEngine,
                 registry: Optional[str] = None, service: str = "gen",
                 report_interval: float = 0.5,
                 admission: Optional[AdmissionController] = None,
                 shed_enabled: bool = True,
                 member_id: Optional[str] = None):
        self.engine = engine
        self.serve = serve
        self.service = service
        self.requests: Dict[int, Request] = {}  #: guarded-by _lock
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.steps = 0  #: guarded-by _lock
        self.admission = admission or AdmissionController()
        self.shed_enabled = shed_enabled
        engine.register("gen.submit", self._submit, pass_handle=True)
        engine.register("gen.submit_bulk", self._submit_bulk,
                        pass_handle=True)
        engine.register("gen.result", self._result, pass_handle=True)
        engine.register("gen.generate", self._generate, pass_handle=True)
        engine.register("gen.stats", self._stats)
        self.instance = None
        self.member = None
        if registry is not None:
            # lazy import (like checkpoint/datafeed): services must not
            # hard-depend on fabric, keeping the layering acyclic
            from ..fabric.registry import ServiceInstance
            if member_id is not None:
                # the unified control plane serves mem.* from the same
                # quorum address set: join the membership plane and bind
                # the registration to it, so a dead gateway node is
                # reaped by member expiry (not just the instance TTL)
                from .membership import MembershipClient
                self.member = MembershipClient(engine, registry, member_id,
                                               heartbeat_interval=(
                                                   report_interval))
                self.member.join({"role": "gateway", "service": service})
            self.instance = ServiceInstance(
                engine, registry, service, capacity=serve.n_slots,
                load_fn=self._load, report_interval=report_interval,
                member_id=member_id)
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _load(self) -> float:
        """The piggybacked balancing signal: in-flight slot occupancy +
        queue depth + pinned-session pressure.  Pinned sessions hold no
        ``slot_req`` — a gateway whose batch is entirely pinned KV would
        report near-idle on active+queued alone, yet admitting a fresh
        request there costs an eviction (and some other session its
        cache), so they count at half weight."""
        s = self.serve.stats()
        return float(s["active_slots"] + s["queued"]
                     + 0.5 * s["pinned_sessions"])

    def _admit(self, handle) -> None:
        """Deadline-aware admission: shed with ``Ret.OVERLOAD`` when the
        backlog × EWMA service time says this request cannot finish
        within the caller's remaining deadline budget."""
        if not self.shed_enabled:
            return
        s = self.serve.stats()
        self.admission.admit(handle.remaining_budget(),
                             backlog=s["active_slots"] + s["queued"],
                             parallelism=max(s["n_slots"], 1))

    def _enqueue(self, req_in) -> Request:
        fe = req_in.get("frontend")
        t0 = time.monotonic()
        req = self.serve.submit(
            np.asarray(req_in["tokens"], np.int32),
            max_new=int(req_in.get("max_new", 32)),
            temperature=float(req_in.get("temperature", 0.0)),
            eos_id=int(req_in.get("eos_id", -1)),
            frontend=None if fe is None else np.asarray(fe, np.float32),
            session_id=req_in.get("session_id"))
        with self._lock:
            self.requests[req.rid] = req
        # feed the admission EWMA from every completion.  The EWMA that
        # drives shedding is PURE service time — measured from the
        # engine's slot-admission stamp (t_admit), not from submit —
        # because queue wait is already priced in via the backlog term;
        # measuring submit→done would double-count queueing right after
        # a burst and over-shed until the EWMA re-converged.  submit→done
        # is still recorded separately (ema_turnaround_ms in gen.stats).
        t_in = req.t_submit or t0
        _M_SUBMITS.inc()
        # the serve span outlives the RPC handler (gen.submit returns a
        # rid immediately): child of the ambient server span, finished
        # from the request's done callback with queue/service timings
        # split on the engine's slot-admission stamp
        span = _trace.start_span(f"{self.service}.serve", _trace.current())

        def _observe():
            now = time.monotonic()
            queue_s = max((req.t_admit or t_in) - t_in, 0.0)
            service_s = now - (req.t_admit or t_in)
            self.admission.observe(service_s, turnaround_s=now - t_in)
            _M_COMPLETIONS.inc()
            _M_TOKENS_OUT.inc(len(req.out_tokens))
            _M_QUEUE_MS.observe(queue_s * 1e3)
            _M_SERVICE_MS.observe(service_s * 1e3)
            if span.recorded:
                span.annotate(rid=req.rid,
                              queue_ms=round(queue_s * 1e3, 3),
                              service_ms=round(service_s * 1e3, 3),
                              new_tokens=len(req.out_tokens))
            span.finish("OK")

        req.add_done_callback(_observe)
        return req

    def _submit(self, req_in, handle):
        self._admit(handle)
        return {"rid": self._enqueue(req_in).rid}

    def _submit_bulk(self, req_in, handle):
        """Zero-copy submit: pull the prompt from the caller's registered
        memory (cheapest-tier transport chosen by address resolution)."""
        self._admit(handle)
        desc = BulkDescriptor.from_bytes(req_in["desc"])
        count = int(req_in.get("count", desc.size // 4))
        # count and the descriptor are client-controlled: never allocate
        # more than the descriptor can actually back
        if count < 0 or count * 4 > desc.size:
            raise ValueError(f"count {count} exceeds descriptor "
                             f"({desc.size} bytes)")
        tokens = np.empty(count, np.int32)
        lh = self.engine.expose([tokens])
        try:
            self.engine.pull(handle.info.addr, desc, lh,
                             size=count * 4)
        finally:
            lh.free()
        req_in = dict(req_in, tokens=tokens)
        out = {"rid": self._enqueue(req_in).rid}
        handle.respond(out)

    @staticmethod
    def _ttft_ms(req: Request) -> float:
        return round((req.t_first - req.t_submit) * 1e3, 3) \
            if req.t_first else -1.0

    def _result_payload(self, rid: int, req: Request) -> dict:
        done = req.done_event.is_set()
        out = {"tokens": list(req.out_tokens), "done": done,
               "ttft_ms": self._ttft_ms(req)}
        if done:
            with self._lock:
                self.requests.pop(rid, None)
        return out

    def _result(self, req_in, handle):
        rid = int(req_in["rid"])
        with self._lock:
            req = self.requests.get(rid)
        if req is None:
            handle.respond({"error": "unknown rid"})
            return
        if not req_in.get("wait") or req.done_event.is_set():
            handle.respond(self._result_payload(rid, req))
            return
        # Waiting path: respond from the request's done callback (or the
        # deadline timer) instead of parking this handler-pool thread.
        handle.deferred = True
        once = threading.Lock()
        state = {"sent": False}

        def finish():
            with once:
                if state["sent"]:
                    return
                state["sent"] = True
            try:
                handle.respond(self._result_payload(rid, req))
            except Exception as e:
                # e.g. MSGSIZE on a huge token payload: report instead of
                # letting the error escape into the caller's thread (the
                # serve step loop or the progress thread's deadline sweep)
                try:
                    if not handle.responded:
                        handle.respond(f"{type(e).__name__}: {e}",
                                       ret=Ret.FAULT)
                except Exception:
                    pass

        entry = self.engine.ctx.add_deadline(
            time.monotonic() + float(req_in.get("timeout", 60.0)), finish)

        def on_done():
            self.engine.ctx.disarm(entry)
            finish()

        req.add_done_callback(on_done)

    def _generate(self, req_in, handle):
        self._admit(handle)
        req = self._enqueue(req_in)
        req.done_event.wait(float(req_in.get("timeout", 120.0)))
        with self._lock:
            self.requests.pop(req.rid, None)
        return {"tokens": list(req.out_tokens),
                "done": req.done_event.is_set(),
                "ttft_ms": self._ttft_ms(req)}

    def _stats(self, _req):
        out = self.serve.stats()
        with self._lock:
            steps = self.steps
        lookups = out["prefix_hits"] + out["prefix_misses"]
        out.update(steps=steps, uris=self.engine.uri,
                   load=self._load(),
                   prefix_hit_rate=(out["prefix_hits"] / lookups
                                    if lookups else 0.0),
                   **self.admission.stats())
        return out

    def _loop(self):
        while not self._stop.is_set():
            n = self.serve.step()
            if n:
                with self._lock:
                    self.steps += 1
            if n == 0 and self.serve.pending() == 0:
                # park until the next submit (double-check after clearing
                # so a racing submit can't be missed; the bounded wait
                # caps the cost of any residual race)
                self.serve.work.clear()
                if self.serve.pending() == 0 and not self._stop.is_set():
                    self.serve.work.wait(0.05)

    def close(self):
        """Graceful stop: deregister from the fabric and join the step
        loop (idempotent)."""
        if self._stop.is_set():
            return
        if self.instance is not None:
            self.instance.close()
        if self.member is not None:
            self.member.leave()
        self._stop.set()
        self.serve.work.set()            # wake a parked step loop
        self._thread.join(timeout=2.0)

    stop = close
