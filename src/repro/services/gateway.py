"""Serving gateway: the Mercury RPC front door for the ServeEngine.

RPCs:
  ``gen.submit``   {tokens, max_new, temperature, eos_id[, frontend]}
                   → {rid}                      (non-blocking enqueue)
  ``gen.submit_bulk`` {desc, count, ...} — the prompt tokens stay in the
                   client's registered memory; the gateway pulls them
                   one-sidedly (zero-copy on sm/self transports) instead
                   of carrying them in the eager message
  ``gen.result``   {rid[, wait]} → {tokens, done}
  ``gen.generate`` blocking submit+wait (handler parks on the request's
                   done event — it runs on the engine's handler pool, so
                   the progress thread keeps spinning: exactly the
                   multithreaded-executor shim of paper C5)
  ``gen.stats``    → queue/slot utilization

A background thread drives ``ServeEngine.step()`` whenever work exists —
continuous batching across concurrently connected clients.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

import numpy as np

from ..core.bulk import BulkDescriptor
from ..core.executor import Engine
from ..serve.engine import Request, ServeEngine


class ServingGateway:
    def __init__(self, engine: Engine, serve: ServeEngine):
        self.engine = engine
        self.serve = serve
        self.requests: Dict[int, Request] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.steps = 0
        engine.register("gen.submit", self._submit)
        engine.register("gen.submit_bulk", self._submit_bulk,
                        pass_handle=True)
        engine.register("gen.result", self._result)
        engine.register("gen.generate", self._generate)
        engine.register("gen.stats", self._stats)
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _enqueue(self, req_in) -> Request:
        fe = req_in.get("frontend")
        req = self.serve.submit(
            np.asarray(req_in["tokens"], np.int32),
            max_new=int(req_in.get("max_new", 32)),
            temperature=float(req_in.get("temperature", 0.0)),
            eos_id=int(req_in.get("eos_id", -1)),
            frontend=None if fe is None else np.asarray(fe, np.float32))
        with self._lock:
            self.requests[req.rid] = req
        return req

    def _submit(self, req_in):
        return {"rid": self._enqueue(req_in).rid}

    def _submit_bulk(self, req_in, handle):
        """Zero-copy submit: pull the prompt from the caller's registered
        memory (cheapest-tier transport chosen by address resolution)."""
        desc = BulkDescriptor.from_bytes(req_in["desc"])
        count = int(req_in.get("count", desc.size // 4))
        # count and the descriptor are client-controlled: never allocate
        # more than the descriptor can actually back
        if count < 0 or count * 4 > desc.size:
            raise ValueError(f"count {count} exceeds descriptor "
                             f"({desc.size} bytes)")
        tokens = np.empty(count, np.int32)
        lh = self.engine.expose([tokens])
        try:
            self.engine.pull(handle.info.addr, desc, lh,
                             size=count * 4)
        finally:
            lh.free()
        req_in = dict(req_in, tokens=tokens)
        out = {"rid": self._enqueue(req_in).rid}
        handle.respond(out)

    def _result(self, req_in):
        rid = int(req_in["rid"])
        with self._lock:
            req = self.requests.get(rid)
        if req is None:
            return {"error": "unknown rid"}
        if req_in.get("wait"):
            req.done_event.wait(float(req_in.get("timeout", 60.0)))
        done = req.done_event.is_set()
        out = {"tokens": list(req.out_tokens), "done": done}
        if done:
            with self._lock:
                self.requests.pop(rid, None)
        return out

    def _generate(self, req_in):
        req = self._enqueue(req_in)
        req.done_event.wait(float(req_in.get("timeout", 120.0)))
        with self._lock:
            self.requests.pop(req.rid, None)
        return {"tokens": list(req.out_tokens),
                "done": req.done_event.is_set()}

    def _stats(self, _req):
        out = self.serve.stats()
        out.update(steps=self.steps, uris=self.engine.uri)
        return out

    def _loop(self):
        while not self._stop.is_set():
            n = self.serve.step()
            self.steps += 1 if n else 0
            if n == 0 and self.serve.queue.empty():
                time.sleep(0.005)

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
