"""Checkpoint service — Mercury's bulk-data design applied to model state.

Save path (client → server):
  1. client snapshots the state pytree to host numpy buffers,
  2. registers them as ONE multi-segment bulk handle,
  3. sends a small ``ckpt.put`` RPC carrying only the *descriptor*
     + manifest (shapes/dtypes/Fletcher-64 checksums),
  4. the server pulls the payload one-sidedly (pipelined chunks),
     verifies checksums, stores, responds.
The RPC itself stays tiny no matter how many GB the checkpoint is —
exactly the paper's bulk/eager split (C3).

Restore reverses the flow: ``ckpt.get`` returns the manifest + a
server-side descriptor; the client pulls and verifies.

``async_save`` = device→host copy now, bulk push on a background thread
(training continues during the transfer).
"""
from __future__ import annotations

import concurrent.futures as cf
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..core.bulk import BulkDescriptor
from ..core.executor import Engine
from ..core.types import MercuryError, Ret
from .base import (alloc_from_manifest, checksum_of, flatten_named,
                   manifest_of, unflatten_named, verify_manifest)


class CheckpointServer:
    """Hosts checkpoints in memory; every stored shard set stays
    registered for one-sided restore pulls.  With ``registry=`` the
    server registers itself as an instance of service ``service`` so
    clients can resolve it by name through the fabric."""

    def __init__(self, engine: Engine, registry: Optional[str] = None,
                 service: str = "ckpt"):
        self.engine = engine
        self.store: Dict[Tuple[str, int], dict] = {}  #: guarded-by _lock
        self._lock = threading.Lock()
        engine.register("ckpt.put", self._put)
        engine.register("ckpt.get", self._get)
        engine.register("ckpt.list", self._list)
        engine.register("ckpt.delete", self._delete)
        self.instance = None
        if registry is not None:
            from ..fabric.registry import ServiceInstance
            self.instance = ServiceInstance(
                engine, registry, service,
                load_fn=lambda: float(self._count()))

    def _count(self) -> int:
        with self._lock:
            return len(self.store)

    def close(self) -> None:
        if self.instance is not None:
            self.instance.close()

    # -- handlers (run on the engine's handler pool) -------------------------
    def _put(self, req):
        name, step = req["name"], int(req["step"])
        man = req["manifest"]
        desc = BulkDescriptor.from_bytes(req["desc"])
        named = alloc_from_manifest(man)
        local = self.engine.expose(list(named.values()), read=False,
                                   write=True)
        try:
            self.engine.pull(req["origin"], desc, local)
        finally:
            pass  # keep registered? no — re-registered below for gets
        verify_manifest(man, named)
        local.free()
        handle = self.engine.expose(list(named.values()), read=True,
                                    write=False)
        with self._lock:
            old = self.store.pop((name, step), None)
            if old:
                old["handle"].free()
            self.store[(name, step)] = {
                "named": named, "manifest": man, "handle": handle,
                "time": time.time(),
            }
        return {"ok": True, "stored": len(named)}

    def _get(self, req):
        name = req["name"]
        step = req.get("step")
        with self._lock:
            if step is None:
                steps = [s for (n, s) in self.store if n == name]
                if not steps:
                    raise MercuryError(Ret.NOENTRY, f"no checkpoint {name}")
                step = max(steps)
            entry = self.store.get((name, int(step)))
        if entry is None:
            raise MercuryError(Ret.NOENTRY, f"no checkpoint {name}@{step}")
        return {
            "step": int(step),
            "manifest": entry["manifest"],
            "desc": entry["handle"].descriptor().to_bytes(),
            "origin": self.engine.uri,
        }

    def _list(self, _req):
        with self._lock:
            return {"checkpoints": [
                {"name": n, "step": s, "time": e["time"]}
                for (n, s), e in sorted(self.store.items())]}

    def _delete(self, req):
        with self._lock:
            e = self.store.pop((req["name"], int(req["step"])), None)
            if e:
                e["handle"].free()
        return {"ok": e is not None}


class CheckpointClient:
    def __init__(self, engine: Engine, server_uri: Optional[str] = None,
                 registry: Optional[str] = None, service: str = "ckpt",
                 cache_ttl: float = 0.0):
        """Address either directly (``server_uri``) or by service name
        through the fabric registry (``registry=`` + ``service=``).

        ``cache_ttl > 0`` caches ``ckpt.list`` reads (DESIGN.md §9):
        the server has no epoch stream, so validity is TTL-bounded plus
        self-invalidation — this client's own ``save``/``delete`` drop
        the cache immediately (read-your-writes), while other writers'
        checkpoints appear within the TTL."""
        self.engine = engine
        if server_uri is None:
            if registry is None:
                raise ValueError("need server_uri or registry")
            from ..fabric.registry import resolve_service_uris
            server_uri = resolve_service_uris(engine, registry, service)[0]
        self.server = server_uri
        from ..fabric.readcache import ReadCache
        self.cache = ReadCache(ttl=cache_ttl)
        self._pool = cf.ThreadPoolExecutor(max_workers=1,
                                           thread_name_prefix="ckpt-async")

    def save(self, name: str, step: int, tree) -> dict:
        named = flatten_named(tree)
        man = manifest_of(named)
        handle = self.engine.expose(list(named.values()), read=True,
                                    write=False)
        try:
            out = self.engine.call(self.server, "ckpt.put", {
                "name": name, "step": step, "manifest": man,
                "desc": handle.descriptor().to_bytes(),
                "origin": self.engine.uri,
            }, timeout=120.0)
            self.cache.invalidate()       # read-your-writes for list()
            return out
        finally:
            handle.free()

    def async_save(self, name: str, step: int, tree) -> cf.Future:
        """Snapshot now (host copies), transfer in the background."""
        named = flatten_named(tree)          # device→host copy happens here

        def push():
            man = manifest_of(named)
            handle = self.engine.expose(list(named.values()), read=True,
                                        write=False)
            try:
                out = self.engine.call(self.server, "ckpt.put", {
                    "name": name, "step": step, "manifest": man,
                    "desc": handle.descriptor().to_bytes(),
                    "origin": self.engine.uri,
                }, timeout=120.0)
                self.cache.invalidate()   # read-your-writes for list()
                return out
            finally:
                handle.free()

        return self._pool.submit(push)

    def restore(self, name: str, template, step: Optional[int] = None):
        """Returns (tree shaped like template, step)."""
        meta = self.engine.call(self.server, "ckpt.get",
                                {"name": name, "step": step}, timeout=60.0)
        man = meta["manifest"]
        named = alloc_from_manifest(man)
        local = self.engine.expose(list(named.values()), read=False,
                                   write=True)
        try:
            self.engine.pull(meta["origin"],
                             BulkDescriptor.from_bytes(meta["desc"]), local)
        finally:
            local.free()
        verify_manifest(man, named)
        return unflatten_named(template, named), meta["step"]

    def delete(self, name: str, step: int) -> bool:
        ok = self.engine.call(self.server, "ckpt.delete",
                              {"name": name, "step": step})["ok"]
        self.cache.invalidate()           # read-your-writes for list()
        return ok

    def list(self, fresh: bool = False) -> list:
        return self.cache.get_or_call(
            "ckpt.list", {},
            lambda: self.engine.call(self.server, "ckpt.list", {}),
            fresh=fresh)["checkpoints"]
