"""Token data pipeline: synthetic + memmap sources, shard-aware,
background-prefetched.

``TokenSource`` implementations produce (tokens, targets) numpy batches
for *this host's shard* of the global batch.  ``Prefetcher`` keeps N
batches in flight on a worker thread so a slow source never stalls the
step (the local half of straggler mitigation; the distributed half is
the datafeed service's replicated RPC issue).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class ShardInfo:
    host_id: int = 0
    num_hosts: int = 1


class SyntheticSource:
    """Deterministic zipf-ish token stream (reproducible per host/step)."""

    def __init__(self, vocab: int, seq_len: int, batch_per_host: int,
                 shard: ShardInfo = ShardInfo(), seed: int = 0,
                 frontend: Optional[tuple] = None):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch_per_host
        self.shard = shard
        self.seed = seed
        self.frontend = frontend            # (frontend_seq, frontend_dim)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + self.shard.host_id)
        # zipf-flavored distribution clipped to the vocab
        z = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        toks = (z % (self.vocab - 2)) + 1
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }
        if self.frontend:
            fs, fd = self.frontend
            batch["frontend"] = rng.standard_normal(
                (self.batch, fs, fd)).astype(np.float32) * 0.1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapSource:
    """Flat binary token file (uint16/uint32), sampled in contiguous
    windows — the standard packed-corpus layout."""

    def __init__(self, path: str, vocab: int, seq_len: int,
                 batch_per_host: int, dtype=np.uint16,
                 shard: ShardInfo = ShardInfo(), seed: int = 0):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch_per_host
        self.shard = shard
        self.seed = seed
        self.n_windows = (len(self.data) - 1) // seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 97 + self.shard.host_id)
        idx = rng.integers(0, self.n_windows, size=self.batch)
        toks = np.stack([
            np.asarray(self.data[i * self.seq_len:
                                 i * self.seq_len + self.seq_len + 1])
            for i in idx]).astype(np.int32)
        toks = np.clip(toks, 0, self.vocab - 1)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Runs a source iterator on a daemon thread, N batches ahead."""

    def __init__(self, source, depth: int = 2):
        self._it = iter(source)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        except Exception as e:                      # surface in next()
            self._q.put(e)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
