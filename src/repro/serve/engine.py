"""KV-cache serving engine: continuous batching, chunked prefill, and
KV session reuse.

A fixed pool of ``n_slots`` sequence slots shares one batched cache
pytree.  New requests prefill into a free slot (B=1 prefill, scatter at
the cache's batch dim — located via the cache's logical axes); every
``step()`` decodes *all* active slots in lockstep with per-slot positions
(the vector-``pos`` decode path).  Finished slots free immediately and
the next queued request takes over — classic continuous batching.

**Chunked prefill** (``chunk_tokens > 0``): instead of one monolithic
prompt pass that monopolizes the step loop, the prompt lands in
fixed-size chunks — one chunk per ``step()``, interleaved with the
decode of every other active slot — so a long prompt no longer hides the
TTFT of queued short requests behind it.  The last chunk is padded to
the fixed size (one jit compile for any prompt length; the padded
garbage K/V sit *above* the live position and are overwritten by decode
writes before any query can attend them).  Requires
``model.supports_chunked_prefill`` (attention-family blocks only);
otherwise the engine silently falls back to monolithic prefill.

**KV sessions** (``session_cap > 0``): when a request carries a
``session_id``, the slot's KV cache stays *pinned in its slot* after the
request finishes (``slot_req`` is freed; the session table remembers the
slot, the token history and the live position).  A follow-up submit with
the same ``session_id`` whose prompt extends the cached history resumes
from the cached position — only the suffix is prefilled (through the
chunk path, at an offset).  Pinned slots are evicted LRU-first whenever
a fresh request needs a slot or the table exceeds ``session_cap``;
correctness never depends on the cache (a miss is just a full prefill).

The Mercury serving gateway (services/gateway.py) drives this engine from
RPC handlers; ``generate()`` is the synchronous convenience wrapper used
by examples and tests.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Model, unzip
from ..models.common import P, is_p
from ..telemetry import metrics as _metrics

# unified metrics (fab.metrics exports these; the per-engine view is in
# stats()/gen.stats): session-reuse effectiveness + slot pressure
_M_PREFIX_HITS = _metrics.counter("serve.engine.prefix_hits")
_M_PREFIX_MISSES = _metrics.counter("serve.engine.prefix_misses")
_M_TOKENS_SAVED = _metrics.counter("serve.engine.prefix_tokens_saved")
_M_EVICTIONS = _metrics.counter("serve.engine.session_evictions")
_G_OCCUPANCY = _metrics.gauge("serve.engine.occupancy")
_G_PINNED = _metrics.gauge("serve.engine.pinned_sessions")

# chunk size used for session *resume* when chunked prefill is otherwise
# disabled (the resume path is built on prefill-at-an-offset)
_RESUME_CHUNK = 32


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new: int = 32
    temperature: float = 0.0           # 0 = greedy
    eos_id: int = -1                   # -1 = never
    frontend: Optional[np.ndarray] = None
    session_id: Optional[str] = None   # KV-session key (None = stateless)
    out_tokens: List[int] = field(default_factory=list)
    done_event: threading.Event = field(default_factory=threading.Event)
    on_token: Optional[Callable[[int, int], None]] = None
    # monotonic time of submit(); the gateway derives submit→done
    # turnaround (queue wait included) from this stamp
    t_submit: float = 0.0
    # monotonic time the request took a slot (prefill start); the
    # gateway's AdmissionController measures its *pure service time*
    # EWMA (slot occupancy, admit→done) from this, keeping queue wait
    # out of the shedding estimate
    t_admit: float = 0.0
    # monotonic time of the first emitted token (TTFT = t_first-t_submit)
    t_first: float = 0.0
    _done_cbs: List[Callable[[], None]] = field(default_factory=list)  #: guarded-by _cb_lock
    _cb_lock: threading.Lock = field(default_factory=threading.Lock)

    def add_done_callback(self, cb: Callable[[], None]) -> None:
        """Run ``cb`` when the request completes (immediately if it
        already has) — lets RPC handlers respond event-driven instead of
        parking a handler-pool thread on ``done_event.wait``."""
        with self._cb_lock:
            if not self.done_event.is_set():
                self._done_cbs.append(cb)
                return
        cb()

    def _fire_done(self) -> None:
        with self._cb_lock:
            cbs, self._done_cbs = self._done_cbs, []
        for cb in cbs:
            try:
                cb()
            except Exception:
                pass       # a failing waiter must not kill the step loop


class ServeEngine:
    def __init__(self, model: Model, params, *, max_len: int = 512,
                 n_slots: int = 4, seed: int = 0, impl: str = "auto",
                 chunk_tokens: int = 0, session_cap: int = 0,
                 cache_dtype=None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.n_slots = n_slots
        self.impl = impl
        self.cache_dtype = cache_dtype or jnp.bfloat16
        cache_p = model.cache_specs(n_slots, max_len, dtype=self.cache_dtype)
        self.cache, self.cache_axes = unzip(cache_p)
        self.pos = np.zeros((n_slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.last_tok = np.zeros((n_slots,), np.int32)
        self.queue: "queue.Queue[Request]" = queue.Queue()
        # set on submit: idle step loops wait on this instead of polling
        self.work = threading.Event()
        self._rng = jax.random.PRNGKey(seed)
        self._rid = 0  #: guarded-by _lock
        self._lock = threading.Lock()

        # chunked prefill + sessions need continuation-at-an-offset,
        # which only attention-family caches support; fall back silently
        # (stats() exposes the effective configuration)
        chunkable = model.supports_chunked_prefill
        self.chunk = int(chunk_tokens) if (chunk_tokens and chunkable) else 0
        self.session_cap = int(session_cap) if (session_cap
                                                and chunkable) else 0
        # admit-order backlog (step-thread only): requests drained from
        # the thread-safe submit queue but not yet placed in a slot
        self._pending: Deque[Request] = deque()
        # slot -> in-progress chunked-prefill state (step-thread only)
        self._prefill: Dict[int, dict] = {}
        # sid -> {"slot", "tokens", "pos"}; iteration order == LRU
        self.sessions: "OrderedDict[str, dict]" = OrderedDict()
        # session bound to each slot: for an *active* request, the sid it
        # will pin on completion; for a free slot, the pinned session
        self.slot_session: List[Optional[str]] = [None] * n_slots
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.prefix_tokens_saved = 0
        self.session_evictions = 0

        self._prefill_jit = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len=max_len,
                                            impl=impl))
        self._decode_jit = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, c, t, pos,
                                                        impl=impl))
        if self.chunk or self.session_cap:
            self._chunk_jit = jax.jit(
                lambda p, c, t, off: self.model.prefill_chunk(p, c, t, off,
                                                              impl=impl))
            # zeroed B=1 staging cache, shared template for fresh prompts
            self._cache1_zero, _ = unzip(
                model.cache_specs(1, max_len, dtype=self.cache_dtype))

        # slot gather/scatter as single jitted executables (slot index is
        # a traced scalar: one compile covers every slot).  Eagerly
        # dispatching one dynamic-slice per cache leaf costs milliseconds
        # per request on the resume path — comparable to the chunk itself
        def _gather(cache, slot):
            def one(src, axes):
                return jax.lax.dynamic_slice_in_dim(
                    src, slot, 1, axis=axes.index("batch"))
            return jax.tree_util.tree_map(
                one, cache, self.cache_axes,
                is_leaf=lambda x: hasattr(x, "shape")
                and not isinstance(x, dict))

        def _scatter(cache, cache1, slot):
            def one(dst, src, axes):
                return jax.lax.dynamic_update_slice_in_dim(
                    dst, src.astype(dst.dtype), slot,
                    axis=axes.index("batch"))
            return jax.tree_util.tree_map(
                one, cache, cache1, self.cache_axes,
                is_leaf=lambda x: hasattr(x, "shape")
                and not isinstance(x, dict))

        self._gather_jit = jax.jit(_gather)
        self._scatter_jit = jax.jit(_scatter)

    # ------------------------------------------------------------------ slots
    def _scatter_slot(self, cache, cache1, slot: int):
        """Insert a B=1 cache into the engine cache at ``slot`` (batch dim
        found via logical axes)."""
        return self._scatter_jit(cache, cache1, jnp.int32(slot))

    def _gather_slot(self, slot: int):
        """Extract slot ``slot`` of the engine cache as a B=1 cache (the
        staging tree a resumed session's suffix chunks continue into)."""
        return self._gather_jit(self.cache, jnp.int32(slot))

    def submit(self, prompt, max_new: int = 32, temperature: float = 0.0,
               eos_id: int = -1, frontend=None,
               on_token=None, session_id=None) -> Request:
        prompt = np.asarray(prompt, np.int32)
        span = len(prompt) + (self.model.cfg.frontend_seq
                              if frontend is not None else 0)
        if span + max_new > self.max_len:
            raise ValueError(
                f"prompt span {span} + max_new {max_new} exceeds the "
                f"cache length {self.max_len}")
        if frontend is not None:
            session_id = None       # sessions are token-prefix keyed
        with self._lock:
            self._rid += 1
            rid = self._rid
        req = Request(rid, prompt, max_new,
                      temperature, eos_id, frontend,
                      session_id=session_id, on_token=on_token)
        req.t_submit = time.monotonic()
        self.queue.put(req)
        self.work.set()
        return req

    def pending(self) -> int:
        """Requests submitted but not yet placed in a slot."""
        return self.queue.qsize() + len(self._pending)

    def stats(self) -> Dict[str, Any]:
        busy = sum(1 for r in self.slot_req if r is not None)
        pinned = len(self.sessions)
        occupancy = busy / max(self.n_slots, 1)
        _G_OCCUPANCY.set(occupancy)
        _G_PINNED.set(pinned)
        return {"active_slots": busy,
                "n_slots": self.n_slots, "queued": self.pending(),
                "max_len": self.max_len,
                "occupancy": occupancy,
                "prefilling": len(self._prefill),
                "pinned_sessions": pinned,
                "session_capacity": self.session_cap,
                "session_evictions": self.session_evictions,
                "chunk_tokens": self.chunk,
                "prefix_hits": self.prefix_hits,
                "prefix_misses": self.prefix_misses,
                "prefix_tokens_saved": self.prefix_tokens_saved}

    # ---------------------------------------------------------------- sessions
    def _evict(self, sid: str) -> int:
        """Drop a pinned session; returns the slot it freed."""
        st = self.sessions.pop(sid)
        self.slot_session[st["slot"]] = None
        self.session_evictions += 1
        _M_EVICTIONS.inc()
        return st["slot"]

    def _take_slot(self) -> Optional[int]:
        """A slot for a fresh request: truly free first, else evict the
        LRU pinned session; None when every slot is actively decoding."""
        for i, r in enumerate(self.slot_req):
            if r is None and self.slot_session[i] is None:
                return i
        for sid in list(self.sessions):          # OrderedDict: LRU first
            if self.slot_req[self.sessions[sid]["slot"]] is None:
                return self._evict(sid)
        return None

    def _release_slot(self, slot: int) -> None:
        """Free a finished slot; with sessions enabled and a session id
        bound, the KV stays pinned in the slot under that id."""
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        sid = self.slot_session[slot]
        self.slot_session[slot] = None
        if sid is None or req is None or self.session_cap <= 0:
            return
        # cache holds positions 0..pos-1 = full prompt + all emitted
        # tokens except the last (its K/V was never written)
        tokens = np.concatenate([
            np.asarray(req.prompt, np.int32),
            np.asarray(req.out_tokens[:-1], np.int32)])
        if len(tokens) != int(self.pos[slot]):
            return                      # frontend span etc.: not resumable
        old = self.sessions.pop(sid, None)
        if old is not None:
            self.slot_session[old["slot"]] = None
        while len(self.sessions) >= self.session_cap:
            self._evict(next(iter(self.sessions)))
        self.sessions[sid] = {"slot": slot, "tokens": tokens,
                              "pos": int(self.pos[slot])}
        self.slot_session[slot] = sid

    # ------------------------------------------------------------------ admit
    def _admit(self):
        while True:
            try:
                self._pending.append(self.queue.get_nowait())
            except queue.Empty:
                break
        while self._pending:
            req = self._pending[0]
            sid = req.session_id if self.session_cap > 0 else None
            st = self.sessions.get(sid) if sid is not None else None
            if st is not None:
                n = st["pos"]
                if (len(req.prompt) > n
                        and np.array_equal(req.prompt[:n], st["tokens"])):
                    # session hit: resume in the pinned slot, prefill
                    # only the suffix at the cached offset
                    self._pending.popleft()
                    slot = st["slot"]
                    self.sessions.pop(sid)       # re-pinned on completion
                    self.prefix_hits += 1
                    self.prefix_tokens_saved += n
                    _M_PREFIX_HITS.inc()
                    _M_TOKENS_SAVED.inc(n)
                    req.t_admit = time.monotonic()
                    self.slot_req[slot] = req
                    self.slot_session[slot] = sid
                    self._start_chunked(slot, req, req.prompt[n:], base=n,
                                        cache1=self._gather_slot(slot))
                    continue
                # stale prefix: the cached KV is useless for this prompt
                self._evict(sid)
            if sid is not None:
                self.prefix_misses += 1
                _M_PREFIX_MISSES.inc()
            slot = self._take_slot()
            if slot is None:
                return                   # every slot actively decoding
            self._pending.popleft()
            req.t_admit = time.monotonic()
            self.slot_req[slot] = req
            self.slot_session[slot] = sid
            if self.chunk and req.frontend is None:
                self._start_chunked(slot, req, req.prompt, base=0,
                                    cache1=self._cache1_zero)
            else:
                self._prefill_monolithic(slot, req)

    def _prefill_monolithic(self, slot: int, req: Request):
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        if req.frontend is not None:
            batch["frontend"] = jnp.asarray(req.frontend[None])
        logits, cache1 = self._prefill_jit(self.params, batch)
        self.cache = self._scatter_slot(self.cache, cache1, slot)
        tok = self._sample(logits[0], req)
        prompt_span = len(req.prompt) + (
            self.model.cfg.frontend_seq
            if req.frontend is not None else 0)
        self.pos[slot] = prompt_span
        self.last_tok[slot] = tok
        self._emit(req, tok)
        if req.done_event.is_set():
            self._release_slot(slot)

    # ---------------------------------------------------------------- chunked
    def _start_chunked(self, slot: int, req: Request, suffix, *, base: int,
                       cache1):
        """Queue a chunked prefill: ``suffix`` tokens land at absolute
        positions ``base..`` of the B=1 staging cache, one chunk per
        step().  Padded to the fixed chunk size so any prompt length
        reuses one jit compile (padded K/V sit above the live position;
        decode overwrites them before they become visible)."""
        C = self.chunk or _RESUME_CHUNK
        toks = np.asarray(suffix, np.int32)
        n = len(toks)
        pad = (-n) % C
        if pad:
            toks = np.concatenate([toks, np.zeros(pad, np.int32)])
        self._prefill[slot] = {"req": req, "cache1": cache1, "toks": toks,
                               "n": n, "off": 0, "base": base}

    def _prefill_step(self, slot: int, st: dict):
        """Advance one chunk; on the final chunk, scatter the staged
        cache into the slot and emit the first sampled token."""
        C = self.chunk or _RESUME_CHUNK
        req = st["req"]
        chunk = jnp.asarray(st["toks"][st["off"]:st["off"] + C][None, :])
        off = st["base"] + st["off"]
        logits, st["cache1"] = self._chunk_jit(self.params, st["cache1"],
                                               chunk, jnp.int32(off))
        st["off"] += C
        if st["off"] < st["n"]:
            return
        # prefill complete
        del self._prefill[slot]
        last = st["n"] - 1 - (st["off"] - C)   # last real token, this chunk
        self.cache = self._scatter_slot(self.cache, st["cache1"], slot)
        self.pos[slot] = st["base"] + st["n"]
        tok = self._sample(logits[0, last], req)
        self.last_tok[slot] = tok
        self._emit(req, tok)
        if req.done_event.is_set():
            self._release_slot(slot)

    def _sample(self, logits, req: Request) -> int:
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits))
        self._rng, k = jax.random.split(self._rng)
        return int(jax.random.categorical(k, logits / req.temperature))

    def _emit(self, req: Request, tok: int):
        if not req.out_tokens:
            req.t_first = time.monotonic()
        req.out_tokens.append(tok)
        if req.on_token:
            req.on_token(req.rid, tok)
        if tok == req.eos_id or len(req.out_tokens) >= req.max_new:
            req.done_event.set()
            req._fire_done()

    # ------------------------------------------------------------------ step
    def step(self) -> int:
        """One engine step: admit, advance one prefill chunk per
        prefilling slot, one decode step for all decoding slots; returns
        #occupied slots (decoding + mid-prefill)."""
        self._admit()
        for slot in list(self._prefill):
            self._prefill_step(slot, self._prefill[slot])
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and i not in self._prefill]
        if active:
            toks = jnp.asarray(self.last_tok[:, None])
            pos = jnp.asarray(self.pos)
            logits, self.cache = self._decode_jit(self.params, self.cache,
                                                  toks, pos)
            for i in active:
                req = self.slot_req[i]
                if req.done_event.is_set():
                    self._release_slot(i)
                    continue
                tok = self._sample(logits[i], req)
                self.pos[i] += 1
                self.last_tok[i] = tok
                self._emit(req, tok)
                if req.done_event.is_set():
                    self._release_slot(i)
        return sum(1 for r in self.slot_req if r is not None)

    def drain(self):
        """Run steps until queue and slots are empty (pinned sessions
        hold no slot_req and do not block draining)."""
        while True:
            n = self.step()
            if n == 0 and self.pending() == 0:
                return

    def generate(self, prompts, max_new: int = 32, temperature: float = 0.0,
                 eos_id: int = -1, frontends=None,
                 session_ids=None) -> List[List[int]]:
        reqs = [self.submit(p, max_new, temperature, eos_id,
                            None if frontends is None else frontends[i],
                            session_id=(None if session_ids is None
                                        else session_ids[i]))
                for i, p in enumerate(prompts)]
        self.drain()
        return [r.out_tokens for r in reqs]
