"""KV-cache serving engine with continuous batching.

A fixed pool of ``n_slots`` sequence slots shares one batched cache
pytree.  New requests prefill into a free slot (B=1 prefill, scatter at
the cache's batch dim — located via the cache's logical axes); every
``step()`` decodes *all* active slots in lockstep with per-slot positions
(the vector-``pos`` decode path).  Finished slots free immediately and
the next queued request takes over — classic continuous batching.

The Mercury serving gateway (services/gateway.py) drives this engine from
RPC handlers; ``generate()`` is the synchronous convenience wrapper used
by examples and tests.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Model, unzip
from ..models.common import P, is_p


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new: int = 32
    temperature: float = 0.0           # 0 = greedy
    eos_id: int = -1                   # -1 = never
    frontend: Optional[np.ndarray] = None
    out_tokens: List[int] = field(default_factory=list)
    done_event: threading.Event = field(default_factory=threading.Event)
    on_token: Optional[Callable[[int, int], None]] = None
    # monotonic time of submit(); the gateway derives submit→done
    # turnaround (queue wait included) from this stamp
    t_submit: float = 0.0
    # monotonic time the request took a slot (prefill start); the
    # gateway's AdmissionController measures its *pure service time*
    # EWMA (slot occupancy, admit→done) from this, keeping queue wait
    # out of the shedding estimate
    t_admit: float = 0.0
    _done_cbs: List[Callable[[], None]] = field(default_factory=list)  #: guarded-by _cb_lock
    _cb_lock: threading.Lock = field(default_factory=threading.Lock)

    def add_done_callback(self, cb: Callable[[], None]) -> None:
        """Run ``cb`` when the request completes (immediately if it
        already has) — lets RPC handlers respond event-driven instead of
        parking a handler-pool thread on ``done_event.wait``."""
        with self._cb_lock:
            if not self.done_event.is_set():
                self._done_cbs.append(cb)
                return
        cb()

    def _fire_done(self) -> None:
        with self._cb_lock:
            cbs, self._done_cbs = self._done_cbs, []
        for cb in cbs:
            try:
                cb()
            except Exception:
                pass       # a failing waiter must not kill the step loop


class ServeEngine:
    def __init__(self, model: Model, params, *, max_len: int = 512,
                 n_slots: int = 4, seed: int = 0, impl: str = "auto"):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.n_slots = n_slots
        self.impl = impl
        cache_p = model.cache_specs(n_slots, max_len)
        self.cache, self.cache_axes = unzip(cache_p)
        self.pos = np.zeros((n_slots,), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.last_tok = np.zeros((n_slots,), np.int32)
        self.queue: "queue.Queue[Request]" = queue.Queue()
        # set on submit: idle step loops wait on this instead of polling
        self.work = threading.Event()
        self._rng = jax.random.PRNGKey(seed)
        self._rid = 0  #: guarded-by _lock
        self._lock = threading.Lock()

        self._prefill_jit = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len=max_len,
                                            impl=impl))
        self._decode_jit = jax.jit(
            lambda p, c, t, pos: self.model.decode_step(p, c, t, pos,
                                                        impl=impl))

    # ------------------------------------------------------------------ slots
    def _scatter_slot(self, cache, cache1, slot: int):
        """Insert a B=1 cache into the engine cache at ``slot`` (batch dim
        found via logical axes)."""
        def one(dst, src, axes):
            b = axes.index("batch")
            idx = tuple([slice(None)] * b + [slot])
            return dst.at[idx].set(src.astype(dst.dtype)[
                tuple([slice(None)] * b + [0])])
        return jax.tree_util.tree_map(
            one, cache, cache1, self.cache_axes,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))

    def submit(self, prompt, max_new: int = 32, temperature: float = 0.0,
               eos_id: int = -1, frontend=None,
               on_token=None) -> Request:
        prompt = np.asarray(prompt, np.int32)
        span = len(prompt) + (self.model.cfg.frontend_seq
                              if frontend is not None else 0)
        if span + max_new > self.max_len:
            raise ValueError(
                f"prompt span {span} + max_new {max_new} exceeds the "
                f"cache length {self.max_len}")
        with self._lock:
            self._rid += 1
            rid = self._rid
        req = Request(rid, prompt, max_new,
                      temperature, eos_id, frontend, on_token=on_token)
        req.t_submit = time.monotonic()
        self.queue.put(req)
        self.work.set()
        return req

    def stats(self) -> Dict[str, int]:
        return {"active_slots": sum(1 for r in self.slot_req if r is not None),
                "n_slots": self.n_slots, "queued": self.queue.qsize(),
                "max_len": self.max_len}

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        for slot in self._free_slots():
            try:
                req = self.queue.get_nowait()
            except queue.Empty:
                return
            req.t_admit = time.monotonic()
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            if req.frontend is not None:
                batch["frontend"] = jnp.asarray(req.frontend[None])
            logits, cache1 = self._prefill_jit(self.params, batch)
            self.cache = self._scatter_slot(self.cache, cache1, slot)
            tok = self._sample(logits[0], req)
            prompt_span = len(req.prompt) + (
                self.model.cfg.frontend_seq
                if req.frontend is not None else 0)
            self.pos[slot] = prompt_span
            self.slot_req[slot] = req
            self.last_tok[slot] = tok
            self._emit(req, tok)

    def _sample(self, logits, req: Request) -> int:
        if req.temperature <= 0.0:
            return int(jnp.argmax(logits))
        self._rng, k = jax.random.split(self._rng)
        return int(jax.random.categorical(k, logits / req.temperature))

    def _emit(self, req: Request, tok: int):
        req.out_tokens.append(tok)
        if req.on_token:
            req.on_token(req.rid, tok)
        if tok == req.eos_id or len(req.out_tokens) >= req.max_new:
            req.done_event.set()
            req._fire_done()

    # ------------------------------------------------------------------ step
    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        toks = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode_jit(self.params, self.cache,
                                              toks, pos)
        for i in active:
            req = self.slot_req[i]
            if req.done_event.is_set():
                self.slot_req[i] = None
                continue
            tok = self._sample(logits[i], req)
            self.pos[i] += 1
            self.last_tok[i] = tok
            self._emit(req, tok)
            if req.done_event.is_set():
                self.slot_req[i] = None
        return len([r for r in self.slot_req if r is not None])

    def drain(self):
        """Run steps until queue and slots are empty."""
        while True:
            n = self.step()
            if n == 0 and self.queue.empty():
                return

    def generate(self, prompts, max_new: int = 32, temperature: float = 0.0,
                 eos_id: int = -1, frontends=None) -> List[List[int]]:
        reqs = [self.submit(p, max_new, temperature, eos_id,
                            None if frontends is None else frontends[i])
                for i, p in enumerate(prompts)]
        self.drain()
        return [r.out_tokens for r in reqs]
