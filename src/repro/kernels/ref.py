"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` of the spec).

These are written for *clarity and obvious correctness*, not speed: naive
full-materialization attention, step-by-step recurrences.  Kernel tests
sweep shapes/dtypes and ``assert_allclose`` the Pallas (interpret=True)
and the fast-XLA implementations in ``ops.py`` against these.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def attention_mask(q_len: int, kv_len: int, *, q_offset: int = 0,
                   causal: bool = True, window: int = 0,
                   prefix_len: Optional[jax.Array] = None) -> jax.Array:
    """(q_len, kv_len) boolean mask. ``q_offset`` is the absolute position
    of query row 0 (decode: kv_len-1).  ``window`` > 0 restricts keys to
    the last ``window`` positions (sliding-window / local attention).
    ``prefix_len`` (scalar) makes positions < prefix_len bidirectional
    (prefix-LM, paligemma)."""
    qpos = jnp.arange(q_len)[:, None] + q_offset
    kpos = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        causal_m = kpos <= qpos
        if prefix_len is not None:
            causal_m = causal_m | (kpos < prefix_len)
        mask &= causal_m
    if window > 0:
        mask &= kpos > qpos - window
    return mask


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0, q_offset: int = 0,
                  prefix_len: Optional[jax.Array] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """Naive attention oracle.

    q: (B, S, Hq, D); k, v: (B, T, Hkv, D) with Hq % Hkv == 0 (GQA).
    Returns (B, S, Hq, D) in q.dtype; math in f32.
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=2)
    s = scale if scale is not None else 1.0 / np.sqrt(D)
    logits = jnp.einsum("bshd,bthd->bhst", qf, kf) * s
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    mask = attention_mask(S, T, q_offset=q_offset, causal=causal,
                          window=window, prefix_len=prefix_len)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality)
# ---------------------------------------------------------------------------
def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, D: Optional[jax.Array] = None,
            h0: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Sequential SSD recurrence oracle.

    x:  (batch, S, H, P)     per-head inputs
    dt: (batch, S, H)        positive step sizes (already softplus'ed)
    A:  (H,)                 negative decay rates
    B:  (batch, S, G, N)     input projections (G groups, H % G == 0)
    C:  (batch, S, G, N)     output projections
    D:  (H,) skip            optional
    h0: (batch, H, P, N)     initial state, optional
    Returns (y: (batch,S,H,P), h_final: (batch,H,P,N)); math in f32.

      h_t = exp(A dt_t) h_{t-1} + dt_t * x_t B_t^T
      y_t = h_t C_t + D x_t
    """
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)  # (Bb,S,H,N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    Af = A.astype(jnp.float32)
    h = jnp.zeros((Bb, H, P, N), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp          # (Bb,H,P),(Bb,H),(Bb,H,N),(Bb,H,N)
        decay = jnp.exp(Af[None] * dt_t)   # (Bb,H)
        dBx = jnp.einsum("bhp,bhn->bhpn", x_t * dt_t[..., None], B_t)
        h = h * decay[..., None, None] + dBx
        y_t = jnp.einsum("bhpn,bhn->bhp", h, C_t)
        return h, y_t

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    h, ys = jax.lax.scan(step, h, xs)
    y = jnp.moveaxis(ys, 0, 1)
    if D is not None:
        y = y + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / recurrentgemma)
# ---------------------------------------------------------------------------
RGLRU_C = 8.0


def rglru_ref(x: jax.Array, r_gate: jax.Array, i_gate: jax.Array,
              log_lambda: jax.Array, h0: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """RG-LRU oracle (sequential).

    x, r_gate, i_gate: (B, S, W)   — gates pre-sigmoid
    log_lambda: (W,)               — Λ parameter; log a = -c·softplus(Λ)·r
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
    Returns (h: (B,S,W) hidden sequence, h_final: (B,W)); math in f32.
    """
    Bb, S, W = x.shape
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(r_gate.astype(jnp.float32))
    i = jax.nn.sigmoid(i_gate.astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(log_lambda.astype(jnp.float32))[None, None] * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed in log space for stability
    a2 = jnp.exp(2.0 * log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12))
    gated = i * xf * beta
    h = jnp.zeros((Bb, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        a_t, g_t = inp
        h = a_t * h + g_t
        return h, h

    h, hs = jax.lax.scan(step, h, (jnp.moveaxis(a, 1, 0),
                                   jnp.moveaxis(gated, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), h


# ---------------------------------------------------------------------------
# MoE router
# ---------------------------------------------------------------------------
def router_topk_ref(logits: jax.Array, k: int, *,
                    renormalize: bool = True
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k softmax gating oracle.

    logits: (T, E). Returns (weights (T,k) f32, idx (T,k) i32,
    full_probs (T,E) f32 — for aux losses)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    if renormalize:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx.astype(jnp.int32), probs


# ---------------------------------------------------------------------------
# Fletcher-64 checksum (bulk/checkpoint integrity — the RPC layer's hot loop)
# ---------------------------------------------------------------------------
FLETCHER_MOD = (1 << 32) - 1


def fletcher64_ref(words: np.ndarray) -> int:
    """Fletcher-64 over uint32 words (numpy oracle, exact integer math)."""
    s1, s2 = 0, 0
    for w in np.asarray(words, dtype=np.uint64):
        s1 = (s1 + int(w)) % FLETCHER_MOD
        s2 = (s2 + s1) % FLETCHER_MOD
    return (s2 << 32) | s1
