"""RG-LRU Pallas TPU kernel: blocked linear recurrence.

Grid (B, n_channel_blocks, n_time_chunks); time chunks are the innermost
(sequential) dim, the hidden state (1, Wb) persists in VMEM scratch.
Gates/decays for a whole (Tc, Wb) tile are computed vectorized; the
recurrence itself is a short ``fori_loop`` of vector ops over the 128-lane
channel block — channel-parallel, which is exactly why the per-channel
gate simplification (see models/rglru_block.py) was chosen.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

RGLRU_C = 8.0


def _kernel(x_ref, r_ref, i_ref, ll_ref, h0_ref, o_ref, hf_ref, h_ref, *,
            nt, tc, use_h0, s_real):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        if use_h0:
            h_ref[...] = h0_ref[...].astype(jnp.float32)
        else:
            h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)                  # (Tc, Wb)
    r = jax.nn.sigmoid(r_ref[0].astype(jnp.float32))
    i = jax.nn.sigmoid(i_ref[0].astype(jnp.float32))
    ll = ll_ref[0].astype(jnp.float32)                # (1, Wb)
    log_a = -RGLRU_C * jax.nn.softplus(ll) * r        # (Tc, Wb)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = i * x * beta
    # time-padding must be an identity step (a=1, b=0) or it decays the
    # carried state
    tpos = it * tc + jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    pad_row = tpos >= s_real
    a = jnp.where(pad_row, 1.0, a)
    b = jnp.where(pad_row, 0.0, b)

    def step(t, h):
        a_t = jax.lax.dynamic_slice_in_dim(a, t, 1, 0)
        b_t = jax.lax.dynamic_slice_in_dim(b, t, 1, 0)
        h = a_t * h + b_t
        pl.store(o_ref, (pl.ds(0, 1), pl.ds(t, 1), slice(None)),
                 h.astype(o_ref.dtype)[None])
        return h

    h = jax.lax.fori_loop(0, tc, step, h_ref[...])
    h_ref[...] = h

    @pl.when(it == nt - 1)
    def _fin():
        hf_ref[...] = h_ref[...].astype(hf_ref.dtype)


def rglru_pallas(x, r_gate, i_gate, log_lambda, h0=None, *,
                 interpret: bool = False, block_w: int = 128,
                 block_t: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Shapes as in :func:`repro.kernels.ref.rglru_ref`."""
    Bb, S, W = x.shape
    wb = min(block_w, W)
    tc = min(block_t, S)
    pad_w = (-W) % wb
    pad_t = (-S) % tc
    if pad_w or pad_t:
        pads = ((0, 0), (0, pad_t), (0, pad_w))
        x = jnp.pad(x, pads)
        r_gate = jnp.pad(r_gate, pads)
        i_gate = jnp.pad(i_gate, pads)
    if pad_w:
        log_lambda = jnp.pad(log_lambda, ((0, pad_w),))
    Wp, Sp = W + pad_w, S + pad_t
    nw, nt = Wp // wb, Sp // tc
    use_h0 = h0 is not None
    h0_in = h0 if use_h0 else jnp.zeros((Bb, W), jnp.float32)
    if pad_w:
        h0_in = jnp.pad(h0_in, ((0, 0), (0, pad_w)))
    ll2 = log_lambda[None, :]                          # (1, Wp)

    kernel = functools.partial(_kernel, nt=nt, tc=tc, use_h0=use_h0,
                               s_real=S)
    hs, hf = pl.pallas_call(
        kernel,
        grid=(Bb, nw, nt),
        in_specs=[
            pl.BlockSpec((1, tc, wb), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, tc, wb), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, tc, wb), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, wb), lambda b, w, t: (0, w)),
            pl.BlockSpec((1, wb), lambda b, w, t: (b, w)),
        ],
        out_specs=[
            pl.BlockSpec((1, tc, wb), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, wb), lambda b, w, t: (b, w)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, Sp, Wp), x.dtype),
            jax.ShapeDtypeStruct((Bb, Wp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, wb), jnp.float32)],
        interpret=interpret,
    )(x, r_gate, i_gate, ll2, h0_in)
    return hs[:, :S, :W], hf[:, :W]
