"""Flash attention Pallas TPU kernel.

Grid (B, Hq, nq, nk); the kv index is the innermost (sequential on TPU)
dimension, so the online-softmax running state (m, l, acc) lives in VMEM
scratch and persists across kv steps — the canonical TPU flash pattern.
Out-of-band blocks (causal future / outside the sliding window) skip the
MXU work entirely with ``pl.when``.

Supports GQA (kv head = q head // rep via the k/v index maps), causal,
sliding window, tanh logit soft-capping, and prefix-LM bidirectional
prefixes (scalar prefix length in SMEM).

Block sizes default to 128 (MXU-aligned); f32 accumulators.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(prefix_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal, window, softcap, scale, nk, block_q, block_k,
            t_real, use_prefix):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # block-level skip tests (python statics fold `causal`/`window`)
    live = k_start < t_real
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + block_q - 1)
        if use_prefix:
            # prefix blocks are always live for every query row
            live = jnp.logical_or(live, k_start < prefix_ref[0])
    if window > 0 and not use_prefix:
        live = jnp.logical_and(
            live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < t_real
        if causal:
            cm = kpos <= qpos
            if use_prefix:
                cm = jnp.logical_or(cm, kpos < prefix_ref[0])
            mask = jnp.logical_and(mask, cm)
        if window > 0:
            wm = kpos > qpos - window
            if use_prefix:
                wm = jnp.logical_or(wm, kpos < prefix_ref[0])
            mask = jnp.logical_and(mask, wm)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _out():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, q_offset: int = 0,
                    prefix_len=None, interpret: bool = False,
                    block_q: int = 128, block_k: int = 128):
    """q: (B,S,Hq,D); k,v: (B,T,Hkv,D) → (B,S,Hq,D).

    ``q_offset`` must be 0 for the kernel path (decode uses the xla path).
    """
    if q_offset != 0:
        raise NotImplementedError("kernel path expects q_offset == 0")
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    bq = min(block_q, max(S, 8))
    bk = min(block_k, max(T, 8))

    qt = jnp.moveaxis(q, 2, 1)                      # (B,Hq,S,D)
    kt = jnp.moveaxis(k, 2, 1)                      # (B,Hkv,T,D)
    vt = jnp.moveaxis(v, 2, 1)
    pad_q = (-S) % bq
    pad_k = (-T) % bk
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sp, Tp = S + pad_q, T + pad_k
    nq, nk = Sp // bq, Tp // bk

    use_prefix = prefix_len is not None
    prefix_arr = jnp.asarray(
        [prefix_len if use_prefix else 0], jnp.int32)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, softcap=softcap,
        scale=1.0 / np.sqrt(D), nk=nk, block_q=bq, block_k=bk,
        t_real=T, use_prefix=use_prefix)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, _rep=rep: (b, h // _rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, _rep=rep: (b, h // _rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(prefix_arr, qt, kt, vt)
    out = out[:, :, :S]
    return jnp.moveaxis(out, 1, 2)
