"""Fused MoE router Pallas kernel: softmax + iterative top-k + renorm.

Grid over token blocks; the whole expert dimension (E ≤ a few hundred)
sits in VMEM lanes.  Top-k is k rounds of (max, argmax-by-iota, mask) —
k is small (≤ 8) so this is k vector passes, no sort.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(logits_ref, w_ref, idx_ref, probs_ref, *, k, renormalize):
    logits = logits_ref[...].astype(jnp.float32)        # (Tb, E)
    m = jnp.max(logits, axis=1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=1, keepdims=True)
    probs_ref[...] = probs

    Tb, E = probs.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (Tb, E), 1)
    work = probs
    wsum = jnp.zeros((Tb, 1), jnp.float32)
    for j in range(k):
        mj = jnp.max(work, axis=1, keepdims=True)       # (Tb,1)
        hit = work == mj
        ij = jnp.min(jnp.where(hit, iota, E), axis=1, keepdims=True)
        w_ref[:, j] = mj[:, 0]
        idx_ref[:, j] = ij[:, 0].astype(jnp.int32)
        wsum = wsum + mj
        work = jnp.where(iota == ij, NEG_INF, work)
    if renormalize:
        w_ref[...] = w_ref[...] / jnp.maximum(wsum, 1e-9)


def router_topk_pallas(logits, k: int, *, renormalize: bool = True,
                       interpret: bool = False, block_t: int = 256
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    T, E = logits.shape
    tb = min(block_t, T)
    pad = (-T) % tb
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
    Tp = T + pad
    nt = Tp // tb

    kernel = functools.partial(_kernel, k=k, renormalize=renormalize)
    w, idx, probs = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((tb, E), lambda t: (t, 0))],
        out_specs=[
            pl.BlockSpec((tb, k), lambda t: (t, 0)),
            pl.BlockSpec((tb, k), lambda t: (t, 0)),
            pl.BlockSpec((tb, E), lambda t: (t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp, k), jnp.float32),
            jax.ShapeDtypeStruct((Tp, k), jnp.int32),
            jax.ShapeDtypeStruct((Tp, E), jnp.float32),
        ],
        interpret=interpret,
    )(logits)
    return w[:T], idx[:T], probs[:T]
