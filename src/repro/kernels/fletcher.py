"""Fletcher-64 checksum Pallas kernel — the RPC layer's own hot loop
(bulk-transfer / checkpoint-shard integrity).

Math: Fletcher-64 over uint32 words, both running sums mod M = 2³²−1.
The kernel exploits 2³² ≡ 1 (mod M): a 64-bit quantity hi·2³²+lo reduces
to hi+lo, so every product/sum can be kept in uint32 with end-around-
carry adds — no 64-bit integers needed, which is exactly the adaptation
a TPU (32-bit VPU lanes) requires.

Block combine: a block of length L with partial sums (s1_b, s2_b)
composes as  s2 = s2_a + s2_b + s1_a·L ;  s1 = s1_a + s1_b  (mod M).
Within a block, s2_b = Σ (L−i)·w_i via per-lane mulmod with small
coefficients, then a lane-sum that splits each word into 16-bit halves
(so a 256-element sum cannot overflow 32 bits).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MOD = (1 << 32) - 1
GROUP = 256          # words per inner group (coef ≤ 256 ⇒ products fit)


def _addmod(a, b):
    """(a + b) mod (2³²−1) with end-around carry, uint32 in/out."""
    s = a + b
    carry = (s < a).astype(jnp.uint32)      # wrapped past 2³²  (≡ +1 mod M)
    s = s + carry
    # the +1 itself cannot re-wrap unless s was 2³²−1; fold once more
    carry2 = (s < carry).astype(jnp.uint32)
    return s + carry2


def _mulmod_small(c, w):
    """(c·w) mod (2³²−1) for c ≤ 2¹⁶. Split w = wh·2¹⁶ + wl;
    c·wh·2¹⁶ mod M = ((c·wh) >> 16) + ((c·wh & 0xFFFF) << 16)."""
    c = c.astype(jnp.uint32)
    w = w.astype(jnp.uint32)
    wh = w >> 16
    wl = w & jnp.uint32(0xFFFF)
    cwh = c * wh                               # ≤ 2³²−2¹⁶, fits
    cwl = c * wl
    part = _addmod(cwh >> 16, (cwh & jnp.uint32(0xFFFF)) << 16)
    return _addmod(part, cwl)


def _summod(v):
    """Sum a (…, GROUP) uint32 vector mod M without overflow: sum 16-bit
    halves in uint32 (≤ 2²⁴ each), recombine with the 2³²≡1 trick."""
    hi = jnp.sum(v >> 16, dtype=jnp.uint32)                # ≤ GROUP·2¹⁶
    lo = jnp.sum(v & jnp.uint32(0xFFFF), dtype=jnp.uint32)
    hi_fold = _addmod(hi >> 16, (hi & jnp.uint32(0xFFFF)) << 16)
    return _addmod(hi_fold, lo)


def _kernel(x_ref, out_ref, acc_ref, *, tile, nt):
    it = pl.program_id(0)

    @pl.when(it == 0)
    def _init():
        acc_ref[0] = jnp.uint32(0)   # s1
        acc_ref[1] = jnp.uint32(0)   # s2

    w = x_ref[...].reshape(tile // GROUP, GROUP)
    # per-group partial sums
    coef = (GROUP - jax.lax.broadcasted_iota(jnp.int32, w.shape, 1)) \
        .astype(jnp.uint32)                               # L..1 per group
    s1_g = jnp.stack([_summod(w[g]) for g in range(tile // GROUP)])
    s2_g = jnp.stack([_summod(_mulmod_small(coef[g], w[g]))
                      for g in range(tile // GROUP)])
    # fold groups left→right: s2 = s2 ∘ group (group length = GROUP)
    s1 = jnp.uint32(0)
    s2 = jnp.uint32(0)
    for g in range(tile // GROUP):
        s2 = _addmod(_addmod(s2, s2_g[g]),
                     _mulmod_small(jnp.uint32(GROUP), s1))
        s1 = _addmod(s1, s1_g[g])
    # fold into running accumulator (previous length = it·tile; but the
    # combine only needs the *current block's* length for the s1 term)
    acc_s1, acc_s2 = acc_ref[0], acc_ref[1]
    acc_ref[1] = _addmod(_addmod(acc_s2, s2),
                         _mulmod_small(jnp.uint32(tile % 65536), acc_s1)
                         if tile <= 65535 else
                         _mulmod_small(jnp.uint32(65535),
                                       _mulmod_small(
                                           jnp.uint32(tile // 65535), acc_s1)))
    acc_ref[0] = _addmod(acc_s1, s1)

    @pl.when(it == nt - 1)
    def _fin():
        out_ref[0] = acc_ref[0]
        out_ref[1] = acc_ref[1]


def fletcher64_pallas(words, *, interpret: bool = False,
                      tile: int = 2048) -> int:
    """words: uint32/uint64 numpy array → int checksum (s2 << 32 | s1)."""
    w = jnp.asarray(np.asarray(words, dtype=np.uint64).astype(np.uint32))
    n = w.size
    pad = (-n) % tile
    if pad:
        w = jnp.pad(w, ((0, pad),))    # zero words: s1 unchanged, s2 gains
        # trailing zeros only shift s2 by s1·pad — correct that after.
    nt = max(w.size // tile, 1)

    kernel = functools.partial(_kernel, tile=tile, nt=nt)
    out = pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((tile,), lambda t: (t,))],
        out_specs=pl.BlockSpec(memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.uint32),
        scratch_shapes=[pltpu.SMEM((2,), jnp.uint32)],
        interpret=interpret,
    )(w)
    s1 = int(out[0])
    s2 = int(out[1])
    if pad:
        # remove the contribution of `pad` trailing zero words to s2
        s2 = (s2 - (s1 * pad) % MOD) % MOD
    # map the 0 ≡ M ambiguity of end-around-carry arithmetic
    s1 %= MOD
    s2 %= MOD
    return (s2 << 32) | s1
