"""Mamba2 SSD Pallas TPU kernel (chunked state-space duality).

Grid (B, H, n_chunks); chunks are the innermost (sequential) dimension,
so the running inter-chunk state (P, N) lives in VMEM scratch.  Each
chunk does the quadratic intra-chunk part on the MXU ((Q,N)·(N,Q),
(Q,Q)·(Q,P)) plus the O(Q·P·N) state update — exactly the SSD
decomposition, with chunk length Q sized so the working set
(Q² scores + state) fits VMEM.

Padding trick: the sequence is padded with dt = 0 ⇒ decay 1, input
contribution 0, so padded tail rows never perturb the state.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(A_ref, D_ref, x_ref, dt_ref, B_ref, C_ref, h0_ref,
            y_ref, hf_ref, h_ref, *, nc, use_D, use_h0):
    h = pl.program_id(1)
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        if use_h0:
            h_ref[...] = h0_ref[0, 0].astype(jnp.float32)
        else:
            h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)          # (Q,)
    Bm = B_ref[0, :, 0].astype(jnp.float32)           # (Q, N)
    Cm = C_ref[0, :, 0].astype(jnp.float32)           # (Q, N)
    A = A_ref[h]

    da = dt * A                                       # (Q,)
    cum = jnp.cumsum(da)                              # inclusive
    total = cum[-1]

    # intra-chunk quadratic part
    Q = x.shape[0]
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.exp(jnp.where(ii >= jj, diff, -1e30))
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = cb * decay * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += exp(cum) * C @ h^T   (h: (P,N))
    hs = h_ref[...]
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, hs, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    if use_D:
        y = y + D_ref[h] * x
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    # state update: h = exp(total) h + sum_j exp(total - cum_j) dt_j x_j ⊗ B_j
    w = jnp.exp(total - cum) * dt                     # (Q,)
    contrib = jax.lax.dot_general(x * w[:, None], Bm,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    h_ref[...] = hs * jnp.exp(total) + contrib

    @pl.when(ic == nc - 1)
    def _fin():
        hf_ref[0, 0] = h_ref[...]


def ssd_pallas(x, dt, A, B, C, D=None, h0=None, *, chunk: int = 256,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Shapes as in :func:`repro.kernels.ref.ssd_ref`."""
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    use_D = D is not None
    use_h0 = h0 is not None
    D_in = D if use_D else jnp.zeros((H,), jnp.float32)
    h0_in = h0 if use_h0 else jnp.zeros((Bb, H, P, N), jnp.float32)

    kernel = functools.partial(_kernel, nc=nc, use_D=use_D, use_h0=use_h0)
    y, hf = pl.pallas_call(
        kernel,
        grid=(Bb, H, nc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # A (H,)
            pl.BlockSpec(memory_space=pltpu.SMEM),     # D (H,)
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, Q, 1, N),
                         lambda b, h, c, _r=rep: (b, c, h // _r, 0)),
            pl.BlockSpec((1, Q, 1, N),
                         lambda b, h, c, _r=rep: (b, c, h // _r, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, Sp, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(A, jnp.float32), jnp.asarray(D_in, jnp.float32),
      x, dt, B, C, h0_in)
    return y[:, :S], hf
