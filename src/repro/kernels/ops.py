"""Dispatching jit'd wrappers around the compute kernels.

Every op has up to four implementations, selected with ``impl=``:

  * ``"ref"``       — the naive oracle in :mod:`repro.kernels.ref`;
  * ``"xla"``       — a memory-efficient pure-jnp implementation (chunked
                      flash attention, blocked local attention, chunked
                      SSD, associative-scan RG-LRU).  This is the path the
                      dry-run compiles: its FLOP/byte structure is what the
                      roofline measures, and on CPU it is the fastest;
  * ``"pallas"``    — the Pallas TPU kernel (``pl.pallas_call``), compiled
                      for the MXU/VMEM (TARGET hardware);
  * ``"interpret"`` — the same Pallas kernel in interpret mode (CPU
                      correctness validation of the TPU kernel body).

``impl="auto"`` resolves to ``pallas`` on TPU backends and ``xla``
elsewhere.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as ref_mod
from .ref import NEG_INF, RGLRU_C, FLETCHER_MOD


def resolve_impl(impl: str) -> str:
    """"cost" = scan-free variants with identical FLOP structure, used by
    the dry-run cost compiles (XLA's cost_analysis counts a while-loop
    body once, so multi-trip scans would undercount)."""
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "xla"


# ===========================================================================
# attention
# ===========================================================================
def attention(q, k, v, *, causal: bool = True, window: int = 0,
              softcap: float = 0.0, q_offset: int = 0,
              prefix_len=None, impl: str = "auto",
              kv_chunk: int = 512, q_block: int = 512):
    """Multi-head GQA attention. q: (B,S,Hq,D); k,v: (B,T,Hkv,D).

    Shape-driven strategy for the xla path:
      * decode (S small, T large)          → masked full-logit matvec
      * sliding window with S == T large   → blocked local attention
      * otherwise                          → kv-chunked online-softmax
    """
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref_mod.attention_ref(q, k, v, causal=causal, window=window,
                                     softcap=softcap, q_offset=q_offset,
                                     prefix_len=prefix_len)
    if impl in ("pallas", "interpret"):
        from .flash_attention import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_offset=q_offset,
                               prefix_len=prefix_len,
                               interpret=(impl == "interpret"))
    # ---- xla / cost path ----
    B, S, Hq, D = q.shape
    T = k.shape[1]
    vector_offset = hasattr(q_offset, "ndim") and q_offset.ndim > 0
    if vector_offset or (S <= 16 and T > 64):
        return _attention_decode(q, k, v, causal=causal, window=window,
                                 softcap=softcap, q_offset=q_offset,
                                 prefix_len=prefix_len)
    if impl == "cost":
        # scan-free: naive einsum attention has the same matmul FLOPs as
        # the chunked/flash path (masking does not reduce einsum FLOPs)
        if causal and window > 0 and S == T and prefix_len is None \
                and S >= 2 * window and S % window == 0:
            return _attention_local_blocked(q, k, v, window=window,
                                            softcap=softcap)
        return ref_mod.attention_ref(q, k, v, causal=causal, window=window,
                                     softcap=softcap, q_offset=q_offset,
                                     prefix_len=prefix_len)
    if (causal and window > 0 and S == T and prefix_len is None
            and S >= 2 * window and S % window == 0):
        return _attention_local_blocked(q, k, v, window=window,
                                        softcap=softcap)
    # naive path only when the full logits tensor is demonstrably small
    if B * Hq * S * T * 4 <= (64 << 20):
        return ref_mod.attention_ref(q, k, v, causal=causal, window=window,
                                     softcap=softcap, q_offset=q_offset,
                                     prefix_len=prefix_len)
    return _attention_chunked(q, k, v, causal=causal, window=window,
                              softcap=softcap, q_offset=q_offset,
                              prefix_len=prefix_len, kv_chunk=kv_chunk)


def _softcap(logits, softcap):
    if softcap > 0.0:
        return jnp.tanh(logits / softcap) * softcap
    return logits


def _attention_decode(q, k, v, *, causal, window, softcap, q_offset,
                      prefix_len):
    """Small-S (decode) attention: full logits over T, masked softmax.
    Written as plain jnp reductions over T so that GSPMD shards T (the KV
    sequence) and emits the 2-pass (max, sum) all-reduces itself.

    ``q_offset`` may be a scalar (all sequences at the same position) or a
    (B,) vector (continuous batching: per-slot positions)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, rep, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bsgrd,btgd->bgrst", qf, kf) / np.sqrt(D)
    logits = _softcap(logits, softcap)
    qoff = jnp.asarray(q_offset)
    if qoff.ndim == 0:
        qpos = (jnp.arange(S) + qoff)[None, :]              # (1,S)
    else:
        qpos = qoff[:, None] + jnp.arange(S)[None, :]       # (B,S)
    kpos = jnp.arange(T)
    mask = jnp.ones(qpos.shape + (T,), bool)
    if causal:
        cm = kpos[None, None, :] <= qpos[..., None]
        if prefix_len is not None:
            cm = cm | (kpos[None, None, :] < prefix_len)
        mask = mask & cm
    if window > 0:
        mask = mask & (kpos[None, None, :] > qpos[..., None] - window)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", p, vf)
    return out.reshape(B, S, Hq, D).astype(q.dtype)


def _attention_local_blocked(q, k, v, *, window, softcap):
    """Exact sliding-window attention in O(S·2W): queries in blocks of W
    attend to their own and the previous key block."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    W = window
    nb = S // W
    qf = q.astype(jnp.float32).reshape(B, nb, W, Hq, D)
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2).reshape(B, nb, W, Hq, D)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=2).reshape(B, nb, W, Hq, D)
    k_prev = jnp.pad(kf[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    v_prev = jnp.pad(vf[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    k2 = jnp.concatenate([k_prev, kf], axis=2)   # (B,nb,2W,H,D)
    v2 = jnp.concatenate([v_prev, vf], axis=2)
    logits = jnp.einsum("bnqhd,bnkhd->bnhqk", qf, k2) / np.sqrt(D)
    logits = _softcap(logits, softcap)
    qpos = jnp.arange(W)[:, None] + W                 # position within 2W frame
    kpos = jnp.arange(2 * W)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - W)
    blk0 = kpos >= W                                   # block 0 has no prev block
    m = jnp.where(jnp.arange(nb)[:, None, None] == 0, mask[None] & blk0[None],
                  mask[None])
    logits = jnp.where(m[None, :, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", p, v2)
    return out.reshape(B, S, Hq, D).astype(q.dtype)


def _attention_chunked(q, k, v, *, causal, window, softcap, q_offset,
                       prefix_len, kv_chunk):
    """Online-softmax flash attention as a lax.scan over KV chunks —
    O(S·Ck) live memory, exact."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    Ck = min(kv_chunk, T)
    pad = (-T) % Ck
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    nc = Tp // Ck
    kf = jnp.moveaxis(k.astype(jnp.float32).reshape(B, nc, Ck, Hkv, D), 1, 0)
    vf = jnp.moveaxis(v.astype(jnp.float32).reshape(B, nc, Ck, Hkv, D), 1, 0)
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, rep, D)
    qpos = jnp.arange(S)[:, None] + q_offset

    def chunk(carry, inp):
        m_prev, l_prev, acc = carry
        kc, vc, c_idx = inp
        kpos = jnp.arange(Ck)[None, :] + c_idx * Ck
        logits = jnp.einsum("bsgrd,bkgd->bsgrk", qf, kc) / np.sqrt(D)
        logits = _softcap(logits, softcap)
        mask = kpos < T
        if causal:
            cm = kpos <= qpos
            if prefix_len is not None:
                cm = cm | (kpos < prefix_len)
            mask = mask & cm
        if window > 0:
            mask = mask & (kpos > qpos - window)
        logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum("bsgrk,bkgd->bsgrd", p, vc)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, S, Hkv, rep), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, rep), jnp.float32)
    acc0 = jnp.zeros((B, S, Hkv, rep, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(chunk, (m0, l0, acc0),
                                  (kf, vf, jnp.arange(nc)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, S, Hq, D).astype(q.dtype)


# ===========================================================================
# Mamba2 SSD
# ===========================================================================
def ssd(x, dt, A, B, C, D=None, h0=None, *, chunk: int = 256,
        impl: str = "auto") -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Shapes as in :func:`repro.kernels.ref.ssd_ref`."""
    impl = resolve_impl(impl)
    if impl == "cost":
        impl = "xla"   # _ssd_chunked is already scan-free in its hot path
    if impl == "ref":
        return ref_mod.ssd_ref(x, dt, A, B, C, D, h0)
    if impl in ("pallas", "interpret"):
        from .ssd import ssd_pallas
        return ssd_pallas(x, dt, A, B, C, D, h0, chunk=chunk,
                          interpret=(impl == "interpret"))
    return _ssd_chunked(x, dt, A, B, C, D, h0, chunk=chunk)


def _ssd_chunked(x, dt, A, B, C, D, h0, *, chunk):
    """Chunked SSD (the state-space-duality algorithm): quadratic within
    Q-length chunks, linear state recurrence across chunks."""
    Bb, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    xf = x.astype(jnp.float32).reshape(Bb, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bb, nc, Q, H)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2).reshape(Bb, nc, Q, H, N)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2).reshape(Bb, nc, Q, H, N)
    Af = A.astype(jnp.float32)

    da = dtf * Af[None, None, None, :]              # (Bb,nc,Q,H) log-decay steps
    cum = jnp.cumsum(da, axis=2)                    # inclusive within-chunk
    total = cum[:, :, -1:, :]                       # (Bb,nc,1,H)

    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i·B_j) x_j
    # mask the exponent BEFORE exp: for i<j it is large-positive and the
    # overflowed inf would poison the backward of the where (0·inf = NaN)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]            # (b,c,i,j,h)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    diff = jnp.where(tri[None, None, :, :, None], diff, -1e30)
    decay = jnp.exp(diff)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Cf, Bf)
    scores = cb * decay * dtf[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xf)

    # per-chunk end state: S_c = sum_j exp(total - cum_j) dt_j B_j ⊗ x_j
    w = jnp.exp(total - cum) * dtf                  # (Bb,nc,Q,H)
    chunk_state = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", w, Bf, xf)

    # inter-chunk recurrence over nc
    h_init = jnp.zeros((Bb, H, P, N), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)
    chunk_decay = jnp.exp(total[:, :, 0, :])        # (Bb,nc,H)

    def carry(h, inp):
        st, dec = inp
        h_out = h                                    # state *entering* the chunk
        h = h * dec[:, :, None, None] + st
        return h, h_out

    h_fin, h_prev = jax.lax.scan(
        carry, h_init, (jnp.moveaxis(chunk_state, 1, 0),
                        jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)              # (Bb,nc,H,P,N)

    # inter-chunk contribution: y_i += exp(cum_i) C_i · h_prev
    y_inter = jnp.einsum("bcih,bcihn,bchpn->bcihp", jnp.exp(cum), Cf, h_prev)

    y = (y_intra + y_inter).reshape(Bb, Sp, H, P)[:, :S]
    if D is not None:
        y = y + x.astype(jnp.float32)[:, :S] * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), h_fin


def ssd_decode_step(h, x_t, dt_t, A, B_t, C_t, D=None):
    """O(1) SSD decode: one token. h: (B,H,P,N); x_t: (B,H,P);
    dt_t: (B,H); B_t, C_t: (B,G,N). Returns (y_t, h_new)."""
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    hf = h.astype(jnp.float32)
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    Bf = jnp.repeat(B_t.astype(jnp.float32), rep, axis=1)
    Cf = jnp.repeat(C_t.astype(jnp.float32), rep, axis=1)
    decay = jnp.exp(A.astype(jnp.float32)[None] * dtf)
    h_new = hf * decay[..., None, None] + \
        jnp.einsum("bhp,bhn->bhpn", xf * dtf[..., None], Bf)
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Cf)
    if D is not None:
        y = y + xf * D.astype(jnp.float32)[None, :, None]
    return y.astype(x_t.dtype), h_new


# ===========================================================================
# RG-LRU
# ===========================================================================
def rglru(x, r_gate, i_gate, log_lambda, h0=None, *, impl: str = "auto"):
    impl = resolve_impl(impl)
    if impl == "cost":
        impl = "xla"   # associative_scan is an unrolled log-depth network
    if impl == "ref":
        return ref_mod.rglru_ref(x, r_gate, i_gate, log_lambda, h0)
    if impl in ("pallas", "interpret"):
        from .rglru_scan import rglru_pallas
        return rglru_pallas(x, r_gate, i_gate, log_lambda, h0,
                            interpret=(impl == "interpret"))
    return _rglru_assoc(x, r_gate, i_gate, log_lambda, h0)


def _rglru_assoc(x, r_gate, i_gate, log_lambda, h0):
    """RG-LRU via log(S)-depth associative scan (the XLA-friendly form)."""
    Bb, S, W = x.shape
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(r_gate.astype(jnp.float32))
    i = jax.nn.sigmoid(i_gate.astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(log_lambda.astype(jnp.float32))[None, None] * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = i * xf * beta
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_decode_step(h, x_t, r_gate_t, i_gate_t, log_lambda):
    """O(1) RG-LRU decode. h: (B,W); x_t/gates: (B,W)."""
    hf = h.astype(jnp.float32)
    r = jax.nn.sigmoid(r_gate_t.astype(jnp.float32))
    i = jax.nn.sigmoid(i_gate_t.astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(log_lambda.astype(jnp.float32))[None] * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h_new = a * hf + beta * (i * x_t.astype(jnp.float32))
    return h_new.astype(x_t.dtype), h_new


# ===========================================================================
# MoE router
# ===========================================================================
def router_topk(logits, k: int, *, impl: str = "auto"):
    impl = resolve_impl(impl)
    if impl == "cost":
        impl = "xla"
    if impl in ("pallas", "interpret"):
        from .moe_router import router_topk_pallas
        return router_topk_pallas(logits, k, interpret=(impl == "interpret"))
    return ref_mod.router_topk_ref(logits, k)


# ===========================================================================
# Fletcher-64
# ===========================================================================
def fletcher64(buf, *, impl: str = "auto", block: int = 1024) -> int:
    """Fletcher-64 checksum of a uint32 word array (numpy in, int out).

    Blockwise-combinable: for a block of length L with partial sums
    (s1_b, s2_b): s1 = s1_a + s1_b ; s2 = s2_a + s2_b + s1_a·L  (mod 2³²−1).
    """
    words = np.ascontiguousarray(buf).view(np.uint32).astype(np.uint64)
    impl = resolve_impl(impl)
    if impl in ("pallas", "interpret"):
        from .fletcher import fletcher64_pallas
        return fletcher64_pallas(words, interpret=(impl == "interpret"))
    if impl == "ref":
        return ref_mod.fletcher64_ref(words)
    # xla/numpy fast path: vectorized blockwise combine
    n = words.size
    s1 = np.uint64(0)
    s2 = np.uint64(0)
    M = np.uint64(FLETCHER_MOD)
    for off in range(0, n, block):
        w = words[off:off + block]
        L = np.uint64(w.size)
        b1 = np.uint64(int(w.sum()) % FLETCHER_MOD)
        coef = np.arange(w.size, 0, -1, dtype=np.uint64)
        b2 = np.uint64(int((coef * w % M).sum()) % FLETCHER_MOD)
        s2 = np.uint64((int(s2) + int(b2) + int(s1) * int(L)) % FLETCHER_MOD)
        s1 = np.uint64((int(s1) + int(b1)) % FLETCHER_MOD)
    return (int(s2) << 32) | int(s1)
