"""Model substrate: parameters with logical sharding axes, norms, RoPE,
MLPs, embeddings.

Parameters are plain pytrees whose leaves are :class:`P` — an array tagged
with a tuple of *logical axis names* (one per dim).  The distribution
layer (``distrib/sharding.py``) maps logical names to mesh axes, so model
code never mentions the mesh.  ``unzip(tree)`` splits a P-tree into
(arrays, axes) pytrees; ``jax.eval_shape`` over an ``init`` gives abstract
params for the dry-run without allocating.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig


@jax.tree_util.register_pytree_node_class
class P:
    """An array leaf tagged with logical axis names (len == ndim)."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes: Tuple[str, ...]):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"P{shape}{self.axes}"


def is_p(x) -> bool:
    return isinstance(x, P)


class Axes(tuple):
    """Logical-axis tuple. A *leaf* type (tuple subclass) so axes trees
    can be tree_map'd alongside value trees without ambiguity against
    tuple containers."""


def unzip(tree):
    """P-tree -> (value tree, axes tree)."""
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=is_p)
    axes = jax.tree_util.tree_map(lambda p: Axes(p.axes), tree, is_leaf=is_p)
    return values, axes


def zip_axes(values, axes):
    """(value tree, axes tree) -> P-tree."""
    return jax.tree_util.tree_map(P, values, axes)


def stack_p(trees):
    """Stack a list of same-structure P-trees along a new 'layers' axis."""
    def leaf(*ps):
        return P(jnp.stack([p.value for p in ps]), ("layers",) + ps[0].axes)
    return jax.tree_util.tree_map(leaf, *trees, is_leaf=is_p)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def _key(rng, *path) -> jax.Array:
    k = rng
    for p in path:
        k = jax.random.fold_in(k, abs(hash(p)) % (2 ** 31))
    return k


def dense_p(rng, path, shape, axes, dtype, in_dim: Optional[int] = None) -> P:
    """Truncated-normal fan-in init."""
    fan_in = in_dim if in_dim is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    v = jax.random.truncated_normal(_key(rng, *path), -2.0, 2.0, shape,
                                    jnp.float32) * std
    return P(v.astype(dtype), axes)


def zeros_p(shape, axes, dtype) -> P:
    return P(jnp.zeros(shape, dtype), axes)


def ones_p(shape, axes, dtype) -> P:
    return P(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x, weight, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)                        # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_params(cfg: ModelConfig, rng, path, d_ff: Optional[int] = None,
               dtype=None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype or jnp.dtype(cfg.param_dtype)
    p = {}
    if cfg.mlp in ("swiglu", "geglu"):
        p["wi_gate"] = dense_p(rng, path + ("wi_gate",), (d, f),
                               ("embed", "mlp"), dt)
        p["wi_up"] = dense_p(rng, path + ("wi_up",), (d, f),
                             ("embed", "mlp"), dt)
    else:
        p["wi"] = dense_p(rng, path + ("wi",), (d, f), ("embed", "mlp"), dt)
    p["wo"] = dense_p(rng, path + ("wo",), (f, d), ("mlp", "embed"), dt,
                      in_dim=f)
    return p


def mlp_apply(cfg: ModelConfig, p: dict, x):
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(xc @ p["wi_gate"].astype(cdt)) * (xc @ p["wi_up"].astype(cdt))
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(xc @ p["wi_gate"].astype(cdt), approximate=True) \
            * (xc @ p["wi_up"].astype(cdt))
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(xc @ p["wi"].astype(cdt)))
    else:  # gelu
        h = jax.nn.gelu(xc @ p["wi"].astype(cdt), approximate=True)
    return h @ p["wo"].astype(cdt)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------
def embed_params(cfg: ModelConfig, rng) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    p = {"embedding": dense_p(rng, ("embed_table",), (cfg.vocab, cfg.d_model),
                              ("vocab", "embed"), dt, in_dim=cfg.d_model)}
    if not cfg.tie_embeddings:
        p["head"] = dense_p(rng, ("head",), (cfg.d_model, cfg.vocab),
                            ("embed", "vocab"), dt)
    if cfg.frontend != "none" and cfg.frontend_dim:
        p["frontend_proj"] = dense_p(rng, ("frontend_proj",),
                                     (cfg.frontend_dim, cfg.d_model),
                                     ("frontend", "embed"), dt)
    return p


def embed_tokens(cfg: ModelConfig, p: dict, tokens):
    cdt = jnp.dtype(cfg.compute_dtype)
    h = jnp.take(p["embedding"], tokens, axis=0).astype(cdt)
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), cdt)
    return h


def unembed(cfg: ModelConfig, p: dict, h):
    cdt = jnp.dtype(cfg.compute_dtype)
    w = p["embedding"].T if cfg.tie_embeddings else p["head"]
    logits = h.astype(cdt) @ w.astype(cdt)
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap > 0.0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# ---------------------------------------------------------------------------
# cross-entropy (chunked over sequence; vocab stays sharded)
# ---------------------------------------------------------------------------
def chunked_ce_loss(cfg: ModelConfig, p: dict, h, targets, *,
                    chunk: int = 512, z_coef: float = 1e-4,
                    ignore_id: int = -1, logits_sharding=None):
    """Softmax CE + z-loss without materializing (B,S,V) at once.

    h: (B,S,d) final hidden states; targets: (B,S) int32.
    Scans over S in chunks; within a chunk the (B,c,V) logits are formed,
    reduced, and discarded. Vocab reductions are plain jnp so GSPMD keeps
    V sharded and emits the cross-shard reductions.
    """
    B, S, d = h.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)),
                          constant_values=ignore_id)
    Sp = S + pad
    nc = Sp // c
    hs = jnp.moveaxis(h.reshape(B, nc, c, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, nc, c), 1, 0)

    def body(acc, inp):
        hc, tc = inp
        logits = unembed(cfg, p, hc)                      # (B,c,V) f32
        if logits_sharding is not None:
            logits = jax.lax.with_sharding_constraint(logits,
                                                      logits_sharding)
        lse = jax.nn.logsumexp(logits, axis=-1)           # (B,c)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(tc, 0)[..., None], axis=-1)[..., 0]
        valid = (tc != ignore_id)
        nll = jnp.where(valid, lse - tgt, 0.0)
        zl = jnp.where(valid, jnp.square(lse), 0.0)
        loss_sum, z_sum, n = acc
        return (loss_sum + nll.sum(), z_sum + zl.sum(),
                n + valid.sum()), None

    (loss_sum, z_sum, n), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0), jnp.int32(0)), (hs, ts))
    n = jnp.maximum(n, 1)
    ce = loss_sum / n
    z = z_sum / n
    return ce + z_coef * z, {"ce": ce, "z_loss": z, "tokens": n}
