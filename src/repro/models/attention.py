"""GQA attention sub-layer: params, train/prefill apply, decode step.

Supports: GQA/MQA (kv repeat), RoPE (per-kind theta), sliding-window
("local" blocks), tanh logit soft-capping, qk RMS-norm, QKV biases,
prefix-LM bidirectional masks, and cross-attention (enc-dec).

KV caches are dicts ``{"k": (B,T,Hkv,D), "v": (B,T,Hkv,D)}``; decode
updates them with a dynamic slice at ``pos``.  When the cache sequence
dim is sharded (sequence-parallel decode), the softmax reductions in
``kernels.ops._attention_decode`` are plain jnp reductions over T, so
GSPMD emits the 2-pass (max/sum) cross-shard reduction instead of
gathering the cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .common import P, dense_p, ones_p, zeros_p, apply_rope, rms_norm


def attn_params(cfg: ModelConfig, rng, path, cross: bool = False) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    d, H, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {
        "wq": dense_p(rng, path + ("wq",), (d, H, D), ("embed", "heads", "head_dim"), dt),
        "wk": dense_p(rng, path + ("wk",), (d, Hkv, D), ("embed", "kv_heads", "head_dim"), dt),
        "wv": dense_p(rng, path + ("wv",), (d, Hkv, D), ("embed", "kv_heads", "head_dim"), dt),
        "wo": dense_p(rng, path + ("wo",), (H, D, d), ("heads", "head_dim", "embed"), dt,
                      in_dim=H * D),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_p((H, D), ("heads", "head_dim"), dt)
        p["bk"] = zeros_p((Hkv, D), ("kv_heads", "head_dim"), dt)
        p["bv"] = zeros_p((Hkv, D), ("kv_heads", "head_dim"), dt)
    if cfg.qk_norm:
        p["q_norm"] = ones_p((D,), ("head_dim",), dt)
        p["k_norm"] = ones_p((D,), ("head_dim",), dt)
    return p


def _theta(cfg: ModelConfig, kind: str) -> float:
    if kind == "global" and cfg.rope_theta_global > 0:
        return cfg.rope_theta_global
    return cfg.rope_theta


def _project_q(cfg, p, x, positions, kind, use_rope=True):
    cdt = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cdt), p["wq"].astype(cdt))
    if "bq" in p:
        q = q + p["bq"].astype(cdt)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, positions, _theta(cfg, kind))
    return q


def _project_kv(cfg, p, x, positions, kind, use_rope=True):
    cdt = jnp.dtype(cfg.compute_dtype)
    k = jnp.einsum("bsd,dhk->bshk", x.astype(cdt), p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", x.astype(cdt), p["wv"].astype(cdt))
    if "bk" in p:
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        k = apply_rope(k, positions, _theta(cfg, kind))
    return k, v


def _out(cfg, p, o):
    cdt = jnp.dtype(cfg.compute_dtype)
    return jnp.einsum("bshk,hkd->bsd", o.astype(cdt), p["wo"].astype(cdt))


def attn_apply(cfg: ModelConfig, p: dict, x, *, kind: str = "attn",
               causal: bool = True, prefix_len=None,
               impl: str = "auto") -> jax.Array:
    """Full-sequence self-attention (train / encoder)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q = _project_q(cfg, p, x, positions, kind)
    k, v = _project_kv(cfg, p, x, positions, kind)
    window = cfg.window if kind == "local" else 0
    o = ops.attention(q, k, v, causal=causal, window=window,
                      softcap=cfg.attn_softcap, prefix_len=prefix_len,
                      impl=impl)
    return _out(cfg, p, o)


def attn_prefill(cfg: ModelConfig, p: dict, x, *, kind: str = "attn",
                 cache_len: int, prefix_len=None,
                 impl: str = "auto") -> Tuple[jax.Array, dict]:
    """Self-attention over the prompt; returns (out, cache)."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q = _project_q(cfg, p, x, positions, kind)
    k, v = _project_kv(cfg, p, x, positions, kind)
    window = cfg.window if kind == "local" else 0
    o = ops.attention(q, k, v, causal=True, window=window,
                      softcap=cfg.attn_softcap, prefix_len=prefix_len,
                      impl=impl)
    pad = cache_len - S
    cache = {
        "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
    }
    return _out(cfg, p, o), cache


def attn_prefill_chunk(cfg: ModelConfig, p: dict, x, cache: dict, offset, *,
                       kind: str = "attn",
                       prefix_len=None) -> Tuple[jax.Array, dict]:
    """Prefill *continuation*: an S-token chunk at absolute positions
    ``offset .. offset+S`` attending causally against a full-length cache
    (earlier chunks / a resumed session's KV live below ``offset``; the
    chunk's own K/V are written at ``offset`` first).  This is the
    building block for micro-batched prefill and KV-session resume —
    ``attn_prefill`` with S == prompt length and ``offset == 0`` is the
    degenerate single-chunk case."""
    B, S, _ = x.shape
    off = jnp.asarray(offset, jnp.int32)
    positions = off + jnp.arange(S)[None, :]
    q = _project_q(cfg, p, x, positions, kind)
    k_new, v_new = _project_kv(cfg, p, x, positions, kind)
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, off, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, off, 0, 0))
    window = cfg.window if kind == "local" else 0
    o = ops.attention(q, k, v, causal=True, window=window,
                      softcap=cfg.attn_softcap, q_offset=off,
                      prefix_len=prefix_len, impl="xla")
    return _out(cfg, p, o), {"k": k, "v": v}


def attn_decode(cfg: ModelConfig, p: dict, x, cache: dict, pos, *,
                kind: str = "attn", prefix_len=None) -> Tuple[jax.Array, dict]:
    """One-token decode against the KV cache. x: (B,1,d); ``pos`` is a
    scalar (lockstep decode) or a (B,) vector (continuous batching)."""
    pos = jnp.asarray(pos)
    positions = (jnp.full((1, 1), 0) + pos) if pos.ndim == 0 \
        else pos[:, None]
    q = _project_q(cfg, p, x, positions, kind)
    k_new, v_new = _project_kv(cfg, p, x, positions, kind)
    if pos.ndim == 0:
        k = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
    else:
        b_idx = jnp.arange(x.shape[0])
        k = cache["k"].at[b_idx, pos].set(
            k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[b_idx, pos].set(
            v_new[:, 0].astype(cache["v"].dtype))
    window = cfg.window if kind == "local" else 0
    o = ops.attention(q, k, v, causal=True, window=window,
                      softcap=cfg.attn_softcap, q_offset=pos,
                      prefix_len=prefix_len, impl="xla")
    return _out(cfg, p, o), {"k": k, "v": v}


# ---------------------------------------------------------------------------
# cross-attention (enc-dec)
# ---------------------------------------------------------------------------
def cross_attn_apply(cfg: ModelConfig, p: dict, x, memory_kv: dict,
                     impl: str = "auto") -> jax.Array:
    """Decoder cross-attention: q from x, kv precomputed from encoder
    memory (no RoPE, bidirectional)."""
    B, S, _ = x.shape
    positions = jnp.zeros((1, S), jnp.int32)
    q = _project_q(cfg, p, x, positions, kind="attn", use_rope=False)
    o = ops.attention(q, memory_kv["k"], memory_kv["v"], causal=False,
                      softcap=cfg.attn_softcap, impl=impl)
    return _out(cfg, p, o)


def cross_kv(cfg: ModelConfig, p: dict, memory) -> dict:
    """Precompute cross-attention K/V from encoder output (prefill)."""
    B, F, _ = memory.shape
    positions = jnp.zeros((1, F), jnp.int32)
    k, v = _project_kv(cfg, p, memory, positions, kind="attn", use_rope=False)
    return {"k": k, "v": v}
