"""RG-LRU recurrent block (Griffin / recurrentgemma), TPU-adapted.

Structure: gate branch (linear + GeLU) ⊗ recurrent branch (linear →
causal conv → RG-LRU), merged and projected out.  The recurrence gates
(r, i) are per-channel affine functions of the conv output — a
documented simplification of Griffin's block-diagonal gate projections
that keeps the recurrence embarrassingly channel-parallel (the property
the Pallas kernel exploits).

Decode state: LRU hidden (B,W) f32 + conv tail (B,cw-1,W) — O(1) in
sequence length, which is why recurrentgemma runs long_500k.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .common import P, dense_p, ones_p, zeros_p
from .ssd_block import _causal_conv, _conv_step


def _width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def rglru_params(cfg: ModelConfig, rng, path) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    d, w = cfg.d_model, _width(cfg)
    cw = cfg.rglru.conv_width
    return {
        "w_gate": dense_p(rng, path + ("w_gate",), (d, w), ("embed", "lru"), dt),
        "w_x": dense_p(rng, path + ("w_x",), (d, w), ("embed", "lru"), dt),
        "conv_w": dense_p(rng, path + ("conv_w",), (cw, w), ("conv", "lru"),
                          dt, in_dim=cw),
        "conv_b": zeros_p((w,), ("lru",), dt),
        "a_gate_w": ones_p((w,), ("lru",), dt),
        "a_gate_b": zeros_p((w,), ("lru",), dt),
        "i_gate_w": ones_p((w,), ("lru",), dt),
        "i_gate_b": zeros_p((w,), ("lru",), dt),
        # Λ init so that a = exp(-c·softplus(Λ)·σ(r)) spans (0.9, 0.999)
        "log_lambda": P(jnp.linspace(-4.3, -1.5, w).astype(dt), ("lru",)),
        "w_out": dense_p(rng, path + ("w_out",), (w, d), ("lru", "embed"), dt),
    }


def _branches(cfg, p, x):
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    gate = jax.nn.gelu(xc @ p["w_gate"].astype(cdt), approximate=True)
    u = xc @ p["w_x"].astype(cdt)
    return gate, u


def _gates(p, u):
    uf = u.astype(jnp.float32)
    r_pre = uf * p["a_gate_w"].astype(jnp.float32) + p["a_gate_b"].astype(jnp.float32)
    i_pre = uf * p["i_gate_w"].astype(jnp.float32) + p["i_gate_b"].astype(jnp.float32)
    return r_pre, i_pre


def rglru_block_apply(cfg: ModelConfig, p: dict, x, *, impl: str = "auto",
                      want_cache: bool = False
                      ) -> Tuple[jax.Array, Optional[dict]]:
    """Train / prefill. x: (B,S,d)."""
    B, S, d = x.shape
    cw = cfg.rglru.conv_width
    gate, u = _branches(cfg, p, x)
    conv_in = u
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    r_pre, i_pre = _gates(p, u)
    h, h_fin = ops.rglru(u, r_pre, i_pre, p["log_lambda"], None, impl=impl)
    cdt = jnp.dtype(cfg.compute_dtype)
    out = (h.astype(cdt) * gate) @ p["w_out"].astype(cdt)
    cache = None
    if want_cache:
        cache = {"h": h_fin.astype(jnp.float32),
                 "conv": conv_in[:, S - (cw - 1):, :].astype(x.dtype)}
    return out, cache


def rglru_block_decode(cfg: ModelConfig, p: dict, x, cache: dict
                       ) -> Tuple[jax.Array, dict]:
    """One-token decode. x: (B,1,d)."""
    gate, u = _branches(cfg, p, x)
    conv_y, new_tail = _conv_step(u[:, 0], cache["conv"].astype(u.dtype),
                                  p["conv_w"], p["conv_b"])
    r_pre, i_pre = _gates(p, conv_y)
    _, h_new = ops.rglru_decode_step(cache["h"], conv_y, r_pre, i_pre,
                                     p["log_lambda"])
    cdt = jnp.dtype(cfg.compute_dtype)
    out = (h_new.astype(cdt)[:, None] * gate) @ p["w_out"].astype(cdt)
    return out, {"h": h_new.astype(jnp.float32),
                 "conv": new_tail.astype(cache["conv"].dtype)}


def rglru_cache_spec(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    w = _width(cfg)
    cw = cfg.rglru.conv_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, w), dtype)}
