from .common import P, unzip, zip_axes, stack_p
from .transformer import Model

__all__ = ["Model", "P", "unzip", "zip_axes", "stack_p"]
