"""Mixture-of-Experts layer with *direct expert-parallel dispatch*.

Expert parallelism maps experts onto the ``model`` mesh axis.  Because TP
activations are replicated across ``model`` at block boundaries, every
model shard already holds its row's tokens — so instead of the classic
all-to-all dispatch, each shard (a) computes the router for its row's
tokens (tiny, redundant across shards), (b) sort-dispatches only the
assignments that route to *its* local experts into an (E_local, C, d)
capacity buffer, (c) runs its expert FFNs, (d) scatter-combines partial
outputs, and (e) all-reduces over ``model`` — the same psum a dense TP
MLP needs anyway.  Net effect: MoE costs one (T_local, d) all-reduce, no
all-to-all, no token-size-dependent resharding.  (Recorded in DESIGN.md
as a TPU adaptation; the classic a2a dispatch is what the GPU literature
uses.)

Token dropping: per-expert capacity C = ceil(T_local·k/E · cf); dropped
assignments fall out of the scatter (mode="drop") and contribute zero,
exactly like Switch-style capacity dispatch.

The same code runs without a mesh (``spmd=None``) for CPU smoke tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from ..configs.base import ModelConfig
from ..kernels import ops
from .common import P, dense_p, mlp_apply, mlp_params


@dataclass(frozen=True)
class MoESpmd:
    """How the MoE layer sees the mesh. ``expert_axis=None`` = experts
    replicated per device (flat-DP layout): dispatch still runs inside
    shard_map per token shard (a global-jnp sort/scatter would make GSPMD
    materialize global dispatch buffers), weights are gathered by the
    shard_map in_specs."""
    mesh: object                      # jax.sharding.Mesh
    token_axes: Tuple[str, ...]       # axes sharding the token dim ("pod","data")
    expert_axis: Optional[str] = "model"

    @property
    def n_expert_shards(self) -> int:
        if self.expert_axis is None:
            return 1
        return self.mesh.shape[self.expert_axis]


def padded_experts(cfg: ModelConfig, n_shards: int) -> int:
    e = cfg.moe.num_experts
    return int(math.ceil(e / n_shards) * n_shards)


def moe_params(cfg: ModelConfig, rng, path, e_pad: Optional[int] = None) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    d, f = cfg.d_model, cfg.d_ff
    E = e_pad or cfg.moe.num_experts
    p = {
        # router replicated (every shard needs global top-k); padded slots
        # are masked to -inf in apply.
        "router": dense_p(rng, path + ("router",), (d, E),
                          ("embed", "experts_unsharded"), dt),
        "wi_gate": dense_p(rng, path + ("wi_gate",), (E, d, f),
                           ("experts", "embed", "mlp"), dt, in_dim=d),
        "wi_up": dense_p(rng, path + ("wi_up",), (E, d, f),
                         ("experts", "embed", "mlp"), dt, in_dim=d),
        "wo": dense_p(rng, path + ("wo",), (E, f, d),
                      ("experts", "mlp", "embed"), dt, in_dim=f),
    }
    if cfg.moe.num_shared_experts:
        p["shared"] = mlp_params(
            cfg, rng, path + ("shared",),
            d_ff=cfg.moe.num_shared_experts * cfg.d_ff)
    return p


def _expert_ffn(cfg: ModelConfig, p: dict, buf):
    """buf: (E_l, C, d) -> (E_l, C, d), swiglu experts."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = buf.astype(cdt)
    gate = jnp.einsum("ecd,edf->ecf", x, p["wi_gate"].astype(cdt))
    up = jnp.einsum("ecd,edf->ecf", x, p["wi_up"].astype(cdt))
    if cfg.mlp in ("swiglu",):
        h = jax.nn.silu(gate) * up
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(gate, approximate=True) * up
    else:
        h = jax.nn.silu(gate) * up
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(cdt))


def _moe_local(cfg: ModelConfig, params: dict, x2d, *, e_start, e_local,
               e_pad: int, capacity_factor: float, dropless: bool = False,
               router_impl: str = "auto"):
    """Dispatch + expert FFN for one shard. x2d: (T_l, d) local tokens;
    expert tensors hold [e_start, e_start+e_local). Returns partial y
    (contributions of local experts only) and local-sum aux stats."""
    T, d = x2d.shape
    E_real, k = cfg.moe.num_experts, cfg.moe.top_k
    cdt = jnp.dtype(cfg.compute_dtype)

    logits = x2d.astype(cdt) @ params["router"].astype(cdt)      # (T, E_pad)
    logits = logits.astype(jnp.float32)
    if e_pad > E_real:
        pad_mask = jnp.arange(e_pad) >= E_real
        logits = jnp.where(pad_mask[None], -1e30, logits)
    w, idx, probs = ops.router_topk(logits, k, impl=router_impl)  # (T,k)

    # aux stats (sums; caller normalizes / psums): load per expert,
    # mean prob per expert, router z
    assign_oh = jax.nn.one_hot(idx, e_pad, dtype=jnp.float32).sum(1)  # (T,E)
    load_sum = assign_oh.sum(0)                                   # (E,)
    prob_sum = probs.sum(0)                                       # (E,)
    z_sum = jnp.square(jax.nn.logsumexp(logits, axis=-1)).sum()

    if dropless:
        # every expert can hold every assignment it could receive (each
        # token contributes at most one assignment per expert) — used for
        # decode, where per-step dropping would make decode diverge from
        # prefill.
        C = T
    else:
        C = max(int(math.ceil(T * k / max(E_real, 1) * capacity_factor)), 1)

    flat_e = idx.reshape(-1)                                      # (T*k,)
    flat_w = w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    if cfg.moe.dispatch == "cumsum":
        # Switch-style rank computation: position-in-expert = number of
        # prior assignments to the same expert, via a cumsum over the
        # (T·k, E) one-hot — no sort. Same (t, j)-order capacity
        # semantics as the stable sort, ~10x fewer HLO bytes (see
        # EXPERIMENTS.md §Perf).
        ohf = (flat_e[:, None] == jnp.arange(e_pad)[None, :]) \
            .astype(jnp.float32)                               # (T*k, E)
        prior = jnp.cumsum(ohf, axis=0) - ohf
        pos_in_e = jnp.sum(prior * ohf, axis=1).astype(jnp.int32)
        se, st, sw = flat_e, flat_t, flat_w
    else:
        order = jnp.argsort(flat_e, stable=True)
        se, st, sw = flat_e[order], flat_t[order], flat_w[order]
        seg_start = jnp.searchsorted(se, jnp.arange(e_pad))
        pos_in_e = jnp.arange(T * k) - seg_start[se]
    local_e = se - e_start                                        # local expert id
    in_shard = (local_e >= 0) & (local_e < e_local)
    keep = (pos_in_e < C) & in_shard
    # out-of-shard / over-capacity rows scatter out of bounds -> dropped
    scat_e = jnp.where(keep, local_e, e_local)
    scat_c = jnp.where(keep, pos_in_e, C)

    buf = jnp.zeros((e_local, C, d), x2d.dtype)
    buf = buf.at[scat_e, scat_c].set(x2d[st], mode="drop")
    out_buf = _expert_ffn(cfg, params, buf)                       # (E_l,C,d)

    vals = out_buf.at[scat_e, scat_c].get(
        mode="fill", fill_value=0.0)                              # (T*k,d)
    vals = vals * jnp.where(keep, sw, 0.0)[:, None].astype(vals.dtype)
    y = jnp.zeros((T, d), vals.dtype).at[st].add(vals)
    return y, (load_sum, prob_sum, z_sum, jnp.float32(T))


def _aux_from_stats(cfg: ModelConfig, load_sum, prob_sum, z_sum, t_total):
    E_real = cfg.moe.num_experts
    k = cfg.moe.top_k
    frac_load = (load_sum / jnp.maximum(t_total * k, 1.0))[:E_real]
    frac_prob = (prob_sum / jnp.maximum(t_total, 1.0))[:E_real]
    lb = E_real * jnp.sum(frac_load * frac_prob)
    z = z_sum / jnp.maximum(t_total, 1.0)
    return {"moe_lb": lb * cfg.moe.aux_coef,
            "moe_z": z * cfg.moe.router_z_coef}


def moe_apply(cfg: ModelConfig, params: dict, x, *,
              spmd: Optional[MoESpmd] = None,
              capacity_factor: Optional[float] = None,
              dropless: bool = False,
              router_impl: str = "auto") -> Tuple[jax.Array, dict]:
    """MoE FFN over x: (B,S,d). Returns (y, aux_losses)."""
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    cf = capacity_factor if capacity_factor is not None \
        else cfg.moe.capacity_factor

    if spmd is None:
        e_pad = params["wi_gate"].shape[0]
        y, (ls, ps, zs, t) = _moe_local(
            cfg, params, x2d, e_start=0, e_local=e_pad, e_pad=e_pad,
            capacity_factor=cf, dropless=dropless, router_impl=router_impl)
        if "shared" in params:
            y = y + mlp_apply(cfg, params["shared"], x2d)
        aux = _aux_from_stats(cfg, ls, ps, zs, t)
        return y.reshape(B, S, d), aux

    from jax.experimental.shard_map import shard_map
    mesh = spmd.mesh
    tok = PS(spmd.token_axes)
    ex = spmd.expert_axis
    n_shards = spmd.n_expert_shards
    e_pad = params["wi_gate"].shape[0]
    e_local = e_pad // n_shards

    shared = params.get("shared")
    has_shared = shared is not None

    def fn(x_loc, router, wig, wiu, wo, *shared_w):
        my = (jax.lax.axis_index(ex) * e_local) if ex is not None else 0
        p_loc = {"router": router, "wi_gate": wig, "wi_up": wiu, "wo": wo}
        y, (ls, ps, zs, t) = _moe_local(
            cfg, p_loc, x_loc, e_start=my, e_local=e_local, e_pad=e_pad,
            capacity_factor=cf, dropless=dropless, router_impl=router_impl)
        if has_shared:
            sp = dict(zip(sorted(shared.keys()), shared_w))
            y = y + mlp_apply(cfg, sp, x_loc)      # mlp dim sharded -> partial
        if ex is not None:
            y = jax.lax.psum(y, ex)                # combine expert partials
        # Aux sums are identical on every expert shard (router is
        # replicated): psum over token shards only -> global sums.
        ls, ps, zs, t = (jax.lax.psum(v, spmd.token_axes)
                         for v in (ls, ps, zs, t))
        return y, ls, ps, zs, t

    shared_keys = sorted(shared.keys()) if has_shared else []
    shared_vals = [shared[k] for k in shared_keys]
    # shared-expert MLP is plain TP: wi_* shard the f dim, wo shards f too
    shared_specs = tuple(
        PS(None, ex) if k.startswith("wi") else PS(ex, None)
        for k in shared_keys)
    expert_spec = PS(ex, None, None)               # ex=None -> replicated

    y, ls, ps, zs, t = shard_map(
        fn, mesh=mesh,
        in_specs=(PS(spmd.token_axes, None),
                  PS(None, None),
                  expert_spec, expert_spec, expert_spec,
                  *shared_specs),
        out_specs=(PS(spmd.token_axes, None), PS(None), PS(None), PS(),
                   PS()),
        check_rep=False,
    )(x2d, params["router"], params["wi_gate"], params["wi_up"],
      params["wo"], *shared_vals)
    aux = _aux_from_stats(cfg, ls, ps, zs, t)
    return y.reshape(B, S, d), aux
