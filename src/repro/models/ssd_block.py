"""Mamba2 block (SSD — state-space duality), TPU-adapted.

Projections: x -> [z, xs, B, C, dt]; depthwise causal conv over
[xs, B, C]; SSD scan (chunked, :mod:`repro.kernels.ops.ssd`); gated
RMS-norm with z; output projection.

Decode carries two states per layer: the SSD state (B,H,P,N) and the
conv tail (B, cw-1, channels) — both O(1) in sequence length, which is
why mamba2 runs the long_500k cell.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .common import P, dense_p, ones_p, zeros_p, rms_norm


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.ngroups * s.state_dim
    return d_in, H, s.head_dim, s.ngroups, s.state_dim, s.conv_width, conv_ch


def ssd_params(cfg: ModelConfig, rng, path) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    d_in, H, Pd, G, N, cw, conv_ch = _dims(cfg)
    p = {
        "wz": dense_p(rng, path + ("wz",), (d, d_in), ("embed", "inner"), dt),
        "wx": dense_p(rng, path + ("wx",), (d, d_in), ("embed", "inner"), dt),
        "wB": dense_p(rng, path + ("wB",), (d, G * N), ("embed", "state_proj"), dt),
        "wC": dense_p(rng, path + ("wC",), (d, G * N), ("embed", "state_proj"), dt),
        "wdt": dense_p(rng, path + ("wdt",), (d, H), ("embed", "ssm_heads"), dt),
        "dt_bias": zeros_p((H,), ("ssm_heads",), dt),
        "A_log": P(jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt), ("ssm_heads",)),
        "D": ones_p((H,), ("ssm_heads",), dt),
        "conv_w": dense_p(rng, path + ("conv_w",), (cw, conv_ch),
                          ("conv", "conv_ch"), dt, in_dim=cw),
        "conv_b": zeros_p((conv_ch,), ("conv_ch",), dt),
        "norm": ones_p((d_in,), ("inner",), dt),
        "wo": dense_p(rng, path + ("wo",), (d_in, d), ("inner", "embed"), dt),
    }
    return p


def _causal_conv(u, w, b):
    """Depthwise causal conv. u: (B,S,C); w: (cw,C); b: (C,)."""
    cw = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    S = u.shape[1]
    out = b[None, None]
    for i in range(cw):
        out = out + pad[:, i:i + S] * w[i][None, None]
    return out


def _conv_step(u_t, tail, w, b):
    """One conv step. u_t: (B,C); tail: (B,cw-1,C). Returns (y_t, new_tail)."""
    window = jnp.concatenate([tail, u_t[:, None]], axis=1)   # (B,cw,C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return y, window[:, 1:]


def _split_conv_channels(cfg: ModelConfig, conv_out):
    d_in, H, Pd, G, N, cw, conv_ch = _dims(cfg)
    xs = conv_out[..., :d_in]
    Bm = conv_out[..., d_in:d_in + G * N]
    Cm = conv_out[..., d_in + G * N:]
    return xs, Bm, Cm


def _project(cfg: ModelConfig, p, x):
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    z = xc @ p["wz"].astype(cdt)
    u = jnp.concatenate([xc @ p["wx"].astype(cdt),
                         xc @ p["wB"].astype(cdt),
                         xc @ p["wC"].astype(cdt)], axis=-1)
    dt_raw = xc @ p["wdt"].astype(cdt)
    return z, u, dt_raw


def _finish(cfg, p, y_heads, z, shape):
    B, S = shape
    d_in = z.shape[-1]
    cdt = jnp.dtype(cfg.compute_dtype)
    y = y_heads.reshape(B, S, d_in)
    y = rms_norm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z.astype(jnp.float32)).astype(cdt)
    return y.astype(cdt) @ p["wo"].astype(cdt)


def ssd_block_apply(cfg: ModelConfig, p: dict, x, *, impl: str = "auto",
                    want_cache: bool = False
                    ) -> Tuple[jax.Array, Optional[dict]]:
    """Train / prefill. x: (B,S,d). Returns (out, cache or None)."""
    B, S, d = x.shape
    d_in, H, Pd, G, N, cw, conv_ch = _dims(cfg)
    z, u, dt_raw = _project(cfg, p, x)
    conv_out = jax.nn.silu(_causal_conv(u, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = _split_conv_channels(cfg, conv_out)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_fin = ops.ssd(xs.reshape(B, S, H, Pd), dt, A,
                       Bm.reshape(B, S, G, N), Cm.reshape(B, S, G, N),
                       p["D"], None, chunk=cfg.ssm.chunk, impl=impl)
    out = _finish(cfg, p, y, z, (B, S))
    cache = None
    if want_cache:
        cache = {"h": h_fin.astype(jnp.float32),
                 "conv": u[:, S - (cw - 1):, :].astype(x.dtype)}
    return out, cache


def ssd_block_decode(cfg: ModelConfig, p: dict, x, cache: dict
                     ) -> Tuple[jax.Array, dict]:
    """One-token decode. x: (B,1,d)."""
    B = x.shape[0]
    d_in, H, Pd, G, N, cw, conv_ch = _dims(cfg)
    z, u, dt_raw = _project(cfg, p, x)
    conv_y, new_tail = _conv_step(u[:, 0], cache["conv"].astype(u.dtype),
                                  p["conv_w"], p["conv_b"])
    conv_y = jax.nn.silu(conv_y)
    xs, Bm, Cm = _split_conv_channels(cfg, conv_y)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y_t, h_new = ops.ssd_decode_step(
        cache["h"], xs.reshape(B, H, Pd), dt, A,
        Bm.reshape(B, G, N), Cm.reshape(B, G, N), p["D"])
    out = _finish(cfg, p, y_t[:, None], z, (B, 1))
    return out, {"h": h_new.astype(jnp.float32),
                 "conv": new_tail.astype(cache["conv"].dtype)}


def ssd_cache_spec(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    d_in, H, Pd, G, N, cw, conv_ch = _dims(cfg)
    return {"h": jnp.zeros((batch, H, Pd, N), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, conv_ch), dtype)}
