"""Model assembly: blocks → period-scanned stacks → Model API.

Layers are stacked with ``lax.scan`` over *periods* (the repeating block
pattern, e.g. gemma3's 5×local+1×global) so compile time stays flat in
depth; heterogeneous trailing layers and special first layers (deepseek's
dense layer 0) are unrolled.

The Model API (all pure functions of (params, inputs)):
  * ``init(rng)``                          → P-tree (arrays + logical axes)
  * ``loss_fn(params, batch, ...)``        → (loss, metrics)      [train]
  * ``prefill(params, batch, ...)``        → (last_logits, cache) [serve]
  * ``decode_step(params, cache, tok, pos)``→ (logits, new cache) [serve]
  * ``cache_specs(batch, cache_len)``      → P-tree of zeroed caches
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ATTN_KINDS, ModelConfig
from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru_block, ssd_block
from .common import (P, dense_p, embed_params, embed_tokens, chunked_ce_loss,
                     ones_p, rms_norm, stack_p, unembed, unzip)

AUX_KEYS = ("moe_lb", "moe_z")


def _zero_aux():
    return {k: jnp.float32(0) for k in AUX_KEYS}


def _add_aux(a, b):
    return {k: a[k] + b.get(k, 0.0) for k in AUX_KEYS}


# ===========================================================================
# single block
# ===========================================================================
def block_params(cfg: ModelConfig, rng, kind: str, path, *,
                 dense_ff: Optional[int] = None, cross: bool = False,
                 e_pad: Optional[int] = None) -> dict:
    """Parameters for one block of the given kind."""
    from .common import mlp_params
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": ones_p((d,), ("embed",), dt)}
    if kind in ATTN_KINDS:
        p["attn"] = attn_mod.attn_params(cfg, rng, path + ("attn",))
    elif kind == "rglru":
        p["rec"] = rglru_block.rglru_params(cfg, rng, path + ("rec",))
    elif kind == "ssd":
        p["rec"] = ssd_block.ssd_params(cfg, rng, path + ("rec",))
    else:
        raise ValueError(kind)
    if cross:
        p["cross_norm"] = ones_p((d,), ("embed",), dt)
        p["cross"] = attn_mod.attn_params(cfg, rng, path + ("cross",))
    # feed-forward half (ssd blocks have none; d_ff == 0)
    if cfg.d_ff > 0 or dense_ff:
        if cfg.moe.num_experts and dense_ff is None:
            p["moe"] = moe_mod.moe_params(cfg, rng, path + ("moe",),
                                          e_pad=e_pad)
        else:
            p["mlp"] = mlp_params(cfg, rng, path + ("mlp",), d_ff=dense_ff)
        if not cfg.parallel_block:
            p["norm2"] = ones_p((d,), ("embed",), dt)
    return p


def _ffn(cfg, p, x, *, spmd, capacity_factor, impl, dropless=False):
    from .common import mlp_apply
    if "moe" in p:
        return moe_mod.moe_apply(cfg, p["moe"], x, spmd=spmd,
                                 capacity_factor=capacity_factor,
                                 dropless=dropless, router_impl=impl)
    return mlp_apply(cfg, p["mlp"], x), {}


def block_apply(cfg: ModelConfig, p: dict, x, kind: str, *,
                mode: str,     # "train" | "prefill" | "chunk" | "decode"
                cache: Optional[dict] = None,
                pos=None, cache_len: int = 0,
                prefix_len=None, spmd=None, impl: str = "auto",
                capacity_factor: Optional[float] = None,
                memory_kv: Optional[dict] = None,
                causal: bool = True,
                inner_sharding=None):
    """Apply one block. Returns (x, aux, new_cache).

    ``inner_sharding``: optional constraint on the post-norm activations —
    under sequence-parallel residuals this pins ONE gather point that both
    the attention and (parallel-block) MLP branches consume, instead of
    letting GSPMD reshard per consumer."""
    aux = {}
    new_cache = dict(cache) if cache is not None else None
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if inner_sharding is not None:
        h = jax.lax.with_sharding_constraint(h, inner_sharding)

    if kind in ATTN_KINDS:
        if mode == "train":
            mix = attn_mod.attn_apply(cfg, p["attn"], h, kind=kind,
                                      causal=causal, prefix_len=prefix_len,
                                      impl=impl)
        elif mode == "prefill":
            mix, kv = attn_mod.attn_prefill(cfg, p["attn"], h, kind=kind,
                                            cache_len=cache_len,
                                            prefix_len=prefix_len, impl=impl)
            new_cache = dict(new_cache or {}); new_cache.update(kv)
        elif mode == "chunk":
            kv = {"k": cache["k"], "v": cache["v"]}
            mix, kv = attn_mod.attn_prefill_chunk(cfg, p["attn"], h, kv,
                                                  pos, kind=kind,
                                                  prefix_len=prefix_len)
            new_cache.update(kv)
        else:
            kv = {"k": cache["k"], "v": cache["v"]}
            mix, kv = attn_mod.attn_decode(cfg, p["attn"], h, kv, pos,
                                           kind=kind, prefix_len=prefix_len)
            new_cache.update(kv)
    elif kind == "rglru":
        if mode == "chunk":
            raise ValueError("chunked prefill requires attention-family "
                             "blocks (rglru carries no resumable prefill "
                             "state)")
        if mode == "decode":
            mix, st = rglru_block.rglru_block_decode(cfg, p["rec"], h, cache)
            new_cache.update(st)
        else:
            mix, st = rglru_block.rglru_block_apply(
                cfg, p["rec"], h, impl=impl, want_cache=(mode == "prefill"))
            if mode == "prefill":
                new_cache = st
    elif kind == "ssd":
        if mode == "chunk":
            raise ValueError("chunked prefill requires attention-family "
                             "blocks (ssd carries no resumable prefill "
                             "state)")
        if mode == "decode":
            mix, st = ssd_block.ssd_block_decode(cfg, p["rec"], h, cache)
            new_cache.update(st)
        else:
            mix, st = ssd_block.ssd_block_apply(
                cfg, p["rec"], h, impl=impl, want_cache=(mode == "prefill"))
            if mode == "prefill":
                new_cache = st
    else:
        raise ValueError(kind)

    # serving is dropless unless an explicit capacity factor is given
    # (training always uses the configured capacity factor)
    dropless = mode != "train" and capacity_factor is None
    if cfg.parallel_block and ("mlp" in p or "moe" in p):
        y, aux = _ffn(cfg, p, h, spmd=spmd, capacity_factor=capacity_factor,
                      impl=impl, dropless=dropless)
        x = x + mix + y
    else:
        x = x + mix
        if "cross" in p and memory_kv is not None:
            hc = rms_norm(x, p["cross_norm"], cfg.norm_eps)
            x = x + attn_mod.cross_attn_apply(cfg, p["cross"], hc, memory_kv,
                                              impl=impl)
        if "mlp" in p or "moe" in p:
            h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
            y, aux = _ffn(cfg, p, h2, spmd=spmd,
                          capacity_factor=capacity_factor, impl=impl,
                          dropless=dropless)
            x = x + y
    return x, aux, new_cache


# ===========================================================================
# the Model
# ===========================================================================
class Model:
    """One architecture, parameterized by its ModelConfig."""

    def __init__(self, cfg: ModelConfig, e_pad: Optional[int] = None,
                 unroll: bool = False):
        self.cfg = cfg
        self.unroll = unroll
        self.e_pad = e_pad or (moe_mod.padded_experts(cfg, 1)
                               if cfg.moe.num_experts else None)
        # layout: [prefix (unrolled)] + n_scan periods + [trailing (unrolled)]
        self.prefix_count = 1 if (cfg.moe.first_layer_dense
                                  and cfg.moe.num_experts) else 0
        rest = cfg.n_layers - self.prefix_count
        if unroll:
            # cost-compile mode: every layer unrolled (exact FLOP counting)
            self.n_scan_periods = 0
            self.trailing_kinds = tuple(
                cfg.kind_at(self.prefix_count + i) for i in range(rest))
        else:
            self.n_scan_periods = rest // len(cfg.period)
            self.trailing_kinds = tuple(
                cfg.kind_at(self.prefix_count + self.n_scan_periods
                            * len(cfg.period) + i)
                for i in range(rest % len(cfg.period)))
        self.is_encdec = cfg.n_enc_layers > 0

    # ------------------------------------------------------------------ init
    def init(self, rng) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        params: Dict[str, Any] = {"embed": embed_params(cfg, rng)}
        cross = self.is_encdec

        if self.prefix_count:
            params["prefix"] = tuple(
                block_params(cfg, rng, cfg.kind_at(i), ("prefix", i),
                             dense_ff=cfg.moe.first_dense_ff or cfg.d_ff,
                             cross=cross, e_pad=self.e_pad)
                for i in range(self.prefix_count))

        plen = len(cfg.period)
        periods = []
        if self.n_scan_periods:
            for pos in range(plen):
                kind = cfg.period[pos]
                layers = [
                    block_params(cfg, rng, kind,
                                 ("scan", j * plen + pos), cross=cross,
                                 e_pad=self.e_pad)
                    for j in range(self.n_scan_periods)]
                periods.append(stack_p(layers))
        params["periods"] = tuple(periods)

        params["trailing"] = tuple(
            block_params(cfg, rng, kind, ("trailing", i), cross=cross,
                         e_pad=self.e_pad)
            for i, kind in enumerate(self.trailing_kinds))

        params["final_norm"] = ones_p((cfg.d_model,), ("embed",), dt)

        if self.is_encdec:
            enc_layers = [
                block_params(cfg, rng, "attn", ("enc", i), e_pad=None)
                for i in range(cfg.n_enc_layers)]
            params["encoder"] = {
                "stack": stack_p(enc_layers),
                "final_norm": ones_p((cfg.d_model,), ("embed",), dt),
            }
        return params

    # -------------------------------------------------------------- embedding
    def _embed_inputs(self, params, batch):
        """Token (+ modality-stub) embedding → (h, prefix_len)."""
        cfg = self.cfg
        emb = params["embed"]
        prefix_len = None
        if cfg.family == "vlm" and "frontend" in batch:
            cdt = jnp.dtype(cfg.compute_dtype)
            patches = batch["frontend"].astype(cdt) @ \
                emb["frontend_proj"].astype(cdt)           # (B,F,d)
            text = embed_tokens(cfg, emb, batch["tokens"])
            h = jnp.concatenate([patches, text], axis=1)
            prefix_len = jnp.int32(cfg.frontend_seq)
            if cfg.prefix_lm:
                pass                                        # mask uses prefix_len
            else:
                prefix_len = None
        else:
            h = embed_tokens(cfg, emb, batch["tokens"])
            if cfg.prefix_lm and "prefix_len" in batch:
                prefix_len = batch["prefix_len"]
        return h, prefix_len

    def _encode(self, params, batch, *, impl):
        """Encoder for enc-dec families: frontend frames → memory."""
        cfg = self.cfg
        cdt = jnp.dtype(cfg.compute_dtype)
        mem = batch["frontend"].astype(cdt) @ \
            params["embed"]["frontend_proj"].astype(cdt)

        def body(h, layer_p):
            h, _, _ = block_apply(cfg, layer_p, h, "attn", mode="train",
                                  causal=False, impl=impl)
            return h, None

        mem, _ = jax.lax.scan(body, mem, params["encoder"]["stack"])
        return rms_norm(mem, params["encoder"]["final_norm"], cfg.norm_eps)

    # ------------------------------------------------------------------ train
    def loss_fn(self, params, batch, *, spmd=None, impl: str = "auto",
                remat: str = "block", z_coef: float = 1e-4,
                act_sharding=None, logits_sharding=None,
                inner_sharding=None, ce_chunk: int = 512):
        """Teacher-forced LM loss. batch: tokens (B,S), targets (B,S),
        optional frontend. params: plain value tree (not P-tree).
        ``act_sharding``: optional sharding constraint applied to the
        residual stream at block boundaries (sequence-parallel layout for
        big-model memory)."""
        cfg = self.cfg

        def constrain(h):
            if act_sharding is not None:
                return jax.lax.with_sharding_constraint(h, act_sharding)
            return h

        h, prefix_len = self._embed_inputs(params, batch)
        h = constrain(h)
        memory_kv = None
        if self.is_encdec:
            memory = self._encode(params, batch, impl=impl)
            # cross K/V are shared across decoder layers' own projections —
            # each layer computes its own K/V from memory inside the block;
            # we pass the memory through a per-layer projection lazily.
            memory_kv = memory   # sentinel: projected per block below

        aux = _zero_aux()

        def apply_one(h, p, kind, aux):
            mkv = None
            if memory_kv is not None and "cross" in p:
                mkv = attn_mod.cross_kv(cfg, p["cross"], memory_kv)
            h, a, _ = block_apply(cfg, p, h, kind, mode="train",
                                  prefix_len=prefix_len, spmd=spmd,
                                  impl=impl, memory_kv=mkv,
                                  inner_sharding=inner_sharding)
            return constrain(h), _add_aux(aux, a)

        for p in params.get("prefix", ()):
            h, aux = apply_one(h, p, cfg.period[0] if cfg.period[0] not in
                               ("rglru", "ssd") else cfg.period[0], aux)

        plen = len(cfg.period)

        def period_body(carry, xs):
            h, aux = carry
            for pos in range(plen):
                h, aux = apply_one(h, xs[pos], cfg.period[pos], aux)
            return (h, aux), None

        body = period_body
        if remat == "block":
            body = jax.checkpoint(period_body, prevent_cse=False)
        if self.n_scan_periods:
            (h, aux), _ = jax.lax.scan(body, (h, aux), params["periods"])

        for p, kind in zip(params["trailing"], self.trailing_kinds):
            h, aux = apply_one(h, p, kind, aux)

        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        loss, metrics = chunked_ce_loss(cfg, params["embed"], h,
                                        batch["targets"], z_coef=z_coef,
                                        chunk=ce_chunk,
                                        logits_sharding=logits_sharding)
        for k in AUX_KEYS:
            loss = loss + aux[k]
            metrics[k] = aux[k]
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------------ serve
    def prefill(self, params, batch, *, cache_len: Optional[int] = None,
                spmd=None, impl: str = "auto",
                capacity_factor: Optional[float] = None,
                act_sharding=None):
        """Prompt pass. Returns (last_logits (B,V), cache pytree)."""
        cfg = self.cfg
        h, prefix_len = self._embed_inputs(params, batch)
        if act_sharding is not None:
            h = jax.lax.with_sharding_constraint(h, act_sharding)
        S = h.shape[1]
        cache_len = cache_len or S
        memory = self._encode(params, batch, impl=impl) if self.is_encdec \
            else None
        cache: Dict[str, Any] = {}

        def apply_one(h, p, kind):
            mkv = None
            if memory is not None and "cross" in p:
                mkv = attn_mod.cross_kv(cfg, p["cross"], memory)
            h, _, c = block_apply(cfg, p, h, kind, mode="prefill",
                                  cache_len=cache_len, prefix_len=prefix_len,
                                  spmd=spmd, impl=impl,
                                  capacity_factor=capacity_factor,
                                  memory_kv=mkv)
            if mkv is not None:
                c = dict(c or {}); c["cross_k"] = mkv["k"]; c["cross_v"] = mkv["v"]
            if act_sharding is not None:
                h = jax.lax.with_sharding_constraint(h, act_sharding)
            return h, c

        cache["prefix"] = []
        for p in params.get("prefix", ()):
            h, c = apply_one(h, p, cfg.period[0])
            cache["prefix"].append(c)
        cache["prefix"] = tuple(cache["prefix"])

        plen = len(cfg.period)

        def period_body(h, xs):
            cs = []
            for pos in range(plen):
                h, c = apply_one(h, xs[pos], cfg.period[pos])
                cs.append(c)
            return h, tuple(cs)

        if self.n_scan_periods:
            h, cache["periods"] = jax.lax.scan(period_body, h,
                                               params["periods"])
        else:
            cache["periods"] = ()

        cache["trailing"] = []
        for p, kind in zip(params["trailing"], self.trailing_kinds):
            h, c = apply_one(h, p, kind)
            cache["trailing"].append(c)
        cache["trailing"] = tuple(cache["trailing"])

        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = unembed(cfg, params["embed"], h[:, -1:])[:, 0]
        return logits, cache

    def decode_step(self, params, cache, tokens, pos, *, spmd=None,
                    impl: str = "auto"):
        """One token for every sequence. tokens: (B,1); pos: scalar int32.
        Returns (logits (B,V), new cache)."""
        cfg = self.cfg
        h = embed_tokens(cfg, params["embed"], tokens)

        def apply_one(h, p, kind, c):
            mkv = None
            if c is not None and "cross_k" in c:
                mkv = {"k": c["cross_k"], "v": c["cross_v"]}
            h, _, nc = block_apply(cfg, p, h, kind, mode="decode", cache=c,
                                   pos=pos, spmd=spmd, impl=impl,
                                   capacity_factor=None, memory_kv=mkv)
            return h, nc

        new_cache: Dict[str, Any] = {}
        new_cache["prefix"] = []
        for p, c in zip(params.get("prefix", ()), cache.get("prefix", ())):
            h, nc = apply_one(h, p, cfg.period[0], c)
            new_cache["prefix"].append(nc)
        new_cache["prefix"] = tuple(new_cache["prefix"])

        plen = len(cfg.period)

        def period_body(h, xs):
            layer_p, layer_c = xs
            ncs = []
            for posn in range(plen):
                h, nc = apply_one(h, layer_p[posn], cfg.period[posn],
                                  layer_c[posn])
                ncs.append(nc)
            return h, tuple(ncs)

        if self.n_scan_periods:
            h, new_cache["periods"] = jax.lax.scan(
                period_body, h, (params["periods"], cache["periods"]))
        else:
            new_cache["periods"] = ()

        new_cache["trailing"] = []
        for (p, kind), c in zip(zip(params["trailing"], self.trailing_kinds),
                                cache["trailing"]):
            h, nc = apply_one(h, p, kind, c)
            new_cache["trailing"].append(nc)
        new_cache["trailing"] = tuple(new_cache["trailing"])

        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        logits = unembed(cfg, params["embed"], h)[:, 0]
        return logits, new_cache

    # ------------------------------------------------------------ chunked
    @property
    def supports_chunked_prefill(self) -> bool:
        """True when the prompt can be prefilled in fixed-size chunks
        (and a session's KV resumed at an offset): every block must
        support continuation against an absolute-position cache.
        Attention caches do; the recurrent families (rglru/ssd) expose
        no carried-state prefill, and prefix-LM masks / enc-dec
        cross-attention are whole-prompt constructs."""
        kinds = set(self.cfg.period) | set(self.trailing_kinds)
        if self.prefix_count:
            kinds.add(self.cfg.kind_at(0))
        return (not self.is_encdec and not self.cfg.prefix_lm
                and all(k in ATTN_KINDS for k in kinds))

    def prefill_chunk(self, params, cache, tokens, offset, *, spmd=None,
                      impl: str = "auto"):
        """One fixed-size prefill chunk: ``tokens`` (B,C) land at
        absolute positions ``offset .. offset+C`` of an existing
        full-length cache (zeroed for a fresh prompt; a pinned session's
        KV for a resumed one).  Returns (logits (B,C,V), new cache) —
        the caller samples from the position of the last *real* prompt
        token once the final chunk lands.  Requires
        :attr:`supports_chunked_prefill`."""
        cfg = self.cfg
        h = embed_tokens(cfg, params["embed"], tokens)

        def apply_one(h, p, kind, c):
            h, _, nc = block_apply(cfg, p, h, kind, mode="chunk", cache=c,
                                   pos=offset, spmd=spmd, impl=impl,
                                   capacity_factor=None)
            return h, nc

        new_cache: Dict[str, Any] = {}
        new_cache["prefix"] = []
        for p, c in zip(params.get("prefix", ()), cache.get("prefix", ())):
            h, nc = apply_one(h, p, cfg.period[0], c)
            new_cache["prefix"].append(nc)
        new_cache["prefix"] = tuple(new_cache["prefix"])

        plen = len(cfg.period)

        def period_body(h, xs):
            layer_p, layer_c = xs
            ncs = []
            for posn in range(plen):
                h, nc = apply_one(h, layer_p[posn], cfg.period[posn],
                                  layer_c[posn])
                ncs.append(nc)
            return h, tuple(ncs)

        if self.n_scan_periods:
            h, new_cache["periods"] = jax.lax.scan(
                period_body, h, (params["periods"], cache["periods"]))
        else:
            new_cache["periods"] = ()

        new_cache["trailing"] = []
        for (p, kind), c in zip(zip(params["trailing"], self.trailing_kinds),
                                cache["trailing"]):
            h, nc = apply_one(h, p, kind, c)
            new_cache["trailing"].append(nc)
        new_cache["trailing"] = tuple(new_cache["trailing"])

        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return unembed(cfg, params["embed"], h), new_cache

    # ------------------------------------------------------------------ specs
    def cache_specs(self, batch_size: int, cache_len: int,
                    dtype=jnp.bfloat16) -> dict:
        """P-tree of zeroed decode caches (axes included for sharding)."""
        cfg = self.cfg

        def one(kind):
            if kind in ATTN_KINDS:
                c = {
                    "k": P(jnp.zeros((batch_size, cache_len, cfg.n_kv_heads,
                                      cfg.hd), dtype),
                           ("batch", "kv_seq", "kv_heads", "head_dim")),
                    "v": P(jnp.zeros((batch_size, cache_len, cfg.n_kv_heads,
                                      cfg.hd), dtype),
                           ("batch", "kv_seq", "kv_heads", "head_dim")),
                }
            elif kind == "rglru":
                s = rglru_block.rglru_cache_spec(cfg, batch_size, dtype)
                c = {"h": P(s["h"], ("batch", "lru")),
                     "conv": P(s["conv"], ("batch", "conv", "lru"))}
            elif kind == "ssd":
                s = ssd_block.ssd_cache_spec(cfg, batch_size, dtype)
                c = {"h": P(s["h"], ("batch", "ssm_heads", "head_dim", "state")),
                     "conv": P(s["conv"], ("batch", "conv", "conv_ch"))}
            else:
                raise ValueError(kind)
            if self.is_encdec:
                c["cross_k"] = P(jnp.zeros((batch_size, cfg.frontend_seq,
                                            cfg.n_kv_heads, cfg.hd), dtype),
                                 ("batch", "enc_seq", "kv_heads", "head_dim"))
                c["cross_v"] = P(jnp.zeros((batch_size, cfg.frontend_seq,
                                            cfg.n_kv_heads, cfg.hd), dtype),
                                 ("batch", "enc_seq", "kv_heads", "head_dim"))
            return c

        def stack_cache(c):
            return jax.tree_util.tree_map(
                lambda p: P(jnp.zeros((self.n_scan_periods,) + p.value.shape,
                                      p.value.dtype), ("layers",) + p.axes),
                c, is_leaf=lambda x: isinstance(x, P))

        cache = {
            "prefix": tuple(one(cfg.period[0])
                            for _ in range(self.prefix_count)),
            "periods": tuple(stack_cache(one(k)) for k in cfg.period)
            if self.n_scan_periods else (),
            "trailing": tuple(one(k) for k in self.trailing_kinds),
        }
        return cache
