"""lockdep — opt-in runtime lock-order sanitizer for the fabric.

Enable with ``REPRO_LOCKDEP=1`` (tests/conftest.py installs it before
the suite imports the fabric).  :func:`install` monkeypatches the
``threading.Lock`` / ``RLock`` / ``Condition`` factories so every lock
subsequently *created from fabric code* is wrapped in a
:class:`TrackedLock`.  Locks are keyed by **creation site**
(``file:line``), the classic lockdep move: every ``ReplicationCore``
instance's ``_lock`` shares one key, so an ordering observed between
two instances in a test generalizes to the fleet.

What it records:

  * the cross-thread **acquisition-order graph**: an edge A→B each
    time a thread acquires a B-site lock while holding an A-site lock.
    Adding an edge that closes a directed cycle is a potential
    deadlock — recorded as a violation (same-site edges are skipped:
    two instances of one class may nest by protocol, e.g. a sender
    touching a peer's inbox lock after releasing its own).
  * **locks held across an RPC boundary**: ``Handle.forward`` and the
    blocking ``Engine.call`` / ``pull`` / ``push`` are hooked; entering
    any of them with a tracked lock held is a violation (a remote
    round-trip under a local lock is a distributed lock-hold).
  * per-site **hold-time histograms**, exported through the PR-7
    metrics registry as ``analysis.lock.hold_ms{site=...}`` — sites
    are a bounded set, so this respects the cardinality policy.

The wrapper keeps the full lock protocol — including the private
``_is_owned`` / ``_release_save`` / ``_acquire_restore`` hooks
``threading.Condition`` uses — so condition variables built over
tracked locks (``Condition(self._cq_lock)``, the default
``Condition()``) keep working, and a ``cv.wait()`` correctly drops the
lock from the thread's held-stack while parked.

Tests can use the machinery without global patching::

    g = lockdep.LockGraph(metrics=False)
    a = lockdep.wrap(threading.Lock(), "A", g)
    b = lockdep.wrap(threading.Lock(), "B", g)
    ...
    assert not g.report()["cycles"]
"""
from __future__ import annotations

import os
import sys
import threading
import time
from typing import Dict, List, Optional, Tuple

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock
_REAL_CONDITION = threading.Condition

# never track locks created inside these files: the metrics registry's
# own locks would recurse through the hold-time export, and threading.py
# internals (Event, Queue plumbing) are not fabric locks
_EXCLUDE_PARTS = (os.path.join("telemetry", "metrics.py"), "threading.py")

_MAX_VIOLATIONS = 64


def _site_of(frame) -> str:
    fn = frame.f_code.co_filename.replace(os.sep, "/")
    idx = fn.rfind("repro/")
    if idx < 0:
        idx = fn.rfind("tests/")
    short = fn[idx:] if idx >= 0 else os.path.basename(fn)
    return f"{short}:{frame.f_lineno}"


class LockGraph:
    """Acquisition-order graph + violation log (one per install; tests
    may build private instances)."""

    def __init__(self, metrics: bool = True):
        self._mu = _REAL_LOCK()          # internal — never tracked
        self._tls = threading.local()
        # edges[a][b] = thread name that first observed a→b
        self.edges: Dict[str, Dict[str, str]] = {}
        self.cycles: List[dict] = []
        self.rpc_violations: List[dict] = []
        self.acquisitions = 0
        self._metrics = metrics
        self._hist = None

    # -- per-thread held stack --------------------------------------------

    def _stack(self) -> List[Tuple[object, float]]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def held_sites(self) -> List[str]:
        """Distinct sites of locks the current thread holds, outermost
        first."""
        seen, out = set(), []
        for lock, _t in self._stack():
            if lock.site not in seen:
                seen.add(lock.site)
                out.append(lock.site)
        return out

    def owns(self, lock: "TrackedLock") -> bool:
        return any(entry[0] is lock for entry in self._stack())

    # -- events ------------------------------------------------------------

    def note_acquire(self, lock: "TrackedLock") -> None:
        st = self._stack()
        self.acquisitions += 1
        if not any(e[0] is lock for e in st):      # not a re-entry
            held = []
            seen = set()
            for other, _t in st:
                if other.site != lock.site and other.site not in seen:
                    seen.add(other.site)
                    held.append(other.site)
            for site in held:
                self._add_edge(site, lock.site)
        st.append((lock, time.monotonic()))

    def note_release(self, lock: "TrackedLock") -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i][0] is lock:
                _l, t0 = st.pop(i)
                if not any(e[0] is lock for e in st):
                    self._observe_hold(lock.site, time.monotonic() - t0)
                return

    def note_release_all(self, lock: "TrackedLock") -> int:
        """Condition._release_save on an RLock: drop every recursion
        level.  Returns the count so the restore can push them back."""
        st = self._stack()
        n = 0
        t0 = None
        for i in range(len(st) - 1, -1, -1):
            if st[i][0] is lock:
                t0 = st.pop(i)[1]
                n += 1
        if n and t0 is not None:
            self._observe_hold(lock.site, time.monotonic() - t0)
        return n

    def note_reacquire(self, lock: "TrackedLock", n: int) -> None:
        # restoring after a cv.wait: not a new ordering observation
        st = self._stack()
        now = time.monotonic()
        for _ in range(max(1, n)):
            st.append((lock, now))

    def note_rpc(self, op: str) -> None:
        held = self.held_sites()
        if not held:
            return
        with self._mu:
            if len(self.rpc_violations) < _MAX_VIOLATIONS:
                self.rpc_violations.append({
                    "op": op,
                    "held": held,
                    "thread": threading.current_thread().name,
                })

    # -- graph -------------------------------------------------------------

    def _add_edge(self, a: str, b: str) -> None:
        d = self.edges.get(a)
        if d is not None and b in d:       # racy fast path: reads are safe
            return
        with self._mu:
            d = self.edges.setdefault(a, {})
            if b in d:
                return
            d[b] = threading.current_thread().name
            path = self._path_locked(b, a)
            if path and len(self.cycles) < _MAX_VIOLATIONS:
                self.cycles.append({
                    "edge": (a, b),
                    "cycle": [a, b] + path[1:],
                    "thread": threading.current_thread().name,
                })

    def _path_locked(self, src: str, dst: str) -> Optional[List[str]]:
        """DFS src→dst over edges (caller holds ``_mu``)."""
        stack, seen = [(src, [src])], {src}
        while stack:
            node, path = stack.pop()
            if node == dst:
                return path
            for nxt in self.edges.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None

    # -- metrics / reporting ----------------------------------------------

    def _observe_hold(self, site: str, dt: float) -> None:
        if not self._metrics:
            return
        if getattr(self._tls, "in_metric", False):
            return                          # re-entrancy firewall
        self._tls.in_metric = True
        try:
            from ..telemetry import metrics as _m
            _m.histogram("analysis.lock.hold_ms", site=site).observe(
                dt * 1e3)
        except Exception:
            pass
        finally:
            self._tls.in_metric = False

    def report(self) -> dict:
        with self._mu:
            return {
                "sites": len(set(self.edges) |
                             {b for d in self.edges.values() for b in d}),
                "edges": sum(len(d) for d in self.edges.values()),
                "acquisitions": self.acquisitions,
                "cycles": list(self.cycles),
                "rpc_violations": list(self.rpc_violations),
            }

    def assert_clean(self) -> None:
        rep = self.report()
        problems = []
        for c in rep["cycles"]:
            problems.append(f"lock-order cycle {' -> '.join(c['cycle'])} "
                            f"(closed by thread {c['thread']})")
        for r in rep["rpc_violations"]:
            problems.append(f"lock(s) {r['held']} held across RPC boundary "
                            f"'{r['op']}' (thread {r['thread']})")
        if problems:
            raise AssertionError(
                "lockdep: %d violation(s):\n  %s"
                % (len(problems), "\n  ".join(problems)))

    def reset(self) -> None:
        with self._mu:
            self.edges.clear()
            self.cycles.clear()
            self.rpc_violations.clear()
            self.acquisitions = 0


class TrackedLock:
    """Wraps a real lock/rlock; reports acquire/release to a LockGraph."""

    def __init__(self, inner, site: str, graph: LockGraph):
        self._inner = inner
        self.site = site
        self._graph = graph

    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._graph.note_acquire(self)
        return got

    def release(self) -> None:
        self._graph.note_release(self)
        self._inner.release()

    def __enter__(self) -> "TrackedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        f = getattr(self._inner, "locked", None)
        return bool(f()) if f is not None else False

    # -- threading.Condition protocol -------------------------------------

    def _is_owned(self) -> bool:
        f = getattr(self._inner, "_is_owned", None)
        if f is not None:
            return f()
        return self._graph.owns(self)

    def _release_save(self):
        f = getattr(self._inner, "_release_save", None)
        if f is not None:
            n = self._graph.note_release_all(self)
            return ("deep", f(), n)
        self._graph.note_release(self)
        self._inner.release()
        return ("flat", None, 1)

    def _acquire_restore(self, saved) -> None:
        kind, state, n = saved
        if kind == "deep":
            self._inner._acquire_restore(state)
        else:
            self._inner.acquire()
        self._graph.note_reacquire(self, n)

    def _at_fork_reinit(self) -> None:
        f = getattr(self._inner, "_at_fork_reinit", None)
        if f is not None:
            f()

    def __repr__(self) -> str:
        return f"<TrackedLock {self.site} over {self._inner!r}>"


def wrap(lock, site: str, graph: Optional[LockGraph] = None) -> TrackedLock:
    """Wrap an existing lock under an explicit site name (test entry
    point — no global patching involved)."""
    return TrackedLock(lock, site, graph or _state["graph"] or LockGraph())


# ---------------------------------------------------------------------------
# global install

_state = {
    "installed": False,
    "graph": None,
    "saved": None,
}


def enabled() -> bool:
    return os.environ.get("REPRO_LOCKDEP") == "1"


def _wants_tracking(frame, prefixes) -> bool:
    fn = frame.f_code.co_filename
    if any(part in fn for part in _EXCLUDE_PARTS):
        return False
    if prefixes is None:
        return True
    norm = fn.replace(os.sep, "/")
    return any(p in norm for p in prefixes)


def _lock_factory(real, graph: LockGraph, prefixes):
    def factory():
        frame = sys._getframe(1)
        if not _wants_tracking(frame, prefixes):
            return real()
        return TrackedLock(real(), _site_of(frame), graph)
    return factory


def _condition_factory(graph: LockGraph, prefixes):
    def Condition(lock=None):
        if lock is None:
            frame = sys._getframe(1)
            if _wants_tracking(frame, prefixes):
                lock = TrackedLock(_REAL_RLOCK(), _site_of(frame), graph)
        return _REAL_CONDITION(lock) if lock is not None \
            else _REAL_CONDITION()
    return Condition


def _patch_rpc(graph: LockGraph) -> List[Tuple[object, str, object]]:
    """Hook the RPC boundary: entering forward/call/pull/push with a
    tracked lock held is a violation."""
    saved: List[Tuple[object, str, object]] = []

    def hook(owner, name):
        orig = getattr(owner, name, None)
        if orig is None:
            return

        def checked(self, *args, **kwargs):
            graph.note_rpc(f"{owner.__name__}.{name}")
            return orig(self, *args, **kwargs)

        checked.__name__ = name
        saved.append((owner, name, orig))
        setattr(owner, name, checked)

    from ..core import executor as _executor
    from ..core import rpc as _rpc
    hook(_rpc.Handle, "forward")
    for name in ("call", "pull", "push"):
        hook(_executor.Engine, name)
    return saved


def install(graph: Optional[LockGraph] = None,
            prefixes: Optional[Tuple[str, ...]] = ("repro/",)) -> LockGraph:
    """Patch the lock factories + RPC boundary.  Idempotent; returns
    the active graph.  ``prefixes=None`` tracks every creation site
    (excluding the hard exclusions)."""
    if _state["installed"]:
        return _state["graph"]
    g = graph or LockGraph()
    saved_rpc = _patch_rpc(g)
    _state.update(installed=True, graph=g, saved=saved_rpc)
    threading.Lock = _lock_factory(_REAL_LOCK, g, prefixes)
    threading.RLock = _lock_factory(_REAL_RLOCK, g, prefixes)
    threading.Condition = _condition_factory(g, prefixes)
    return g


def uninstall() -> None:
    """Restore the real factories and RPC methods (already-wrapped lock
    instances keep working — they are just no longer created)."""
    if not _state["installed"]:
        return
    threading.Lock = _REAL_LOCK
    threading.RLock = _REAL_RLOCK
    threading.Condition = _REAL_CONDITION
    for owner, name, orig in _state["saved"] or []:
        setattr(owner, name, orig)
    _state.update(installed=False, graph=None, saved=None)


def graph() -> Optional[LockGraph]:
    return _state["graph"]


def report() -> dict:
    g = _state["graph"]
    return g.report() if g else {"sites": 0, "edges": 0, "acquisitions": 0,
                                 "cycles": [], "rpc_violations": []}


def assert_clean() -> None:
    g = _state["graph"]
    if g is not None:
        g.assert_clean()
