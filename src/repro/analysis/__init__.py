"""Concurrency-invariant analysis for the RPC fabric (DESIGN.md §11).

Two analyzers, one vocabulary:

  * :mod:`repro.analysis.lint` (**fablint**) — an AST-based static pass
    over the source tree that enforces the project's concurrency
    conventions: ``#: guarded-by`` field discipline, no blocking
    operations under a lock, span lifecycle, monotonic-clock
    discipline, thread hygiene, and metrics-cardinality policy.
    Run it as ``python -m repro.analysis.lint src/``.

  * :mod:`repro.analysis.lockdep` — an opt-in runtime sanitizer
    (``REPRO_LOCKDEP=1``) that wraps the fabric's locks, records the
    cross-thread acquisition-order graph, flags order cycles
    (potential deadlocks) and locks held across an RPC boundary, and
    exports per-lock hold-time histograms through the metrics
    registry.

Static analysis proves lexical discipline; the sanitizer catches what
statics cannot (actual cross-object acquisition order at runtime).
They are designed to be run together in CI — see the ``analysis`` job.
"""
# Submodules are imported lazily (``from repro.analysis import lint``)
# so ``python -m repro.analysis.lint`` does not double-import the
# module it is about to execute.
__all__ = ["lint", "lockdep"]
