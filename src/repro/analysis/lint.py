"""fablint — AST-based concurrency-invariant lint for the RPC fabric.

Usage::

    python -m repro.analysis.lint src/            # lint a tree
    python -m repro.analysis.lint a.py b.py       # lint files

The rules are project-specific (see DESIGN.md §11 for the catalogue and
the motivating pre-fix violation behind each one):

``guarded-by``
    Attributes annotated ``#: guarded-by _lock`` may only be read or
    written under ``with self._lock`` (aliases: ``#: guarded-by
    _cq_lock,_cq_cv`` accepts either name; a ``threading.Condition``
    built over an existing lock aliases automatically).  Methods whose
    name ends in ``_locked`` — the repo's convention for
    must-be-called-under-the-lock helpers — or carrying a
    ``#: requires _lock`` comment are assumed to hold the lock at
    entry.  ``__init__`` is exempt (the object is not shared yet).

``lock-blocking``
    No blocking operation while holding a lock: ``Handle.forward``,
    ``call``/``call_async``/``call_each``/``call_on``/``call_routed``,
    socket ``send``/``recv``/``sendall``, ``Future.result``,
    ``Thread.join``, ``Event.wait`` (waiting *on the held lock's own
    condition variable* is the one allowed wait), ``time.sleep``, and
    proc ``encode``/``decode`` (two-argument form — the PR-5
    gossip-stats bug class).

``span-finish``
    Every ``trace.start_span()``/``start_trace()`` must be finished on
    all paths: a ``finally`` block, an except-handler *plus* the
    fall-through path, or ownership handed off (returned, stored,
    passed to a callback/closure).

``wallclock``
    ``time.time()`` is banned — lease/TTL/deadline arithmetic must use
    ``time.monotonic()``.  The deliberate wall-clock sites (human-facing
    timestamps, the wire-age translation boundary) live in the baseline
    file.

``thread-hygiene``
    Every ``threading.Thread`` is created ``daemon=True`` or joined
    (PR-5's wedged-interpreter-exit bug class).

``metric-cardinality``
    Metric names are string literals and label values come from bounded
    sets — no f-strings, concatenation, or formatting in either
    (DESIGN.md §10's cardinality policy).

Suppressions: an inline ``# fablint: ok[rule-id] reason`` comment on
the flagged line waives it in place; the checked-in baseline file
(``baseline.txt`` next to this module) lists the few deliberate
exceptions as ``rule-id path::qualname  # reason`` lines.  A baseline
entry that no longer matches anything is itself an error ("baseline
drift") so the file can only shrink.
"""
from __future__ import annotations

import argparse
import ast
import io
import os
import re
import sys
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

GUARD_RE = re.compile(r"#:\s*guarded-by\s+([\w.|,]+)")
REQUIRES_RE = re.compile(r"#:\s*requires\s+([\w.|,]+)")
OK_RE = re.compile(r"#\s*fablint:\s*ok\[([\w-]+)\]\s*(.*)")
LOCKISH_RE = re.compile(r"lock|cv|cond|wakeup|mutex", re.IGNORECASE)

BLOCKING_ATTRS = {
    "forward", "call", "call_async", "call_each", "call_on", "call_routed",
    "result", "recv", "sendall",
}
# ``.join(`` is only a blocking op when the receiver looks like a thread
# (str/bytes/os.path joins are everywhere)
THREADISH_RE = re.compile(r"^(t\d*|thr\w*|\w*thread\w*|worker\w*)$",
                          re.IGNORECASE)
LOCK_FACTORIES = {"Lock", "RLock", "Condition", "Semaphore",
                  "BoundedSemaphore"}
METRIC_FACTORIES = {"counter", "gauge", "histogram"}

RULES = ("guarded-by", "lock-blocking", "span-finish", "wallclock",
         "thread-hygiene", "metric-cardinality")


@dataclass
class Violation:
    rule: str
    path: str
    line: int
    qualname: str
    msg: str

    @property
    def key(self) -> str:
        return f"{self.rule} {norm_path(self.path)}::{self.qualname}"

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] "
                f"({self.qualname}) {self.msg}")


def norm_path(path: str) -> str:
    """Stable key: the path from the last ``repro/`` (or ``tests/``)
    component on, so the same baseline matches ``src/repro/...``,
    ``./repro/...`` and absolute paths."""
    p = path.replace(os.sep, "/")
    for marker in ("repro/", "tests/"):
        idx = p.rfind(marker)
        if idx >= 0:
            return p[idx:]
    return p.lstrip("./")


def _split_locks(spec: str) -> Set[str]:
    return {s for s in re.split(r"[|,]", spec) if s}


# ---------------------------------------------------------------------------
# per-class / per-module collected facts


@dataclass
class ClassInfo:
    name: str
    locks: Set[str] = field(default_factory=set)
    guards: Dict[str, Set[str]] = field(default_factory=dict)
    # condition-variable aliasing: Condition(self._lock) means holding
    # either name satisfies a guard naming the other
    aliases: Dict[str, Set[str]] = field(default_factory=dict)

    def alias_closure(self, names: Iterable[str]) -> Set[str]:
        out = set(names)
        for n in list(out):
            out |= self.aliases.get(n, set())
        return out


@dataclass
class ModuleInfo:
    path: str
    comments: Dict[int, str]
    own_line: Set[int] = field(default_factory=set)       # standalone comments
    locks: Set[str] = field(default_factory=set)          # module-level
    guards: Dict[str, Set[str]] = field(default_factory=dict)

    def comment_above(self, line: int) -> str:
        """Comment on the line above — only if it is a standalone comment
        (a trailing comment belongs to *its* line, not the next one)."""
        if line - 1 in self.own_line:
            return self.comments.get(line - 1, "")
        return ""

    def comment_near(self, line: int) -> str:
        return self.comments.get(line, "") + " " + self.comment_above(line)

    def suppressed(self, rule: str, line: int) -> bool:
        for text in (self.comments.get(line, ""), self.comment_above(line)):
            m = OK_RE.search(text)
            if m and m.group(1) == rule:
                return True
        return False


def _collect_comments(source: str) -> Tuple[Dict[int, str], Set[int]]:
    comments: Dict[int, str] = {}
    own_line: Set[int] = set()
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                comments[tok.start[0]] = tok.string
                if not tok.line[:tok.start[1]].strip():
                    own_line.add(tok.start[0])
    except tokenize.TokenError:
        pass
    return comments, own_line


def _is_lock_factory(call: ast.Call) -> bool:
    fn = call.func
    if isinstance(fn, ast.Attribute) and fn.attr in LOCK_FACTORIES:
        return True
    if isinstance(fn, ast.Name) and fn.id in LOCK_FACTORIES:
        return True
    # dataclass field(default_factory=threading.Lock)
    if isinstance(fn, ast.Name) and fn.id == "field":
        for kw in call.keywords:
            if kw.arg == "default_factory":
                v = kw.value
                if isinstance(v, ast.Attribute) and v.attr in LOCK_FACTORIES:
                    return True
                if isinstance(v, ast.Name) and v.id in LOCK_FACTORIES:
                    return True
    return False


def _self_attr(node: ast.expr) -> Optional[str]:
    """``self.a`` -> "a"; ``self.a.b`` -> "a.b"; else None."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name) and cur.id == "self" and parts:
        return ".".join(reversed(parts))
    return None


def _collect_class(cls: ast.ClassDef, mod: ModuleInfo) -> ClassInfo:
    info = ClassInfo(cls.name)

    def note_guard(attr: str, line: int, end_line: int) -> None:
        texts = [mod.comment_above(line), mod.comments.get(line, ""),
                 mod.comments.get(end_line, "")]
        for text in texts:
            m = GUARD_RE.search(text)
            if m:
                info.guards[attr] = _split_locks(m.group(1))
                info.locks |= {g for g in info.guards[attr]
                               if "." not in g}
                return

    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            attr = _self_attr(node.targets[0])
            if attr is None and isinstance(node.targets[0], ast.Name):
                attr = node.targets[0].id          # class-body assignment
            if attr is None or "." in attr:
                continue
            if isinstance(node.value, ast.Call) and \
                    _is_lock_factory(node.value):
                info.locks.add(attr)
                call = node.value
                fn = call.func
                cond = (isinstance(fn, ast.Attribute) and
                        fn.attr == "Condition") or \
                       (isinstance(fn, ast.Name) and fn.id == "Condition")
                if cond and call.args:
                    base = _self_attr(call.args[0])
                    if base:
                        info.aliases.setdefault(attr, set()).add(base)
                        info.aliases.setdefault(base, set()).add(attr)
            note_guard(attr, node.lineno, node.end_lineno or node.lineno)
        elif isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name):
            attr = node.target.id                  # dataclass field
            if isinstance(node.value, ast.Call) and \
                    _is_lock_factory(node.value):
                info.locks.add(attr)
            note_guard(attr, node.lineno, node.end_lineno or node.lineno)
    return info


def _collect_module(tree: ast.Module, mod: ModuleInfo) -> None:
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if isinstance(node.value, ast.Call) and \
                    _is_lock_factory(node.value):
                mod.locks.add(name)
            for text in (mod.comment_above(node.lineno),
                         mod.comments.get(node.lineno, ""),
                         mod.comments.get(node.end_lineno or node.lineno, "")):
                m = GUARD_RE.search(text)
                if m:
                    mod.guards[name] = _split_locks(m.group(1))
                    break


# ---------------------------------------------------------------------------
# the checker


class _FunctionChecker(ast.NodeVisitor):
    """Walks one top-level function/method, tracking lexically held
    locks through ``with`` statements (nested defs inherit the lexical
    held-set: a closure defined under a lock runs its enclosing
    critical section's discipline)."""

    def __init__(self, linter: "Linter", mod: ModuleInfo,
                 cls: Optional[ClassInfo], qualname: str, fn: ast.AST):
        self.linter = linter
        self.mod = mod
        self.cls = cls
        self.qualname = qualname
        self.fn = fn
        self.held: List[str] = []
        self.local_locks: Set[str] = set()
        self.spans: Dict[str, dict] = {}
        self.in_init = qualname.split(".")[-1] == "__init__"
        # context flags for span-finish classification
        self._in_finally = 0
        self._in_except = 0
        self._in_closure = 0
        self._calls_since: Dict[str, int] = {}

    # -- plumbing ----------------------------------------------------------

    def err(self, rule: str, node: ast.AST, msg: str) -> None:
        self.linter.add(Violation(rule, self.mod.path, node.lineno,
                                  self.qualname, msg))

    def _lock_token(self, node: ast.expr) -> Optional[str]:
        """Render a with-item / wait-target expression to a lock token."""
        attr = _self_attr(node)
        if attr is not None:
            if attr.split(".")[-1] in (self.cls.locks if self.cls else set()) \
                    or LOCKISH_RE.search(attr.split(".")[-1]) \
                    or (self.cls and attr in
                        {g for gs in self.cls.guards.values() for g in gs}):
                return attr
            return None
        if isinstance(node, ast.Name):
            if node.id in self.mod.locks or node.id in self.local_locks or \
                    LOCKISH_RE.search(node.id):
                return node.id
            return None
        if isinstance(node, ast.Attribute):
            # non-self attribute chain, e.g. ``peer._lock``
            if LOCKISH_RE.search(node.attr):
                return f"<{node.attr}>"
        return None

    def _held_satisfies(self, wanted: Set[str]) -> bool:
        if not self.held:
            return False
        want = self.cls.alias_closure(wanted) if self.cls else set(wanted)
        for h in self.held:
            if h in want or h.split(".")[-1] in want:
                return True
        return False

    # -- statements --------------------------------------------------------

    def visit_With(self, node: ast.With) -> None:
        tokens = []
        for item in node.items:
            tok = self._lock_token(item.context_expr)
            if tok is not None:
                tokens.append(tok)
        self.held.extend(tokens)
        for stmt in node.body:
            self.visit(stmt)
        for _ in tokens:
            self.held.pop()

    def visit_Assign(self, node: ast.Assign) -> None:
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if isinstance(node.value, ast.Call):
                if _is_lock_factory(node.value):
                    self.local_locks.add(name)
                fn = node.value.func
                attr = fn.attr if isinstance(fn, ast.Attribute) else \
                    (fn.id if isinstance(fn, ast.Name) else "")
                if attr in ("start_span", "start_trace"):
                    self.spans[name] = {
                        "node": node, "finished": False, "plain": False,
                        "safe": False, "except": False,
                        "calls_after_plain": False,
                    }
                    self.generic_visit(node.value)
                    return
        self.generic_visit(node)

    def visit_Try(self, node: ast.Try) -> None:
        for stmt in node.body:
            self.visit(stmt)
        self._in_except += 1
        for handler in node.handlers:
            for stmt in handler.body:
                self.visit(stmt)
        self._in_except -= 1
        for stmt in node.orelse:
            self.visit(stmt)
        self._in_finally += 1
        for stmt in node.finalbody:
            self.visit(stmt)
        self._in_finally -= 1

    def _visit_nested(self, node: ast.AST) -> None:
        self._in_closure += 1
        self.generic_visit(node)
        self._in_closure -= 1

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_nested(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_nested(node)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._visit_nested(node)

    def visit_Return(self, node: ast.Return) -> None:
        if isinstance(node.value, ast.Name) and node.value.id in self.spans:
            self.spans[node.value.id]["safe"] = True
        self.generic_visit(node)

    # -- expressions -------------------------------------------------------

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if self.cls and not self.in_init:
            attr = _self_attr(node)
            if attr in self.cls.guards and \
                    not GUARD_RE.search(self.mod.comment_near(node.lineno)):
                if not self._held_satisfies(self.cls.guards[attr]):
                    if not self.mod.suppressed("guarded-by", node.lineno):
                        locks = ",".join(sorted(self.cls.guards[attr]))
                        self.err("guarded-by", node,
                                 f"'self.{attr}' is guarded by '{locks}' "
                                 f"but accessed without holding it")
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if node.id in self.mod.guards and \
                not GUARD_RE.search(self.mod.comment_near(node.lineno)):
            if not self._held_satisfies_module(self.mod.guards[node.id]):
                if not self.mod.suppressed("guarded-by", node.lineno):
                    locks = ",".join(sorted(self.mod.guards[node.id]))
                    self.err("guarded-by", node,
                             f"'{node.id}' is guarded by '{locks}' "
                             f"but accessed without holding it")
        self.generic_visit(node)

    def _held_satisfies_module(self, wanted: Set[str]) -> bool:
        return any(h in wanted for h in self.held)

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        fn_attr = fn.attr if isinstance(fn, ast.Attribute) else None
        fn_name = fn.id if isinstance(fn, ast.Name) else None

        # span bookkeeping: x.finish(...) / escape via call argument
        if fn_attr == "finish" and isinstance(fn.value, ast.Name) and \
                fn.value.id in self.spans:
            rec = self.spans[fn.value.id]
            rec["finished"] = True
            if self._in_finally or self._in_closure:
                rec["safe"] = True
            elif self._in_except:
                rec["except"] = True
            else:
                rec["plain"] = True
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Name) and arg.id in self.spans:
                self.spans[arg.id]["safe"] = True

        self._check_blocking(node, fn_attr, fn_name)
        self._check_wallclock(node, fn_attr, fn_name)
        self._check_thread(node, fn_attr, fn_name)
        self._check_metric(node, fn_attr, fn_name)
        self.generic_visit(node)

    # -- the rules ---------------------------------------------------------

    def _check_blocking(self, node: ast.Call, fn_attr, fn_name) -> None:
        if not self.held:
            return
        blocked = None
        if fn_attr in BLOCKING_ATTRS:
            blocked = fn_attr
        elif fn_attr == "join":
            recv = node.func.value
            name = _self_attr(recv) or \
                (recv.id if isinstance(recv, ast.Name) else "")
            if name and THREADISH_RE.match(name.split(".")[-1]):
                blocked = "join"
        elif fn_attr == "wait":
            target = self._lock_token(node.func.value)
            waited = _self_attr(node.func.value) or \
                (node.func.value.id if isinstance(node.func.value, ast.Name)
                 else None)
            allowed = False
            if target is not None or waited is not None:
                name = (target or waited)
                names = {name, name.split(".")[-1]}
                if self.cls:
                    names = self.cls.alias_closure(names)
                allowed = any(h in names or h.split(".")[-1] in names
                              for h in self.held)
            if not allowed:
                blocked = "wait"
        elif fn_attr == "send":
            # socket/pipe send; exempt generator.send-style single use on
            # lockish objects is not a thing in this tree
            blocked = "send"
        elif fn_attr in ("encode", "decode") and len(node.args) >= 2:
            blocked = fn_attr
        elif (fn_attr == "sleep" and isinstance(node.func.value, ast.Name)
              and node.func.value.id == "time") or fn_name == "sleep":
            blocked = "sleep"
        if blocked and not self.mod.suppressed("lock-blocking", node.lineno):
            self.err("lock-blocking", node,
                     f"blocking operation '{blocked}' while holding "
                     f"lock(s) {sorted(set(self.held))}")

    def _check_wallclock(self, node: ast.Call, fn_attr, fn_name) -> None:
        is_time = (fn_attr == "time" and
                   isinstance(node.func.value, ast.Name) and
                   node.func.value.id == "time")
        if is_time and not self.mod.suppressed("wallclock", node.lineno):
            self.err("wallclock", node,
                     "time.time() is banned (use time.monotonic(); "
                     "wall clock only at annotated boundaries)")

    def _check_thread(self, node: ast.Call, fn_attr, fn_name) -> None:
        is_thread = (fn_attr == "Thread" and
                     isinstance(node.func.value, ast.Name) and
                     node.func.value.id == "threading") or \
                    fn_name == "Thread"
        if not is_thread:
            return
        for kw in node.keywords:
            if kw.arg == "daemon" and isinstance(kw.value, ast.Constant) \
                    and kw.value.value is True:
                return
        if self.mod.suppressed("thread-hygiene", node.lineno):
            return
        if self.linter.scope_has_join(self.mod.path, node):
            return
        self.err("thread-hygiene", node,
                 "threading.Thread is neither daemon=True nor joined "
                 "in its owning scope (wedges interpreter exit)")

    def _check_metric(self, node: ast.Call, fn_attr, fn_name) -> None:
        if fn_attr not in METRIC_FACTORIES:
            return
        base = node.func.value
        if not (isinstance(base, ast.Name) and
                base.id.lstrip("_") in ("metrics", "m")):
            return
        if self.mod.suppressed("metric-cardinality", node.lineno):
            return
        if not node.args or not (isinstance(node.args[0], ast.Constant) and
                                 isinstance(node.args[0].value, str)):
            self.err("metric-cardinality", node,
                     "metric name must be a string literal "
                     "(unbounded names explode the registry)")
        for kw in node.keywords:
            if kw.arg is None:
                continue
            if not isinstance(kw.value, (ast.Constant, ast.Name,
                                         ast.Attribute)):
                self.err("metric-cardinality", node,
                         f"label '{kw.arg}' value must be a literal or a "
                         f"bounded-set variable, not an expression")

    # -- finish ------------------------------------------------------------

    def finalize(self) -> None:
        for name, rec in self.spans.items():
            node = rec["node"]
            if self.mod.suppressed("span-finish", node.lineno):
                continue
            if rec["safe"]:
                continue
            if rec["except"] and rec["plain"]:
                continue
            if not rec["finished"]:
                self.err("span-finish", node,
                         f"span '{name}' is never finished "
                         f"(use try/finally or hand it off)")
            elif rec["plain"] and not rec["except"]:
                self.err("span-finish", node,
                         f"span '{name}' leaks if an exception is raised "
                         f"before the straight-line finish "
                         f"(use try/finally)")


# ---------------------------------------------------------------------------
# driver


class Linter:
    def __init__(self) -> None:
        self.violations: List[Violation] = []
        self._join_cache: Dict[Tuple[str, int], bool] = {}
        self._scopes: Dict[str, List[ast.AST]] = {}

    def add(self, v: Violation) -> None:
        self.violations.append(v)

    def scope_has_join(self, path: str, thread_call: ast.Call) -> bool:
        """True when any ``.join(`` call appears in the function or class
        that owns the Thread() creation (deliberately coarse: the point
        is catching threads nobody *ever* joins)."""
        for scope in self._scopes.get(path, []):
            lo = scope.lineno
            hi = scope.end_lineno or scope.lineno
            if not (lo <= thread_call.lineno <= hi):
                continue
            for sub in ast.walk(scope):
                if isinstance(sub, ast.Call) and \
                        isinstance(sub.func, ast.Attribute) and \
                        sub.func.attr == "join":
                    return True
        return False

    def check_source(self, source: str, path: str) -> List[Violation]:
        before = len(self.violations)
        try:
            tree = ast.parse(source)
        except SyntaxError as e:
            self.add(Violation("parse", path, e.lineno or 0, "<module>",
                               f"syntax error: {e.msg}"))
            return self.violations[before:]
        comments, own_line = _collect_comments(source)
        mod = ModuleInfo(path, comments, own_line)
        _collect_module(tree, mod)

        # scopes for thread-hygiene join lookup: innermost-first order
        scopes: List[ast.AST] = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                scopes.append(node)
        scopes.sort(key=lambda n: ((n.end_lineno or n.lineno) - n.lineno))
        self._scopes[path] = scopes

        def run(fn: ast.AST, cls: Optional[ClassInfo], qual: str) -> None:
            chk = _FunctionChecker(self, mod, cls, qual, fn)
            requires: Set[str] = set()
            m = REQUIRES_RE.search(mod.comment_near(fn.lineno))
            if m:
                requires |= _split_locks(m.group(1))
            if qual.split(".")[-1].endswith("_locked") and cls:
                requires |= cls.locks | \
                    {g for gs in cls.guards.values() for g in gs}
            chk.held.extend(sorted(requires))
            for stmt in fn.body:
                chk.visit(stmt)
            chk.finalize()

        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                run(node, None, node.name)
            elif isinstance(node, ast.ClassDef):
                cinfo = _collect_class(node, mod)
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                        run(sub, cinfo, f"{node.name}.{sub.name}")
        return self.violations[before:]

    def check_file(self, path: str) -> None:
        with open(path, "r", encoding="utf-8") as f:
            self.check_source(f.read(), path)


def iter_py_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)
        else:
            yield p


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baseline.txt")


def load_baseline(path: str) -> Dict[str, str]:
    """``rule path::qualname  # reason`` lines -> {key: reason}."""
    entries: Dict[str, str] = {}
    if not os.path.exists(path):
        return entries
    with open(path, "r", encoding="utf-8") as f:
        for raw in f:
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            body, _, reason = line.partition("#")
            parts = body.split()
            if len(parts) != 2:
                continue
            entries[f"{parts[0]} {parts[1]}"] = reason.strip()
    return entries


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="fablint: concurrency-invariant lint (DESIGN.md §11)")
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--baseline", default=default_baseline_path(),
                    help="baseline file of documented exceptions")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report everything)")
    args = ap.parse_args(argv)

    linter = Linter()
    n_files = 0
    for path in iter_py_files(args.paths):
        n_files += 1
        linter.check_file(path)

    baseline = {} if args.no_baseline else load_baseline(args.baseline)
    used: Set[str] = set()
    reported: List[Violation] = []
    for v in linter.violations:
        if v.key in baseline:
            used.add(v.key)
            continue
        reported.append(v)

    rc = 0
    for v in reported:
        print(v)
        rc = 1
    stale = set(baseline) - used
    for key in sorted(stale):
        print(f"baseline drift: entry no longer matches anything: {key}")
        rc = 1
    status = "clean" if rc == 0 else f"{len(reported)} violation(s)"
    print(f"fablint: {n_files} file(s), {status}, "
          f"{len(used)} baselined exception(s)", file=sys.stderr)
    return rc


if __name__ == "__main__":
    sys.exit(main())
