"""recurrentgemma-9b  [hybrid]
38L d_model=4096 16H (GQA kv=1, i.e. MQA) d_ff=12288 vocab=256000 —
RG-LRU (Griffin) recurrent blocks + local attention in a 2:1 pattern
(rec, rec, local-attn), window 2048.  O(1) recurrent state + bounded
window ⇒ long_500k applies.  38 = 12 full periods + 2 trailing recurrent
layers.  [arXiv:2402.19427; unverified]
"""
from .base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    period=("rglru", "rglru", "local"),
    window=2048,
    embed_scale=True,
    mlp="geglu",
    tie_embeddings=True,
    logit_softcap=30.0,
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=512, window=32, rglru=RGLRUConfig(lru_width=64),
    )
