"""seamless-m4t-large-v2  [audio]
24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206 — encoder-decoder
text backbone; the speech/audio frontend is a STUB (``input_specs()``
provides precomputed frame embeddings; see DESIGN.md).
[arXiv:2308.11596; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,          # decoder depth
    n_enc_layers=24,      # encoder depth
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    period=("attn",),
    mlp="gelu",
    qkv_bias=True,
    frontend="audio_frames",
    frontend_seq=512,      # precomputed speech frames per example
    frontend_dim=160,      # fbank-ish raw feature dim before projection
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, frontend_seq=16, frontend_dim=20,
    )
