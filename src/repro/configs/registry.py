"""Architecture registry: ``get(name)`` / ``reduced(name)`` / ``names()``.

Each assigned architecture lives in ``configs/<id>.py`` exposing
``CONFIG`` (the exact published shape) and ``reduced()`` (a small
same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from .base import ModelConfig, ShapeSpec, SHAPES

_ARCHS = [
    "granite_moe_3b_a800m",
    "deepseek_moe_16b",
    "seamless_m4t_large_v2",
    "gemma3_12b",
    "qwen1_5_0_5b",
    "nemotron_4_340b",
    "command_r_35b",
    "recurrentgemma_9b",
    "mamba2_1_3b",
    "paligemma_3b",
]

# public ids use dashes/dots; module names use underscores
_ID_TO_MOD = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "gemma3-12b": "gemma3_12b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "nemotron-4-340b": "nemotron_4_340b",
    "command-r-35b": "command_r_35b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-1.3b": "mamba2_1_3b",
    "paligemma-3b": "paligemma_3b",
}
_MOD_TO_ID = {v: k for k, v in _ID_TO_MOD.items()}


def names() -> List[str]:
    return list(_ID_TO_MOD)


def _module(arch: str):
    mod = _ID_TO_MOD.get(arch, arch.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod}")


def get(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def shapes_for(arch: str) -> Dict[str, ShapeSpec]:
    """Applicable shape cells for an arch (per assignment rules):
    ``long_500k`` only for sub-quadratic archs; all archs have decode
    (seamless decodes with its enc-dec decoder)."""
    cfg = get(arch)
    out = dict(SHAPES)
    if not cfg.supports_long_context:
        out.pop("long_500k")
    return out


def all_cells() -> List[tuple]:
    return [(a, s) for a in names() for s in shapes_for(a)]
