"""mamba2-1.3b  [ssm]
48L d_model=2048 (attention-free) d_ff=0 vocab=50280, ssm_state=128 —
SSD (state-space duality) blocks: chunked intra-chunk quadratic +
inter-chunk recurrent state carry.  O(1) decode state ⇒ long_500k applies.
[arXiv:2405.21060; unverified]
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    period=("ssd",),
    mlp="swiglu",            # unused (d_ff=0): SSD block carries the MLP role
    tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  ngroups=1, chunk=256),
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, vocab=512,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                      ngroups=1, chunk=32),
    )
