"""command-r-35b  [dense]
40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 — no biases,
parallel attention/FFN block (Cohere style), tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    period=("attn",),
    parallel_block=True,
    mlp="swiglu",
    tie_embeddings=True,
    rope_theta=8_000_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    )
