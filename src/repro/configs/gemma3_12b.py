"""gemma3-12b  [dense]
48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 — 5 local
(sliding-window 1024) : 1 global layer pattern, 128k context, qk-norm,
sqrt(d) embed scaling, separate RoPE base for global layers.
long_500k applies: decode cost is O(window) on 5/6 of layers; global
layers use the full KV — see DESIGN.md §4 note.
[hf:google/gemma-3-1b-pt; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    period=("local", "local", "local", "local", "local", "global"),
    window=1024,
    qk_norm=True,
    embed_scale=True,
    mlp="geglu",
    tie_embeddings=True,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, window=32,
    )
