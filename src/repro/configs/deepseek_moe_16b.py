"""deepseek-moe-16b  [moe]
28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400, MoE 64e top-6,
2 shared + 64 routed, fine-grained segmentation; layer 0 is a dense FFN
(width 10944) per the paper.  [arXiv:2401.06066; hf]
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    period=("attn",),
    mlp="swiglu",
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  first_layer_dense=True, first_dense_ff=10944),
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
        vocab=512,
        moe=MoEConfig(num_experts=8, top_k=2, num_shared_experts=1,
                      first_layer_dense=True, first_dense_ff=128),
    )
