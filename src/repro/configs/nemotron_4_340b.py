"""nemotron-4-340b  [dense]
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000 — squared-ReLU
MLP, GQA. The largest assigned arch: fitting 16 GB/chip requires full
ZeRO-3 + TP sharding (see EXPERIMENTS.md dry-run memory analysis).
[arXiv:2402.16819; unverified]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    period=("attn",),
    mlp="relu2",
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_ff=384, vocab=512,
    )
