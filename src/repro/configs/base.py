"""Model / parallelism / shape configuration.

One :class:`ModelConfig` covers every assigned architecture family
(dense, MoE, SSM, hybrid, enc-dec, VLM/audio-stub).  The per-layer block
pattern is expressed as a *period*: a short tuple of block kinds that
repeats down the stack (``("attn",)`` for uniform transformers,
``("local", "local", "local", "local", "local", "global")`` for gemma3's
5:1 mix, ``("rglru", "rglru", "local")`` for recurrentgemma, ``("ssd",)``
for mamba2).  Layers are stacked with ``lax.scan`` over periods so compile
time stays flat in depth; a partial trailing period is unrolled.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block kinds understood by models/transformer.py
ATTN_KINDS = ("attn", "local", "global")
RECURRENT_KINDS = ("rglru", "ssd")


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # routed experts (0 = dense MLP)
    top_k: int = 0
    num_shared_experts: int = 0     # DeepSeekMoE shared experts
    capacity_factor: float = 1.25   # train-time capacity
    router_z_coef: float = 1e-3     # router z-loss
    aux_coef: float = 1e-2          # load-balance loss
    first_layer_dense: bool = False # DeepSeekMoE: layer 0 is a dense FFN
    first_dense_ff: int = 0         # ... with its own width
    dispatch: str = "sort"          # sort | cumsum (see models/moe.py)


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128            # N (SSD state size)
    head_dim: int = 64              # P (channels per SSD head)
    expand: int = 2                 # d_inner = expand * d_model
    conv_width: int = 4
    ngroups: int = 1
    chunk: int = 256                # SSD chunk length


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0              # 0 -> d_model
    conv_width: int = 4
    block_kind_period: int = 3      # (rec, rec, local)


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 0               # 0 -> d_model // n_heads
    d_ff: int = 256
    vocab: int = 256
    # block structure
    period: Tuple[str, ...] = ("attn",)
    window: int = 1024              # sliding window for "local" blocks
    # attention details
    qkv_bias: bool = False
    attn_softcap: float = 0.0       # tanh logit soft-capping (0 = off)
    qk_norm: bool = False           # gemma3-style RMS-norm on q and k
    parallel_block: bool = False    # command-r: attn and ffn in parallel
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # separate base for "global" blocks (0 = same)
    prefix_lm: bool = False         # paligemma: bidirectional prefix
    logit_softcap: float = 0.0      # final-logit soft-capping
    # mlp
    mlp: str = "swiglu"             # swiglu | geglu | relu2 | gelu
    tie_embeddings: bool = False
    embed_scale: bool = False       # gemma-style sqrt(d_model) embed scaling
    # families
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    rglru: RGLRUConfig = field(default_factory=RGLRUConfig)
    # enc-dec
    n_enc_layers: int = 0           # encdec: encoder depth (n_layers = decoder)
    # modality frontends (stub: precomputed embeddings arrive as inputs)
    frontend: str = "none"          # none | audio_frames | vision_patches
    frontend_seq: int = 0           # frames/patches per example
    frontend_dim: int = 0           # raw embedding dim before projection
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    # ------------------------------------------------------------------ derived
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def trailing(self) -> Tuple[str, ...]:
        return self.period[: self.n_layers % len(self.period)]

    @property
    def is_recurrent_family(self) -> bool:
        return any(k in RECURRENT_KINDS for k in self.period)

    @property
    def supports_long_context(self) -> bool:
        """long_500k applies unless the arch is *pure* full attention.

        Skip rule (assignment): pure full-attention archs skip long_500k.
        A uniform ``attn`` stack is pure; SSM/hybrid and mixes dominated by
        bounded-window blocks (gemma3's 5:1 local:global, recurrentgemma's
        rglru+local) qualify — their decode state is O(window)/O(1) on all
        or most layers.
        """
        return "attn" not in self.period

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND."""
        c = self
        hd = self.hd
        n = c.vocab * c.d_model  # embedding (+ untied head counted below)
        if not c.tie_embeddings:
            n += c.vocab * c.d_model
        per_kind = {}
        attn = c.d_model * (c.n_heads * hd) + 2 * c.d_model * (c.n_kv_heads * hd) \
            + (c.n_heads * hd) * c.d_model
        mlp_mult = {"swiglu": 3, "geglu": 3, "relu2": 2, "gelu": 2}[c.mlp]
        dense_mlp = mlp_mult * c.d_model * c.d_ff
        moe_mlp = dense_mlp * (c.moe.num_experts + c.moe.num_shared_experts) \
            + c.d_model * c.moe.num_experts
        for kind in set(c.period) | set(c.trailing):
            if kind in ATTN_KINDS:
                body = attn + (moe_mlp if c.moe.num_experts else dense_mlp)
            elif kind == "rglru":
                w = c.rglru.lru_width or c.d_model
                body = 2 * c.d_model * w + w * c.d_model + 3 * w \
                    + c.rglru.conv_width * w + dense_mlp
            elif kind == "ssd":
                s = c.ssm
                d_in = s.expand * c.d_model
                nheads = d_in // s.head_dim
                zxbcdt = c.d_model * (2 * d_in + 2 * s.ngroups * s.state_dim + nheads)
                body = zxbcdt + s.conv_width * (d_in + 2 * s.ngroups * s.state_dim) \
                    + d_in * c.d_model + 2 * nheads
            else:
                raise ValueError(kind)
            per_kind[kind] = body
        for i in range(c.n_layers):
            kind = (list(c.period) * ((i // len(c.period)) + 1) + list(c.trailing))[i] \
                if False else c.kind_at(i)
            n += per_kind[kind]
        if c.moe.first_layer_dense and c.moe.num_experts:
            # layer 0 swaps MoE for a dense FFN of first_dense_ff
            n -= moe_mlp
            n += mlp_mult * c.d_model * c.moe.first_dense_ff
        if c.n_enc_layers:
            # encoder self-attn + mlp, decoder adds cross-attn
            n += c.n_enc_layers * (attn + dense_mlp)
            n += c.n_layers * attn  # cross-attention in each decoder layer
        if c.frontend != "none" and c.frontend_dim:
            n += c.frontend_dim * c.d_model
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared only)."""
        c = self
        if not c.moe.num_experts:
            return self.param_count()
        mlp_mult = {"swiglu": 3, "geglu": 3, "relu2": 2, "gelu": 2}[c.mlp]
        dense_mlp = mlp_mult * c.d_model * c.d_ff
        inactive_per_moe_layer = dense_mlp * (
            c.moe.num_experts - c.moe.top_k)
        n_moe_layers = c.n_layers - (1 if c.moe.first_layer_dense else 0)
        return self.param_count() - n_moe_layers * inactive_per_moe_layer

    def kind_at(self, i: int) -> str:
        return self.period[i % len(self.period)]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class ParallelConfig:
    """How a step is laid out on the mesh (see distrib/sharding.py)."""

    fsdp: bool = True               # shard params+opt over the data axis
    fsdp_axis: str = "data"
    tensor_axis: str = "model"
    pod_axis: Optional[str] = None  # present on the multi-pod mesh
    pipeline_stages: int = 1        # >1 enables the PP stage runner
    microbatches: int = 1           # grad-accumulation steps
    remat: str = "block"            # none | block | full
    seq_shard_decode: bool = True   # shard KV cache sequence over `model`
    compress_grads: bool = False    # int8 all-reduce w/ error feedback
    decode_twopass: bool = True     # shard_map 2-pass decode softmax
    param_gather_dtype: str = ""    # "bfloat16": cast params before use so
                                    # FSDP all-gathers / grad reduces travel
                                    # in 16-bit (mixed-precision ZeRO-3)

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)
