"""granite-moe-3b-a800m  [moe]
32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8.
Assignment lists "MoE 40e top-8" with a bracket note "32 experts top-8";
we take the primary spec (40 routed experts, top-8) — discrepancy recorded
in DESIGN.md §4. Fine-grained experts (d_ff=512 each).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    period=("attn",),
    mlp="swiglu",
    tie_embeddings=True,
    moe=MoEConfig(num_experts=40, top_k=8),
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab=512, moe=MoEConfig(num_experts=8, top_k=2),
    )
