from .base import (ModelConfig, MoEConfig, ParallelConfig, RGLRUConfig,
                   SHAPES, SSMConfig, ShapeSpec)
from .registry import all_cells, get, names, reduced, shapes_for

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "RGLRUConfig", "ParallelConfig",
    "ShapeSpec", "SHAPES", "get", "reduced", "names", "shapes_for",
    "all_cells",
]
