"""paligemma-3b  [vlm]
18L d_model=2048 8H (GQA kv=1, i.e. MQA) d_ff=16384 vocab=257216 —
gemma-2b text backbone; the SigLIP vision tower is a STUB
(``input_specs()`` provides precomputed patch embeddings).  Prefix-LM
attention: image+prefix tokens attend bidirectionally, suffix is causal.
[arXiv:2407.07726; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    period=("attn",),
    prefix_lm=True,
    embed_scale=True,
    mlp="geglu",
    tie_embeddings=True,
    frontend="vision_patches",
    frontend_seq=256,        # 224px/14 -> 16x16 patches
    frontend_dim=1152,       # SigLIP-So400m width
    rope_theta=10_000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=512, frontend_seq=16, frontend_dim=32,
    )
