"""Registry node launcher — one replica of the fabric's control plane.

Every node of a quorum is started with the SAME ordered ``--peers`` list
(order is leadership priority; the lowest-ranked live replica holds the
leader lease) and its own entry as ``--listen``.  Clients — pools,
``ServiceInstance``s, ``--registry`` flags — are given the whole
comma-separated set and fail over between replicas on their own.

  # three-node quorum (run one per host):
  python -m repro.launch.registry --listen tcp://10.0.0.1:7700 \\
      --peers tcp://10.0.0.1:7700,tcp://10.0.0.2:7700,tcp://10.0.0.3:7700
  ...same command on 10.0.0.2 / 10.0.0.3 with their --listen...

  # single-node (development):
  python -m repro.launch.registry --listen tcp://127.0.0.1:7700

See docs/OPERATIONS.md for deployment guidance and DESIGN.md §8 for the
replication protocol.
"""
from __future__ import annotations

import argparse
import time

from repro.core.executor import Engine
from repro.fabric import RegistryService
from repro.services import MembershipServer


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="fabric registry node (control plane replica)")
    ap.add_argument("--listen", required=True,
                    help="this node's address (set), e.g. tcp://0.0.0.0:7700")
    ap.add_argument("--peers", default=None, metavar="URI,URI,...",
                    help="ordered quorum peer list (identical on every "
                         "node; order = leadership priority).  Omit for a "
                         "single-node registry.")
    ap.add_argument("--self", dest="self_uri", default=None,
                    help="this node's entry in --peers when it differs "
                         "from the resolved --listen uri (e.g. listening "
                         "on 0.0.0.0 but advertised by host IP)")
    ap.add_argument("--instance-ttl", type=float, default=3.0,
                    help="seconds without a fab.report before an "
                         "instance is expired")
    ap.add_argument("--lease-ttl", type=float, default=1.0,
                    help="leader lease: seconds of gossip silence before "
                         "a peer is presumed dead")
    ap.add_argument("--gossip-interval", type=float, default=0.25,
                    help="seconds between gossip rounds")
    ap.add_argument("--membership", action="store_true",
                    help="co-host a MembershipServer (mem.*) on this "
                         "node; its member expiries reap bound instances")
    args = ap.parse_args(argv)

    engine = Engine(args.listen)
    peers = ([p.strip() for p in args.peers.split(",") if p.strip()]
             if args.peers else None)
    membership = MembershipServer(engine) if args.membership else None
    svc = RegistryService(
        engine, membership=membership,
        instance_ttl=args.instance_ttl, peers=peers,
        self_uri=args.self_uri, lease_ttl=args.lease_ttl,
        gossip_interval=args.gossip_interval)
    print(f"registry node at {engine.uri}"
          + (f" (quorum of {len(peers)}, priority "
             f"{peers.index(svc.self_uri)})" if peers else " (single)"),
          flush=True)
    try:
        last_role = None
        while True:
            time.sleep(2.0)
            st = svc._status({})
            if st["role"] != last_role:
                print(f"[registry] role={st['role']} "
                      f"leader={st['leader']} epoch={st['epoch']} "
                      f"instances={st['instances']}", flush=True)
                last_role = st["role"]
    except KeyboardInterrupt:
        pass
    finally:
        svc.close()
        if membership is not None:
            membership.close()
        engine.shutdown()


if __name__ == "__main__":
    main()
