"""Registry node launcher — replicas of the fabric's control plane.

Every node of a quorum is started with the SAME ordered ``--peers`` list
(order is leadership priority; the lowest-ranked live replica holds the
leader lease) and its own entry as ``--listen``.  Clients — pools,
``ServiceInstance``s, ``--registry`` flags — are given the whole
comma-separated set and fail over between replicas on their own.

Each node hosts the **unified control plane**: the registry's instance
table and the membership service's member table ride the same leader
lease and delta-gossip stream (``mem.*`` is served by every node —
follower reads, writes proxied to the leaseholder), so member liveness
and expiry reaps survive leaseholder death.  ``--no-membership`` turns
the membership service off; ``--full-gossip`` falls back to full-state
snapshot gossip (the delta protocol is the default).

**Sharding** (DESIGN.md §12): ``--shards M`` splits the name space
across M independent quorums by rendezvous hash.  Shard ``k`` listens
on the base ``--listen`` address offset by ``k`` (port + k, or a
``-k`` name suffix — see ``repro.fabric.sharding.shard_addr``) and the
same offset applies to every ``--peers`` entry; alternatively give
``--peers`` as an explicit ``|``-separated per-shard list.  By default
one process co-hosts all M shards; ``--shard-index K`` hosts only
shard K, for one-process-per-shard (or per-host) deployments.  The
membership plane is unsharded and rides shard 0.  Clients take the
``|``-joined spec the launcher prints.

  # three-node quorum (run one per host):
  python -m repro.launch.registry --listen tcp://10.0.0.1:7700 \\
      --peers tcp://10.0.0.1:7700,tcp://10.0.0.2:7700,tcp://10.0.0.3:7700
  ...same command on 10.0.0.2 / 10.0.0.3 with their --listen...

  # single-node (development):
  python -m repro.launch.registry --listen tcp://127.0.0.1:7700

  # four shards co-hosted (dev) on ports 7700..7703:
  python -m repro.launch.registry --listen tcp://127.0.0.1:7700 --shards 4

  # shard 2 of 4 as its own process:
  python -m repro.launch.registry --listen tcp://127.0.0.1:7700 \\
      --shards 4 --shard-index 2

See docs/OPERATIONS.md for deployment guidance and DESIGN.md §8/§12 for
the replication and sharding protocols.
"""
from __future__ import annotations

import argparse
import time

from repro.core.executor import Engine
from repro.fabric import RegistryService
from repro.fabric.sharding import SHARD_SEP, parse_shard_spec, shard_addr
from repro.telemetry import trace


def _shard_peer_sets(peers_arg, shards: int):
    """Per-shard ordered peer lists (or ``None`` for single-node
    shards) from either a base list (offset convention) or an explicit
    ``|``-separated per-shard spec."""
    if not peers_arg:
        return [None] * shards
    if SHARD_SEP in peers_arg:
        segments = parse_shard_spec(peers_arg)
        if len(segments) != shards:
            raise SystemExit(
                f"--peers names {len(segments)} shards but --shards is "
                f"{shards}")
        return [[p.strip() for p in seg.split(",") if p.strip()]
                for seg in segments]
    base = [p.strip() for p in peers_arg.split(",") if p.strip()]
    return [[shard_addr(p, k) for p in base] for k in range(shards)]


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="fabric registry node (control plane replica)")
    ap.add_argument("--listen", required=True,
                    help="this node's address (set), e.g. tcp://0.0.0.0:7700"
                         " — with --shards it is the shard-0 base address")
    ap.add_argument("--peers", default=None, metavar="URI,URI,...",
                    help="ordered quorum peer list (identical on every "
                         "node; order = leadership priority).  Omit for a "
                         "single-node registry.  With --shards: either a "
                         "base list (each entry offset per shard) or an "
                         "explicit '|'-separated per-shard list.")
    ap.add_argument("--self", dest="self_uri", default=None,
                    help="this node's entry in --peers when it differs "
                         "from the resolved --listen uri (e.g. listening "
                         "on 0.0.0.0 but advertised by host IP); offset "
                         "per shard like --listen")
    ap.add_argument("--shards", type=int, default=1, metavar="M",
                    help="shard the name space across M independent "
                         "quorums (DESIGN.md §12; default 1)")
    ap.add_argument("--shard-index", type=int, default=None, metavar="K",
                    help="host only shard K of the --shards map in this "
                         "process (default: co-host all M shards)")
    ap.add_argument("--instance-ttl", type=float, default=3.0,
                    help="seconds without a fab.report before an "
                         "instance is expired")
    ap.add_argument("--lease-ttl", type=float, default=1.0,
                    help="leader lease: seconds of gossip silence before "
                         "a peer is presumed dead")
    ap.add_argument("--gossip-interval", type=float, default=0.25,
                    help="seconds between gossip rounds")
    ap.add_argument("--membership", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="serve the membership plane (mem.*) from this "
                         "node's replicated member table; member "
                         "expiries reap bound instances (default: on; "
                         "sharded maps serve it from shard 0 only)")
    ap.add_argument("--heartbeat-timeout", type=float, default=2.0,
                    help="seconds without a mem.heartbeat before a "
                         "member is expired")
    ap.add_argument("--full-gossip", action="store_true",
                    help="replicate with full-state snapshot gossip "
                         "instead of per-entry deltas (debug/fallback)")
    ap.add_argument("--trace-sample", type=float, default=None,
                    metavar="P",
                    help="head-sampling probability for distributed "
                         "traces rooted here (0..1; default honors "
                         "REPRO_TRACE_SAMPLE, falling back to 0.01). "
                         "Sampled spans are served via dbg.trace")
    args = ap.parse_args(argv)

    if args.trace_sample is not None:
        trace.configure(sample=args.trace_sample)
    if args.shards < 1:
        raise SystemExit("--shards must be >= 1")
    if args.shard_index is not None and not (
            0 <= args.shard_index < args.shards):
        raise SystemExit("--shard-index out of range for --shards")

    own = ([args.shard_index] if args.shard_index is not None
           else list(range(args.shards)))
    peer_sets = _shard_peer_sets(args.peers, args.shards)

    engines, svcs = [], []
    for k in own:
        engine = Engine(shard_addr(args.listen, k))
        peers = peer_sets[k]
        svc = RegistryService(
            engine, instance_ttl=args.instance_ttl, peers=peers,
            self_uri=(shard_addr(args.self_uri, k)
                      if args.self_uri else None),
            lease_ttl=args.lease_ttl,
            gossip_interval=args.gossip_interval,
            delta_gossip=not args.full_gossip,
            serve_membership=args.membership and k == 0,
            heartbeat_timeout=args.heartbeat_timeout)
        engines.append(engine)
        svcs.append(svc)
        print(f"registry shard {k}/{args.shards} at {engine.uri}"
              + (f" (quorum of {len(peers)}, priority "
                 f"{peers.index(svc.self_uri)})" if peers else " (single)")
              + (", membership plane on"
                 if args.membership and k == 0 else ""),
              flush=True)
    # the client-side spec for this map ('|'-joined shard address sets)
    spec = SHARD_SEP.join(
        ",".join(peer_sets[k]) if peer_sets[k] else shard_addr(args.listen, k)
        for k in range(args.shards))
    print(f"registry spec: {spec}", flush=True)

    try:
        last_roles = {k: None for k in own}
        while True:
            time.sleep(2.0)
            for k, svc in zip(own, svcs):
                st = svc._status({})
                if st["role"] != last_roles[k]:
                    g = st.get("gossip", {})
                    print(f"[registry shard {k}] role={st['role']} "
                          f"leader={st['leader']} epoch={st['epoch']} "
                          f"instances={st['instances']} "
                          f"tables={ {n: t['entries'] for n, t in st['tables'].items()} } "
                          f"gossip(delta/snap)="
                          f"{g.get('delta_pushes', 0)}/"
                          f"{g.get('snapshot_pushes', 0)}", flush=True)
                    last_roles[k] = st["role"]
    except KeyboardInterrupt:
        pass
    finally:
        for svc in svcs:
            svc.close()
        for engine in engines:
            engine.shutdown()


if __name__ == "__main__":
    main()
