"""Registry node launcher — one replica of the fabric's control plane.

Every node of a quorum is started with the SAME ordered ``--peers`` list
(order is leadership priority; the lowest-ranked live replica holds the
leader lease) and its own entry as ``--listen``.  Clients — pools,
``ServiceInstance``s, ``--registry`` flags — are given the whole
comma-separated set and fail over between replicas on their own.

Each node hosts the **unified control plane**: the registry's instance
table and the membership service's member table ride the same leader
lease and delta-gossip stream (``mem.*`` is served by every node —
follower reads, writes proxied to the leaseholder), so member liveness
and expiry reaps survive leaseholder death.  ``--no-membership`` turns
the membership service off; ``--full-gossip`` falls back to full-state
snapshot gossip (the delta protocol is the default).

  # three-node quorum (run one per host):
  python -m repro.launch.registry --listen tcp://10.0.0.1:7700 \\
      --peers tcp://10.0.0.1:7700,tcp://10.0.0.2:7700,tcp://10.0.0.3:7700
  ...same command on 10.0.0.2 / 10.0.0.3 with their --listen...

  # single-node (development):
  python -m repro.launch.registry --listen tcp://127.0.0.1:7700

See docs/OPERATIONS.md for deployment guidance and DESIGN.md §8 for the
replication protocol.
"""
from __future__ import annotations

import argparse
import time

from repro.core.executor import Engine
from repro.fabric import RegistryService
from repro.telemetry import trace


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="fabric registry node (control plane replica)")
    ap.add_argument("--listen", required=True,
                    help="this node's address (set), e.g. tcp://0.0.0.0:7700")
    ap.add_argument("--peers", default=None, metavar="URI,URI,...",
                    help="ordered quorum peer list (identical on every "
                         "node; order = leadership priority).  Omit for a "
                         "single-node registry.")
    ap.add_argument("--self", dest="self_uri", default=None,
                    help="this node's entry in --peers when it differs "
                         "from the resolved --listen uri (e.g. listening "
                         "on 0.0.0.0 but advertised by host IP)")
    ap.add_argument("--instance-ttl", type=float, default=3.0,
                    help="seconds without a fab.report before an "
                         "instance is expired")
    ap.add_argument("--lease-ttl", type=float, default=1.0,
                    help="leader lease: seconds of gossip silence before "
                         "a peer is presumed dead")
    ap.add_argument("--gossip-interval", type=float, default=0.25,
                    help="seconds between gossip rounds")
    ap.add_argument("--membership", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="serve the membership plane (mem.*) from this "
                         "node's replicated member table; member "
                         "expiries reap bound instances (default: on)")
    ap.add_argument("--heartbeat-timeout", type=float, default=2.0,
                    help="seconds without a mem.heartbeat before a "
                         "member is expired")
    ap.add_argument("--full-gossip", action="store_true",
                    help="replicate with full-state snapshot gossip "
                         "instead of per-entry deltas (debug/fallback)")
    ap.add_argument("--trace-sample", type=float, default=None,
                    metavar="P",
                    help="head-sampling probability for distributed "
                         "traces rooted here (0..1; default honors "
                         "REPRO_TRACE_SAMPLE, falling back to 0.01). "
                         "Sampled spans are served via dbg.trace")
    args = ap.parse_args(argv)

    if args.trace_sample is not None:
        trace.configure(sample=args.trace_sample)

    engine = Engine(args.listen)
    peers = ([p.strip() for p in args.peers.split(",") if p.strip()]
             if args.peers else None)
    svc = RegistryService(
        engine, instance_ttl=args.instance_ttl, peers=peers,
        self_uri=args.self_uri, lease_ttl=args.lease_ttl,
        gossip_interval=args.gossip_interval,
        delta_gossip=not args.full_gossip,
        serve_membership=args.membership,
        heartbeat_timeout=args.heartbeat_timeout)
    print(f"registry node at {engine.uri}"
          + (f" (quorum of {len(peers)}, priority "
             f"{peers.index(svc.self_uri)})" if peers else " (single)")
          + (", membership plane on" if args.membership else ""),
          flush=True)
    try:
        last_role = None
        while True:
            time.sleep(2.0)
            st = svc._status({})
            if st["role"] != last_role:
                g = st.get("gossip", {})
                print(f"[registry] role={st['role']} "
                      f"leader={st['leader']} epoch={st['epoch']} "
                      f"instances={st['instances']} "
                      f"tables={ {n: t['entries'] for n, t in st['tables'].items()} } "
                      f"gossip(delta/snap)="
                      f"{g.get('delta_pushes', 0)}/"
                      f"{g.get('snapshot_pushes', 0)}", flush=True)
                last_role = st["role"]
    except KeyboardInterrupt:
        pass
    finally:
        svc.close()
        engine.shutdown()


if __name__ == "__main__":
    main()
