"""Training driver wired to the Mercury services.

Single-process topology (the multi-process topology is the same code with
tcp URIs — see examples/checkpoint_restart.py and the integration tests):
  * a checkpoint server engine (restore on start, async save every
    --ckpt-every steps),
  * a datafeed engine hosting the token pipeline,
  * a membership coordinator the trainer heartbeats to,
  * the jit'd train step from repro.train.step.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --reduced --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ParallelConfig
from repro.core.executor import Engine
from repro.data.pipeline import SyntheticSource
from repro.models import Model, unzip
from repro.services import (CheckpointClient, CheckpointServer,
                            DataFeedClient, DataFeedServer,
                            MembershipClient, MembershipServer)
from repro.train import optim
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-uri", default=None,
                    help="external checkpoint server URI (tcp://…)")
    args = ap.parse_args(argv)

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    model = Model(cfg)
    opt_cfg = optim.OptConfig(lr=args.lr, warmup=5, decay_steps=args.steps)
    par = ParallelConfig(microbatches=args.microbatches, remat="none")

    # --- services -----------------------------------------------------------
    trainer = Engine(None)                      # self plugin (in-process)
    if args.ckpt_uri:
        ckpt_server_uri = args.ckpt_uri
    else:
        ckpt_engine = Engine(None)
        CheckpointServer(ckpt_engine)
        ckpt_server_uri = ckpt_engine.uri
    ckpt = CheckpointClient(trainer, ckpt_server_uri)

    feed_engine = Engine(None)
    frontend = None
    if cfg.frontend != "none":
        frontend = (cfg.frontend_seq, cfg.frontend_dim)
    source = SyntheticSource(cfg.vocab, args.seq, args.batch,
                             frontend=frontend)
    DataFeedServer(feed_engine, source)
    feed = DataFeedClient(trainer, [feed_engine.uri], depth=2)

    coord = Engine(None)
    MembershipServer(coord)
    member = MembershipClient(trainer, coord.uri, "trainer-0")
    member.join({"role": "trainer"})

    # --- state --------------------------------------------------------------
    state, axes = __import__("repro.train.step", fromlist=["init_state"]) \
        .init_state(model, opt_cfg, jax.random.PRNGKey(0))
    start_step = 0
    if args.resume:
        try:
            state, start_step = ckpt.restore(cfg.name, state)
            print(f"resumed from step {start_step}")
        except Exception as e:
            print(f"no checkpoint to resume ({e}); starting fresh")

    step_fn = jax.jit(make_train_step(model, opt_cfg, par, mesh=None,
                                      impl="xla"))

    # --- loop ---------------------------------------------------------------
    t0 = time.monotonic()
    pending_save = None
    for step in range(start_step, start_step + args.steps):
        raw = feed.get(step)
        batch = {k: jnp.asarray(v) for k, v in raw.items()
                 if k in ("tokens", "targets", "frontend")}
        if cfg.family == "vlm":
            F = cfg.frontend_seq
            pad = np.full((batch["tokens"].shape[0], F), -1, np.int32)
            batch["targets"] = jnp.concatenate(
                [jnp.asarray(pad), batch["targets"]], axis=1)
        state, metrics = step_fn(state, batch)
        if (step + 1) % args.ckpt_every == 0 or step == start_step + args.steps - 1:
            if pending_save is not None:
                pending_save.result(timeout=120)
            host_state = jax.tree_util.tree_map(np.asarray, state)
            pending_save = ckpt.async_save(cfg.name, step + 1, host_state)
        print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.3f} "
              f"lr={float(metrics['lr']):.2e}")
    if pending_save is not None:
        print("final checkpoint:", pending_save.result(timeout=120))
    dt = time.monotonic() - t0
    toks = args.steps * args.batch * args.seq
    print(f"{args.steps} steps, {toks} tokens, {dt:.1f}s "
          f"({toks / dt:.0f} tok/s); checkpoints: {ckpt.list()}")
    member.leave()


if __name__ == "__main__":
    main()
