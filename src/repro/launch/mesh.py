"""Production mesh construction.

Kept as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``--xla_force_host_platform_device_count`` before any jax initialization.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Assignment mesh: single pod (16,16)=(data,model); two pods
    (2,16,16)=(pod,data,model) — 512 chips of TPU v5e."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever this host has (tests / reduced runs)."""
    n = len(jax.devices())
    data = max(n // model_axis, 1)
    return jax.make_mesh((data, model_axis), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes present on a mesh, in (pod, data) order."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


# TPU v5e hardware constants (roofline denominators)
HW = {
    "peak_flops_bf16": 197e12,      # per chip
    "hbm_bw": 819e9,                # bytes/s per chip
    "ici_link_bw": 50e9,            # bytes/s per link (~)
    "ici_links_per_ring": 2,        # bidirectional ring over one torus axis
    "hbm_bytes": 16 * 2 ** 30,      # 16 GB per chip
}
