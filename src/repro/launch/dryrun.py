import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Per cell this produces:
  * the PRODUCTION compile — scanned layers, remat, memory-efficient ops;
    its success is the deliverable ("the sharding is coherent"), and its
    ``memory_analysis()`` proves the per-device footprint;
  * two COST compiles — small *unrolled* depths (1 and 2 scan periods)
    with scan-free ops (impl="cost") — cost_analysis/collective bytes are
    linear in depth, so a 2-point fit extrapolates exact full-depth
    FLOPs/bytes/collective-bytes in seconds of compile time (XLA's
    cost_analysis counts a while body once, which would otherwise
    undercount scanned layers);
  * a JSON record under experiments/dryrun/ consumed by
    ``benchmarks.roofline`` / ``benchmarks.report``.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--no-cost]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro import configs
from repro.configs.base import ModelConfig, ParallelConfig, SHAPES, ShapeSpec
from repro.distrib import merge_rules, tree_shardings, tree_specs
from repro.distrib.sharding import DEFAULT_RULES, bytes_per_device
from repro.launch.mesh import HW, dp_axes, make_production_mesh
from repro.models import Model, unzip
from repro.models.moe import padded_experts
from repro.train import optim
from repro.train.step import make_train_step

OUT_DIR = Path("experiments/dryrun")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
               "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
               "c128": 16}

COLL_RE = re.compile(
    r"=\s*(\(?.*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
GROUPS_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(txt):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> list:
    """Per-device collective records from optimized HLO text."""
    out = []
    for line in hlo.splitlines():
        line = line.strip()
        m = COLL_RE.search(line)
        if not m:
            continue
        result_txt, kind, variant = m.group(1), m.group(2), m.group(3)
        if variant == "-done":
            continue            # counted at -start
        rbytes = _shape_bytes(result_txt)
        group = 1
        gm = GROUPS_ITOA_RE.search(line)
        if gm:
            group = int(gm.group(2))
        else:
            gm = GROUPS_LIST_RE.search(line)
            if gm:
                group = len([x for x in gm.group(1).split(",") if x.strip()])
        out.append({"op": kind, "result_bytes": rbytes, "group": group})
    return out


def wire_bytes(rec: dict) -> float:
    """Per-device ICI wire traffic of one collective (ring algorithms)."""
    n = max(rec["group"], 1)
    r = rec["result_bytes"]
    if n == 1:
        return 0.0
    if rec["op"] == "all-reduce":
        return 2.0 * r * (n - 1) / n
    if rec["op"] == "all-gather":
        return r * (n - 1) / n            # result is the gathered buffer
    if rec["op"] == "reduce-scatter":
        return r * (n - 1)                 # operand = result * n
    if rec["op"] == "all-to-all":
        return r * (n - 1) / n
    if rec["op"] == "collective-permute":
        return float(r)
    return float(r)


# ===========================================================================
# per-cell builders
# ===========================================================================
def batch_specs(cfg: ModelConfig, shape: ShapeSpec, kind: str):
    """ShapeDtypeStruct stand-ins for every model input."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if kind == "train":
        F = cfg.frontend_seq if cfg.family == "vlm" else 0
        b = {"tokens": sds((B, S - F), jnp.int32),
             "targets": sds((B, S), jnp.int32)}
        if cfg.frontend != "none":
            b["frontend"] = sds((B, cfg.frontend_seq, cfg.frontend_dim),
                                jnp.float32)
            if cfg.family != "vlm":
                b["targets"] = sds((B, S), jnp.int32)
        return b
    if kind == "prefill":
        F = cfg.frontend_seq if cfg.family == "vlm" else 0
        b = {"tokens": sds((B, S - F), jnp.int32)}
        if cfg.frontend != "none":
            b["frontend"] = sds((B, cfg.frontend_seq, cfg.frontend_dim),
                                jnp.float32)
        return b
    # decode: one new token against a cache of S
    return {"tokens": sds((B, 1), jnp.int32)}


def batch_shardings(specs, mesh, dp_over=None):
    dp = dp_over or dp_axes(mesh)

    def sh(sds):
        dims = [dp if (sds.shape and sds.shape[0] %
                       int(np.prod([mesh.shape[a] for a in dp])) == 0)
                else None]
        dims += [None] * (len(sds.shape) - 1)
        return NamedSharding(mesh, PS(*dims))

    return jax.tree_util.tree_map(sh, specs)


def cell_rules(shape: ShapeSpec) -> dict:
    if shape.name == "long_500k":
        # batch=1: spread the KV sequence over (data, model) = 256-way
        return {"kv_seq": ("data", "model")}
    return {}


def opt_for(cfg: ModelConfig) -> optim.OptConfig:
    if cfg.name == "nemotron-4-340b":
        return optim.OptConfig(state_dtype="bfloat16")
    return optim.OptConfig()


def par_for(cfg: ModelConfig, mesh, shape: ShapeSpec) -> ParallelConfig:
    return ParallelConfig(
        pod_axis="pod" if "pod" in mesh.shape else None,
        microbatches=1,
        remat="block",
    )


def act_sharding_for(cfg: ModelConfig, mesh, shape: ShapeSpec):
    """Residual-stream constraint at block boundaries.  Batch over the DP
    axes always (GSPMD left alone picks pathological layouts); wide dense
    models additionally shard the sequence over ``model``
    (Korthikanti-style SP: saved block inputs shrink by 1/TP)."""
    dp = dp_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    ndp = int(np.prod([mesh.shape[a] for a in dp]))
    if B % ndp:
        return None
    wide = cfg.d_model >= 3840 and not cfg.moe.num_experts
    if wide and shape.kind == "train" and S % mesh.shape["model"] == 0:
        return NamedSharding(mesh, PS(dp, "model", None))
    return NamedSharding(mesh, PS(dp, None, None))


def logits_sharding_for(cfg: ModelConfig, mesh, shape: ShapeSpec):
    dp = dp_axes(mesh)
    B = shape.global_batch
    ndp = int(np.prod([mesh.shape[a] for a in dp]))
    if B % ndp:
        return None
    return NamedSharding(mesh, PS(dp, None, "model"))


def abstract_state(model: Model, opt_cfg: optim.OptConfig):
    def build(rng):
        params_p = model.init(rng)
        opt_p = optim.adamw_init(params_p)
        if opt_cfg.state_dtype != "float32":
            opt_p = optim.cast_state(opt_p, opt_cfg.state_dtype)
        return {"params": params_p, "opt": opt_p}
    tree_p = jax.eval_shape(build, jax.random.PRNGKey(0))
    return unzip(tree_p)


def abstract_params(model: Model):
    tree_p = jax.eval_shape(lambda r: model.init(r), jax.random.PRNGKey(0))
    return unzip(tree_p)


def abstract_cache(model: Model, batch: int, seq: int):
    tree_p = jax.eval_shape(
        lambda: model.cache_specs(batch, seq, jnp.bfloat16))
    return unzip(tree_p)


# ===========================================================================
# lower+compile one cell
# ===========================================================================
def lower_cell(arch: str, shape_name: str, mesh, *, unroll_periods: int = 0,
               impl: str = "xla", remat: str = "block",
               overrides: Optional[dict] = None):
    """Build and lower one cell. unroll_periods>0 → cost-mode variant with
    that many unrolled periods. ``overrides`` (hillclimb variants):
      moe_dispatch: "cumsum"       — sort-free MoE dispatch
      param_gather: "bfloat16"     — cast params before use (16-bit FSDP
                                     gathers / grad reduces)
      flat_dp: True                — no TP: both mesh axes are data
                                     parallel, params FSDP over all chips
    Returns (lowered, meta)."""
    import dataclasses as _dc
    overrides = overrides or {}
    cfg = configs.get(arch)
    if overrides.get("moe_dispatch") and cfg.moe.num_experts:
        cfg = cfg.replace(moe=_dc.replace(cfg.moe,
                                          dispatch=overrides["moe_dispatch"]))
    shape = SHAPES[shape_name]
    e_pad = padded_experts(cfg, mesh.shape["model"]) \
        if cfg.moe.num_experts else None

    if unroll_periods > 0:
        plen = len(cfg.period)
        prefix = 1 if (cfg.moe.first_layer_dense and cfg.moe.num_experts) \
            else 0
        trail = cfg.n_layers % plen if plen > 1 else 0
        n_layers = prefix + unroll_periods * plen + trail
        cfg_v = cfg.replace(n_layers=n_layers)
        model = Model(cfg_v, e_pad=e_pad, unroll=True)
        remat = "none"
    else:
        cfg_v = cfg
        model = Model(cfg_v, e_pad=e_pad)

    par = par_for(cfg_v, mesh, shape)
    opt_cfg = opt_for(cfg)
    rules = cell_rules(shape)
    dp_all = tuple(dp_axes(mesh)) + ("model",)
    if overrides.get("flat_dp"):
        rules.update({"heads": (), "kv_heads": (), "mlp": (), "vocab": (),
                      "experts": (), "inner": (), "lru": (),
                      "ssm_heads": (), "embed": dp_all, "batch": dp_all})
    pg_dtype = overrides.get("param_gather")

    def cast_params(params):
        if not pg_dtype:
            return params
        dt = jnp.dtype(pg_dtype)
        return jax.tree_util.tree_map(
            lambda x: x.astype(dt) if x.dtype == jnp.float32 else x, params)
    specs_b = batch_specs(cfg_v, shape, shape.kind)
    b_sh = batch_shardings(specs_b, mesh,
                           dp_over=dp_all if overrides.get("flat_dp")
                           else None)
    meta: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                            "kind": shape.kind,
                            "n_layers": cfg_v.n_layers}

    if shape.kind == "train":
        state_sds, state_axes = abstract_state(model, opt_cfg)
        st_sh = tree_shardings(state_sds, state_axes, mesh, rules)
        if overrides.get("flat_dp"):
            act_sh = NamedSharding(mesh, PS(dp_all, None, None))
        else:
            act_sh = act_sharding_for(cfg_v, mesh, shape)
        step_fn = make_train_step(model, opt_cfg, par, mesh, impl=impl)

        def train_step(state, batch):
            # thread act_sharding / ce_chunk via a wrapper loss
            return step_fn(state, batch)

        # rebuild step with act_sharding by overriding model.loss_fn call
        from repro.models.moe import MoESpmd
        from repro.train.step import make_moe_spmd
        spmd = make_moe_spmd(cfg_v, par, mesh)
        ce_chunk = shape.seq_len if impl == "cost" else 512

        if overrides.get("flat_dp"):
            logits_sh = NamedSharding(mesh, PS(dp_all, None, None))
            if cfg_v.moe.num_experts:
                from repro.models.moe import MoESpmd
                spmd = MoESpmd(mesh=mesh, token_axes=dp_all,
                               expert_axis=None)
            else:
                spmd = None
        else:
            logits_sh = logits_sharding_for(cfg_v, mesh, shape)

        inner_sh = None
        if overrides.get("gather_once") and act_sh is not None:
            # one explicit SP gather per block: post-norm activations go
            # to (dp-batch, full-seq) exactly once for both branches
            inner_sh = NamedSharding(mesh, PS(dp_axes(mesh), None, None))

        def loss_of(params, b):
            return model.loss_fn(cast_params(params), b, spmd=spmd,
                                 impl=impl, remat=remat,
                                 act_sharding=act_sh,
                                 logits_sharding=logits_sh,
                                 inner_sharding=inner_sh,
                                 ce_chunk=ce_chunk)

        grad_fn = jax.value_and_grad(loss_of, has_aux=True)
        n_micro = int(overrides.get("microbatches", 1))

        def full_step(state, batch):
            if n_micro > 1:
                micro = jax.tree_util.tree_map(
                    lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                        + x.shape[1:]), batch)

                def acc(carry, mb):
                    g_acc, l_acc = carry
                    (l, m), g = grad_fn(state["params"], mb)
                    return (jax.tree_util.tree_map(jnp.add, g_acc, g),
                            l_acc + l), m

                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])
                (grads, loss), ms = jax.lax.scan(
                    acc, (g0, jnp.float32(0)), micro)
                grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
                loss = loss / n_micro
                metrics = jax.tree_util.tree_map(lambda x: x[-1], ms)
            else:
                (loss, metrics), grads = grad_fn(state["params"], batch)
            opt = state["opt"]
            new_params, m_new, v_new, count, stats = optim.adamw_update(
                opt_cfg, state["params"], grads, opt["m"], opt["v"],
                opt["count"])
            metrics = dict(metrics); metrics.update(stats)
            return ({"params": new_params,
                     "opt": {"m": m_new, "v": v_new, "count": count}},
                    metrics)

        lowered = jax.jit(
            full_step,
            in_shardings=(st_sh, b_sh),
            out_shardings=(st_sh, None),
        ).lower(state_sds, specs_b)
        meta["state_bytes_analytic"] = bytes_per_device(
            state_sds, state_axes, mesh, rules)
        return lowered, meta

    params_sds, params_axes = abstract_params(model)
    p_sh = tree_shardings(params_sds, params_axes, mesh, rules)

    if shape.kind == "prefill":
        act_sh = act_sharding_for(cfg_v, mesh, shape)

        def prefill_fn(params, batch):
            return model.prefill(params, batch, cache_len=shape.seq_len,
                                 impl=impl, capacity_factor=2.0,
                                 act_sharding=act_sh)

        lowered = jax.jit(
            prefill_fn, in_shardings=(p_sh, b_sh),
        ).lower(params_sds, specs_b)
        meta["state_bytes_analytic"] = bytes_per_device(
            params_sds, params_axes, mesh, rules)
        return lowered, meta

    # decode
    cache_sds, cache_axes = abstract_cache(model, shape.global_batch,
                                           shape.seq_len)
    c_sh = tree_shardings(cache_sds, cache_axes, mesh, rules)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    def decode_fn(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, impl=impl)

    lowered = jax.jit(
        decode_fn,
        in_shardings=(p_sh, c_sh, b_sh["tokens"], None),
        out_shardings=(None, c_sh),
    ).lower(params_sds, cache_sds, specs_b["tokens"], pos_sds)
    meta["state_bytes_analytic"] = bytes_per_device(
        params_sds, params_axes, mesh, rules)
    meta["cache_bytes_analytic"] = bytes_per_device(
        cache_sds, cache_axes, mesh, rules)
    return lowered, meta


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             with_cost: bool = True, verbose: bool = True,
             overrides: Optional[dict] = None,
             variant: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "ok": False}
    if variant:
        rec["variant"] = variant
        rec["overrides"] = {k: str(v) for k, v in (overrides or {}).items()}
    t0 = time.monotonic()
    with mesh:
        lowered, meta = lower_cell(arch, shape_name, mesh,
                                   overrides=overrides)
        rec.update(meta)
        compiled = lowered.compile()
        rec["compile_s"] = round(time.monotonic() - t0, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)}
        rec["ok"] = True

        if with_cost and mesh_kind == "single":
            fits = {}
            for k in (1, 2):
                tl, _ = lower_cell(arch, shape_name, mesh,
                                   unroll_periods=k, impl="cost",
                                   overrides=overrides)
                tc = tl.compile()
                ca = tc.cost_analysis()
                colls = parse_collectives(tc.as_text())
                fits[k] = {
                    "flops": float(ca.get("flops", 0.0)),
                    "bytes": float(ca.get("bytes accessed", 0.0)),
                    "coll_wire": sum(wire_bytes(c) for c in colls),
                    "colls": colls,
                }
            cfg = configs.get(arch)
            plen = len(cfg.period)
            prefix = 1 if (cfg.moe.first_layer_dense
                           and cfg.moe.num_experts) else 0
            n_periods = (cfg.n_layers - prefix) // plen
            full = {}
            for key in ("flops", "bytes", "coll_wire"):
                b = fits[2][key] - fits[1][key]       # per period
                a = fits[1][key] - b                  # fixed part
                full[key] = a + b * n_periods
                full[key + "_per_period"] = b
                full[key + "_fixed"] = a
            rec["cost_fit"] = full
            rec["cost_points"] = {k: {kk: v[kk] for kk in
                                      ("flops", "bytes", "coll_wire")}
                                  for k, v in fits.items()}
            # collective mix at depth 2 (for the report's dominant-op line)
            mix: Dict[str, float] = {}
            for c in fits[2]["colls"]:
                mix[c["op"]] = mix.get(c["op"], 0.0) + wire_bytes(c)
            rec["coll_mix_k2"] = mix
    if verbose:
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "ok", "compile_s")}))
    return rec


def save_rec(rec: dict):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    suffix = f"__{rec['variant']}" if rec.get("variant") else ""
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{suffix}.json"
    (OUT_DIR / name).write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--variant", default="",
                    help="hillclimb variant: comma list of "
                         "moe_dispatch=cumsum, param_gather=bfloat16, "
                         "flat_dp")
    args = ap.parse_args()
    overrides = {}
    for item in args.variant.split(","):
        if not item:
            continue
        if "=" in item:
            k, v = item.split("=", 1)
            overrides[k] = v
        else:
            overrides[item] = True

    if args.all:
        cells = configs.all_cells()
    else:
        shapes = [args.shape] if args.shape else \
            list(configs.shapes_for(args.arch))
        cells = [(args.arch, s) for s in shapes]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch, shape in cells:
        for mk in meshes:
            suffix = f"__{args.variant.replace(',', '+').replace('=', '-')}" \
                if args.variant else ""
            out = OUT_DIR / f"{arch}_{shape}_{mk}{suffix}.json"
            if args.skip_done and out.exists() and \
                    json.loads(out.read_text()).get("ok"):
                continue
            try:
                rec = run_cell(arch, shape, mk,
                               with_cost=not args.no_cost,
                               overrides=overrides or None,
                               variant=args.variant.replace(",", "+")
                               .replace("=", "-"))
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "mesh": mk,
                       "ok": False, "error": f"{type(e).__name__}: {e}"}
                failures.append((arch, shape, mk))
            save_rec(rec)
    if failures:
        print("FAILED CELLS:", failures)
        raise SystemExit(1)
    print("all requested cells OK")


if __name__ == "__main__":
    main()
