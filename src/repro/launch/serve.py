"""Serving driver: model server behind the Mercury gateway + demo client.

Starts a ServeEngine for the chosen arch (reduced config by default),
exposes it through the ServingGateway over the tcp NA plugin, and — in
--demo mode — runs a client engine that submits a few batched prompts and
prints the completions.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced --demo
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --listen tcp://0.0.0.0:7777        # stay up as a server
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core.executor import Engine
from repro.models import Model, unzip
from repro.serve.engine import ServeEngine
from repro.services import ServingGateway
from repro.telemetry import trace


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--listen", default="tcp://127.0.0.1:0")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--registry", default=None, metavar="URI[,URI...]",
                    help="fabric registry to self-register with (service "
                         "'gen'): replicas started this way are routable "
                         "through a ServicePool.  For a replicated "
                         "registry pass the whole comma-separated quorum "
                         "address set; registration and heartbeats fail "
                         "over between the replicas (DESIGN.md §8)")
    ap.add_argument("--service", default="gen",
                    help="service name to register under (with --registry)")
    ap.add_argument("--member-id", default=None,
                    help="join the control plane's membership service "
                         "(mem.*, served by the same registry quorum) "
                         "under this id and bind the registration to "
                         "it: if this node dies, member expiry reaps "
                         "the instance without waiting for the "
                         "instance TTL (requires the registry to run "
                         "with its membership plane on — the default)")
    ap.add_argument("--trace-sample", type=float, default=None,
                    metavar="P",
                    help="head-sampling probability for distributed "
                         "traces rooted here (0..1; default honors "
                         "REPRO_TRACE_SAMPLE, falling back to 0.01). "
                         "Sampled spans are served via dbg.trace")
    args = ap.parse_args(argv)

    if args.trace_sample is not None:
        trace.configure(sample=args.trace_sample)

    cfg = configs.reduced(args.arch) if args.reduced else configs.get(args.arch)
    model = Model(cfg)
    params, _ = unzip(model.init(jax.random.PRNGKey(0)))
    serve = ServeEngine(model, params, max_len=args.max_len,
                        n_slots=args.slots)

    server = Engine(args.listen)
    gw = ServingGateway(server, serve, registry=args.registry,
                        service=args.service, member_id=args.member_id)
    print(f"serving {cfg.name} at {server.uri} "
          f"({args.slots} slots, max_len {args.max_len})"
          + (f", registered with {args.registry} as {args.service!r}"
             if args.registry else "")
          + (f", member {args.member_id!r}" if args.member_id else ""))

    if args.demo:
        rng = np.random.default_rng(0)
        with Engine("tcp://127.0.0.1:0") as client:
            t0 = time.monotonic()
            rids = []
            for i in range(6):
                prompt = rng.integers(1, cfg.vocab, size=5 + i).tolist()
                rids.append(client.call(server.uri, "gen.submit",
                                        {"tokens": prompt, "max_new": 12,
                                         "temperature": 0.7}))
            for r in rids:
                out = client.call(server.uri, "gen.result",
                                  {"rid": r["rid"], "wait": True},
                                  timeout=120.0)
                print(f"rid {r['rid']}: {out['tokens']}")
            print("stats:", client.call(server.uri, "gen.stats", {}),
                  f"({time.monotonic() - t0:.1f}s)")
        gw.stop()
        server.shutdown()
    else:
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            gw.stop()
            server.shutdown()


if __name__ == "__main__":
    main()
