"""Wire-propagated distributed tracing (DESIGN.md §10).

A *trace* follows one logical operation across every hop of the fabric:
client attempts (retries, hedges), registry write-proxy hops, gateway
queue/decode, nested service calls.  The context that rides the wire is
deliberately tiny — 16-byte trace id, 8-byte span id, 1 flag byte — and
is carried in the v5 :class:`~repro.core.types.RequestHeader`; the
self-tier local-dispatch fast path hands the context object across
directly (no serialization, matching the data path it instruments).

Head sampling: the root decides once (``configure(sample=...)``) and the
decision propagates via the SAMPLED flag.  Unsampled traces still carry
their ids downstream (so a future tail-sampler could act on them) but
record *nothing* — span objects on that path are no-ops, which is what
keeps the unsampled overhead near zero (asserted ≤5% of routed-pool RTT
by the ``trace_overhead`` benchmark).

Finished spans land in a bounded per-process ring buffer served by the
``dbg.trace`` RPC that every :class:`~repro.core.executor.Engine`
exposes; a client reassembles the cross-process span tree by unioning
``dbg.trace`` responses and joining on parent span ids (clocks are never
compared across processes).
"""
from __future__ import annotations

import os
import random
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

FLAG_SAMPLED = 0x01
ZERO_TRACE_ID = b"\x00" * 16


class TraceContext:
    """The immutable triplet that rides the wire."""

    __slots__ = ("trace_id", "span_id", "flags")

    def __init__(self, trace_id: bytes, span_id: int, flags: int = 0):
        self.trace_id = trace_id
        self.span_id = span_id
        self.flags = flags

    @property
    def sampled(self) -> bool:
        return bool(self.flags & FLAG_SAMPLED)

    @property
    def trace_hex(self) -> str:
        return self.trace_id.hex()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"TraceContext({self.trace_id.hex()}, "
                f"{self.span_id:016x}, flags={self.flags})")


# -- ambient (thread-local) context -----------------------------------------
_tls = threading.local()


def current() -> Optional[TraceContext]:
    """The context active on this thread (None when untraced)."""
    return getattr(_tls, "ctx", None)


def activate(ctx: Optional[TraceContext]) -> Optional[TraceContext]:
    """Install ``ctx`` as the ambient context; returns the previous one
    (pass it back to :func:`restore`).  Installing ``None`` explicitly
    clears stale context — handler pools rely on this."""
    prev = getattr(_tls, "ctx", None)
    _tls.ctx = ctx
    return prev


def restore(prev: Optional[TraceContext]) -> None:
    _tls.ctx = prev


class use:
    """``with trace.use(ctx): ...`` — scoped ambient context."""

    __slots__ = ("_ctx", "_prev")

    def __init__(self, ctx: Optional[TraceContext]):
        self._ctx = ctx

    def __enter__(self) -> Optional[TraceContext]:
        self._prev = activate(self._ctx)
        return self._ctx

    def __exit__(self, *exc) -> None:
        restore(self._prev)


# -- tracer state ------------------------------------------------------------
class _Tracer:
    def __init__(self) -> None:
        self.enabled = True
        self.sample = float(os.environ.get("REPRO_TRACE_SAMPLE", "0.01"))
        self.ring: deque = deque(
            maxlen=int(os.environ.get("REPRO_TRACE_RING", "4096")))
        # module-owned RNG: cheap (no urandom syscall per id) and isolated
        # from user seeding of the global random module
        self.rng = random.Random(os.urandom(16))


_T = _Tracer()


def configure(sample: Optional[float] = None, ring: Optional[int] = None,
              enabled: Optional[bool] = None) -> None:
    """Adjust the process-global tracer.

    ``sample`` — head-sampling probability in [0, 1] applied where a
    trace is *rooted* (downstream hops obey the propagated flag).
    ``ring`` — span ring-buffer capacity.  ``enabled=False`` turns the
    machinery off entirely (no context is even created)."""
    if sample is not None:
        _T.sample = max(0.0, min(1.0, float(sample)))
    if ring is not None:
        _T.ring = deque(_T.ring, maxlen=max(1, int(ring)))
    if enabled is not None:
        _T.enabled = bool(enabled)


def sample_rate() -> float:
    return _T.sample


def is_enabled() -> bool:
    return _T.enabled


def clear() -> None:
    """Drop all buffered spans (tests / benchmarks)."""
    _T.ring.clear()


def _new_span_id() -> int:
    return _T.rng.getrandbits(64) or 1


# -- spans -------------------------------------------------------------------
class Span:
    """A timed unit of work.  ``recorded=False`` spans are pass-through:
    they carry a context for propagation but never touch the clock or the
    ring (the near-zero unsampled path)."""

    __slots__ = ("ctx", "name", "parent_id", "recorded", "tags",
                 "_t0", "_wall", "_done")

    def __init__(self, ctx: TraceContext, name: str, parent_id: int,
                 recorded: bool, tags: Optional[Dict[str, Any]] = None):
        self.ctx = ctx
        self.name = name
        self.parent_id = parent_id
        self.recorded = recorded
        self.tags = tags if tags is not None else ({} if recorded else None)
        self._done = False
        if recorded:
            self._t0 = time.monotonic()
            self._wall = time.time()
        else:
            self._t0 = 0.0
            self._wall = 0.0

    def annotate(self, **tags: Any) -> None:
        if self.recorded:
            self.tags.update(tags)

    def finish(self, status: str = "OK", **tags: Any) -> None:
        if not self.recorded or self._done:
            return
        self._done = True
        if tags:
            self.tags.update(tags)
        _T.ring.append({
            "trace": self.ctx.trace_id.hex(),
            "span": f"{self.ctx.span_id:016x}",
            "parent": f"{self.parent_id:016x}" if self.parent_id else None,
            "name": self.name,
            "pid": os.getpid(),
            "wall": self._wall,
            "dur_ms": round((time.monotonic() - self._t0) * 1e3, 3),
            "status": status,
            "tags": self.tags,
        })


class _NullSpan:
    """Singleton no-op span: no context, records nothing."""

    __slots__ = ()
    ctx: Optional[TraceContext] = None
    recorded = False

    def annotate(self, **tags: Any) -> None:
        pass

    def finish(self, status: str = "OK", **tags: Any) -> None:
        pass


NULL_SPAN = _NullSpan()


def start_trace(name: str, **tags: Any):
    """Root a new trace (head-sampling decision happens here).  Returns a
    recorded :class:`Span` when sampled, an unrecorded pass-through span
    (context still propagates) when not, and :data:`NULL_SPAN` when
    tracing is disabled."""
    t = _T
    if not t.enabled:
        return NULL_SPAN
    s = t.sample
    sampled = s >= 1.0 or (s > 0.0 and t.rng.random() < s)
    ctx = TraceContext(t.rng.getrandbits(128).to_bytes(16, "little"),
                       _new_span_id(), FLAG_SAMPLED if sampled else 0)
    return Span(ctx, name, 0, sampled, dict(tags) if (tags and sampled) else None)


def start_span(name: str, parent: Optional[TraceContext], **tags: Any):
    """Open a child span under ``parent``.  ``parent=None`` (or tracing
    disabled) → :data:`NULL_SPAN`; unsampled parent → pass-through span
    reusing the parent context (ids keep propagating, nothing recorded)."""
    if parent is None or not _T.enabled:
        return NULL_SPAN
    if not (parent.flags & FLAG_SAMPLED):
        return Span(parent, name, parent.span_id, False)
    ctx = TraceContext(parent.trace_id, _new_span_id(), parent.flags)
    return Span(ctx, name, parent.span_id, True,
                dict(tags) if tags else None)


# -- ring export / reassembly ------------------------------------------------
def export(trace_id: Optional[str] = None,
           limit: Optional[int] = None) -> Dict[str, Any]:
    """Snapshot of the span ring — the ``dbg.trace`` response body.
    ``trace_id`` (hex) filters to one trace; ``limit`` keeps the newest N."""
    spans = list(_T.ring)
    if trace_id:
        spans = [s for s in spans if s["trace"] == trace_id]
    if limit:
        spans = spans[-int(limit):]
    return {"pid": os.getpid(), "spans": spans}


def spans_for(trace_id: str) -> List[Dict[str, Any]]:
    return [s for s in _T.ring if s["trace"] == trace_id]


def build_tree(spans: List[Dict[str, Any]]
               ) -> Tuple[List[Dict[str, Any]], Dict[str, List[Dict[str, Any]]]]:
    """Join spans on parent ids: returns ``(roots, children_by_span_id)``.
    A span whose parent is absent from the set counts as a root — one
    *connected* tree therefore means exactly one root."""
    seen = {}
    for s in spans:
        seen.setdefault(s["span"], s)          # union of rings may duplicate
    uniq = list(seen.values())
    children: Dict[str, List[Dict[str, Any]]] = {}
    roots = []
    for s in sorted(uniq, key=lambda s: s.get("wall", 0.0)):
        p = s.get("parent")
        if p and p in seen:
            children.setdefault(p, []).append(s)
        else:
            roots.append(s)
    return roots, children


def format_tree(spans: List[Dict[str, Any]]) -> str:
    """Pretty-print a span tree (one trace) for consoles and examples."""
    roots, children = build_tree(spans)
    lines: List[str] = []

    def walk(s: Dict[str, Any], depth: int) -> None:
        tags = s.get("tags") or {}
        tag_str = " ".join(f"{k}={v}" for k, v in sorted(tags.items()))
        lines.append(f"{'  ' * depth}{s['name']}  "
                     f"[{s['status']} {s['dur_ms']:.2f}ms pid={s['pid']}]"
                     + (f"  {tag_str}" if tag_str else ""))
        for c in children.get(s["span"], []):
            walk(c, depth + 1)

    for r in roots:
        walk(r, 0)
    return "\n".join(lines)
