"""Fabric telemetry plane (DESIGN.md §10, docs/OPERATIONS.md §7).

Two halves, both process-global and dependency-free:

* :mod:`repro.telemetry.trace` — wire-propagated distributed tracing:
  a 16-byte trace id + span id + flags carried in the v5 request
  header, head-sampled at the root, recorded into a bounded ring
  buffer served by the ``dbg.trace`` RPC.
* :mod:`repro.telemetry.metrics` — the unified metrics registry
  (counters / gauges / log-bucket histograms) that the fabric's
  components report through, exported by the ``fab.metrics`` RPC and
  rendered live by ``tools/fabtop.py``.
"""
from . import metrics, trace
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, REGISTRY,
                      counter, gauge, histogram, snapshot)
from .trace import (FLAG_SAMPLED, NULL_SPAN, Span, TraceContext,
                    ZERO_TRACE_ID, build_tree, configure, current,
                    format_tree, start_span, start_trace, use)

__all__ = [
    "metrics", "trace",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY",
    "counter", "gauge", "histogram", "snapshot",
    "FLAG_SAMPLED", "NULL_SPAN", "Span", "TraceContext", "ZERO_TRACE_ID",
    "build_tree", "configure", "current", "format_tree", "start_span",
    "start_trace", "use",
]
