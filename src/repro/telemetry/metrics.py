"""Process-global metrics registry: counters, gauges, log-bucket
histograms.

One registry per process (module-level :data:`REGISTRY`); fabric
components create named instruments at import/construction time and the
``fab.metrics`` RPC (registered by every Engine) serves one uniform
snapshot.  This supersedes the ad-hoc per-component ``stats()`` dicts —
those remain as *views* for callers that hold the object, but the wire
export is the registry.

Instruments are keyed ``name{label=value,...}``; labels are optional and
should stay low-cardinality (service names, not request ids).
Histograms bucket by powers of two of the observed value (milliseconds
by convention, suffix the name ``_ms``), which keeps the export tiny at
any volume.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Callable, Dict, Optional


def _key(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic counter."""

    __slots__ = ("key", "_v", "_lock")

    def __init__(self, key: str):
        self.key = key
        self._v = 0  #: guarded-by _lock
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._v


class Gauge:
    """Last-written value, or a live callback."""

    __slots__ = ("key", "_v", "_fn")

    def __init__(self, key: str, fn: Optional[Callable[[], float]] = None):
        self.key = key
        self._v = 0.0
        self._fn = fn

    def set(self, v: float) -> None:
        self._v = float(v)

    @property
    def value(self) -> float:
        if self._fn is not None:
            try:
                return float(self._fn())
            except Exception:
                return self._v
        return self._v


class Histogram:
    """Log2-bucketed histogram: bucket k counts observations in
    ``(2^(k-1), 2^k]`` (bucket 0 holds v ≤ 1)."""

    __slots__ = ("key", "_lock", "count", "sum", "max", "buckets")

    def __init__(self, key: str):
        self.key = key
        self._lock = threading.Lock()
        self.count = 0  #: guarded-by _lock
        self.sum = 0.0  #: guarded-by _lock
        self.max = 0.0  #: guarded-by _lock
        self.buckets: Dict[int, int] = {}  #: guarded-by _lock

    def observe(self, v: float) -> None:
        v = float(v)
        k = 0 if v <= 1.0 else math.ceil(math.log2(v))
        with self._lock:
            self.count += 1
            self.sum += v
            if v > self.max:
                self.max = v
            self.buckets[k] = self.buckets.get(k, 0) + 1

    def quantile(self, q: float) -> float:
        """Upper bucket bound at quantile ``q`` (coarse by design)."""
        with self._lock:
            if not self.count:
                return 0.0
            target = q * self.count
            acc = 0
            for k in sorted(self.buckets):
                acc += self.buckets[k]
                if acc >= target:
                    return float(2 ** k)
            return float(2 ** max(self.buckets))

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "count": self.count,
                "sum": round(self.sum, 3),
                "avg": round(self.sum / self.count, 3) if self.count else 0.0,
                "max": round(self.max, 3),
                "buckets": {f"le_{2 ** k}": n
                            for k, n in sorted(self.buckets.items())},
            }


class MetricsRegistry:
    """Name → instrument table.  Instrument getters are idempotent: the
    same key always returns the same object, so module-level and
    per-instance callers share one counter."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}  #: guarded-by _lock
        self._gauges: Dict[str, Gauge] = {}  #: guarded-by _lock
        self._histograms: Dict[str, Histogram] = {}  #: guarded-by _lock

    def counter(self, name: str, **labels: Any) -> Counter:
        key = _key(name, labels)
        with self._lock:
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter(key)
            return c

    def gauge(self, name: str, fn: Optional[Callable[[], float]] = None,
              **labels: Any) -> Gauge:
        key = _key(name, labels)
        with self._lock:
            g = self._gauges.get(key)
            if g is None or fn is not None:
                g = self._gauges[key] = Gauge(key, fn)
            return g

    def histogram(self, name: str, **labels: Any) -> Histogram:
        key = _key(name, labels)
        with self._lock:
            h = self._histograms.get(key)
            if h is None:
                h = self._histograms[key] = Histogram(key)
            return h

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: round(g.value, 4) for k, g in sorted(gauges.items())},
            "histograms": {k: h.snapshot() for k, h in sorted(hists.items())},
        }


REGISTRY = MetricsRegistry()

# module-level conveniences bound to the process-global registry
counter = REGISTRY.counter
gauge = REGISTRY.gauge
histogram = REGISTRY.histogram
snapshot = REGISTRY.snapshot
