"""Train-step factory: microbatch gradient accumulation (scan), remat,
MoE SPMD wiring, optimizer update — one jit-able pure function.

State layout (plain value pytree, shardable with distrib.tree_shardings):
  {"params": …, "opt": {"m": …, "v": …} | {"f": …}, "count": i32}
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ParallelConfig
from ..models import Model, unzip
from ..models.common import P, is_p
from ..models.moe import MoESpmd
from . import optim


def make_moe_spmd(cfg: ModelConfig, par: ParallelConfig, mesh):
    if mesh is None or not cfg.moe.num_experts:
        return None
    if mesh.shape.get(par.tensor_axis, 1) <= 1:
        return None
    token_axes = tuple(a for a in (par.pod_axis, par.fsdp_axis)
                       if a and a in mesh.shape)
    return MoESpmd(mesh=mesh, token_axes=token_axes,
                   expert_axis=par.tensor_axis)


def init_state(model: Model, opt_cfg: optim.OptConfig, rng):
    """Returns (state value-tree, axes tree) — P-trees unzipped."""
    params_p = model.init(rng)
    if opt_cfg.name == "adafactor":
        opt_p = optim.adafactor_init(params_p)
    else:
        opt_p = optim.adamw_init(params_p)
        if opt_cfg.state_dtype != "float32":
            opt_p = optim.cast_state(opt_p, opt_cfg.state_dtype)
    state_p = {"params": params_p, "opt": opt_p}
    values, axes = unzip(state_p)
    return values, axes


def state_specs(model: Model, opt_cfg: optim.OptConfig):
    """Abstract state (ShapeDtypeStructs) + axes via eval_shape — no
    allocation; used by the dry-run."""
    def build(rng):
        v, _ = init_state(model, opt_cfg, rng)
        return v
    shapes = jax.eval_shape(build, jax.random.PRNGKey(0))
    _, axes = init_state_axes(model, opt_cfg)
    return shapes, axes


def init_state_axes(model: Model, opt_cfg: optim.OptConfig):
    """Axes tree only (cheap: init under eval_shape)."""
    def build(rng):
        params_p = model.init(rng)
        opt_p = optim.adafactor_init(params_p) \
            if opt_cfg.name == "adafactor" else optim.adamw_init(params_p)
        return {"params": params_p, "opt": opt_p}
    tree_p = jax.eval_shape(build, jax.random.PRNGKey(0))
    values, axes = unzip(tree_p)
    return values, axes


def make_train_step(model: Model, opt_cfg: optim.OptConfig,
                    par: ParallelConfig, mesh=None,
                    impl: str = "auto") -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    cfg = model.cfg
    spmd = make_moe_spmd(cfg, par, mesh)
    n_micro = max(par.microbatches, 1)

    def loss_of(params, mb):
        return model.loss_fn(params, mb, spmd=spmd, impl=impl,
                             remat=par.remat)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def split_micro(batch):
        def r(x):
            return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
        return jax.tree_util.tree_map(r, batch)

    def train_step(state, batch):
        params = state["params"]

        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = split_micro(batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = jax.lax.scan(
                acc_body, (g0, jnp.float32(0)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            metrics = jax.tree_util.tree_map(lambda x: x[-1], ms)

        opt = state["opt"]
        if opt_cfg.name == "adafactor":
            new_params, f_new, count, stats = optim.adafactor_update(
                opt_cfg, params, grads, opt["f"], opt["count"])
            new_opt = {"f": f_new, "count": count}
        else:
            new_params, m_new, v_new, count, stats = optim.adamw_update(
                opt_cfg, params, grads, opt["m"], opt["v"], opt["count"])
            new_opt = {"m": m_new, "v": v_new, "count": count}

        metrics = dict(metrics)
        metrics.update(stats)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
