"""Optimizers: AdamW and Adafactor, with state-dtype policies.

State is a P-tree (same logical axes as the params it shadows) so FSDP
shards optimizer state exactly like ZeRO-3.  ``state_dtype`` lets the
340B config keep m/v in bf16 (see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.common import P, is_p


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"             # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"    # m/v dtype (bf16 for the 340B config)
    warmup: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup) /
                    jnp.maximum(cfg.decay_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params_p) -> dict:
    """params_p: P-tree → opt-state P-tree (m, v mirror params' axes)."""
    def zeros_like_p(p: P, dtype) -> P:
        return P(jnp.zeros(p.value.shape, dtype), p.axes)

    return {
        "m": jax.tree_util.tree_map(
            lambda p: zeros_like_p(p, jnp.float32), params_p, is_leaf=is_p),
        "v": jax.tree_util.tree_map(
            lambda p: zeros_like_p(p, jnp.float32), params_p, is_leaf=is_p),
        "count": P(jnp.zeros((), jnp.int32), ()),
    }


def cast_state(opt_state, dtype):
    dt = jnp.dtype(dtype)

    def cast(p: P) -> P:
        if p.value.ndim == 0:
            return p
        return P(p.value.astype(dt), p.axes)

    return {
        "m": jax.tree_util.tree_map(cast, opt_state["m"], is_leaf=is_p),
        "v": jax.tree_util.tree_map(cast, opt_state["v"], is_leaf=is_p),
        "count": opt_state["count"],
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: OptConfig, params, grads, m, v, count):
    """All args plain value trees. Returns (params, m, v, count, stats)."""
    count = count + 1
    lr = schedule(cfg, count)
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    def upd(p, g, m_, v_):
        g = g.astype(jnp.float32) * clip
        mf = m_.astype(jnp.float32)
        vf = v_.astype(jnp.float32)
        m_new = cfg.b1 * mf + (1 - cfg.b1) * g
        v_new = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        mhat = m_new / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        # dict marker (params trees contain tuples as *containers*, so a
        # tuple leaf would be ambiguous to tree_map)
        return {"__p": p_new.astype(p.dtype), "__m": m_new.astype(m_.dtype),
                "__v": v_new.astype(v_.dtype)}

    out = jax.tree_util.tree_map(upd, params, grads, m, v)
    marker = lambda x: isinstance(x, dict) and "__p" in x
    params_new = jax.tree_util.tree_map(lambda t: t["__p"], out, is_leaf=marker)
    m_new = jax.tree_util.tree_map(lambda t: t["__m"], out, is_leaf=marker)
    v_new = jax.tree_util.tree_map(lambda t: t["__v"], out, is_leaf=marker)
    return params_new, m_new, v_new, count, {"grad_norm": gn, "lr": lr}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment for ≥2D tensors)
# ---------------------------------------------------------------------------
def adafactor_init(params_p) -> dict:
    def state_for(p: P):
        if p.value.ndim >= 2:
            row = P(jnp.zeros(p.value.shape[:-1], jnp.float32),
                    p.axes[:-1])
            col = P(jnp.zeros(p.value.shape[:-2] + p.value.shape[-1:],
                              jnp.float32), p.axes[:-2] + p.axes[-1:])
            return {"row": row, "col": col}
        return {"v": P(jnp.zeros(p.value.shape, jnp.float32), p.axes)}

    return {
        "f": jax.tree_util.tree_map(state_for, params_p, is_leaf=is_p),
        "count": P(jnp.zeros((), jnp.int32), ()),
    }


def adafactor_update(cfg: OptConfig, params, grads, fstate, count):
    count = count + 1
    lr = schedule(cfg, count)
    decay = 1.0 - (count.astype(jnp.float32) + 1.0) ** -0.8
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    def upd(p, g, st):
        g = g.astype(jnp.float32) * clip
        g2 = jnp.square(g) + 1e-30
        if p.ndim >= 2:
            row = decay * st["row"] + (1 - decay) * g2.mean(-1)
            col = decay * st["col"] + (1 - decay) * g2.mean(-2)
            rmean = row.mean(-1, keepdims=True)
            vhat = (row / jnp.maximum(rmean, 1e-30))[..., None] * \
                col[..., None, :]
            new_st = {"row": row, "col": col}
        else:
            v = decay * st["v"] + (1 - decay) * g2
            vhat = v
            new_st = {"v": v}
        step = g / jnp.sqrt(vhat + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return {"__p": p_new, "__st": new_st}

    out = jax.tree_util.tree_map(upd, params, grads, fstate)
    marker = lambda x: isinstance(x, dict) and "__p" in x
    params_new = jax.tree_util.tree_map(lambda t: t["__p"], out, is_leaf=marker)
    f_new = jax.tree_util.tree_map(lambda t: t["__st"], out, is_leaf=marker)
    return params_new, f_new, count, {"grad_norm": gn, "lr": lr}
