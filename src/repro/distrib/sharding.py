"""Logical-axis → mesh sharding resolver.

Model code tags every parameter/cache dim with a *logical* axis name
(see models/common.P).  This module maps those names onto mesh axes via
an ordered rule table, with automatic fallback: a rule only applies if
the mesh axes exist, are not already used by another dim of the same
tensor, and divide the dim size — otherwise progressively shorter
prefixes of the rule are tried, ending at replication.

Default layout = ZeRO-3 FSDP (+TP):
  * tensor-parallel dims (vocab, heads, mlp, experts, …) → ``model``
  * the ``embed`` dim of every weight → ``("pod","data")``  (FSDP)
  * decode KV caches: batch → ``("pod","data")``, sequence → ``model``
    (sequence-parallel decode; overridden to ("data","model") for the
    batch=1 long-context cell)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..models.common import Axes

Rules = Dict[str, Tuple[str, ...]]


def abstract_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Device-less mesh for spec resolution, across JAX API revisions.

    Newer JAX takes ``AbstractMesh(((name, size), ...))``; older releases
    took ``(shape, axis_names)`` positionally.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except (TypeError, ValueError):
        return AbstractMesh(shape, axes)

# rule values are *ordered preferences*; () / missing = replicate
DEFAULT_RULES: Rules = {
    # ---- weights: TP dims
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "inner": ("model",),
    "lru": ("model",),
    "ssm_heads": ("model",),
    # ---- weights: FSDP dim
    "embed": ("pod", "data"),
    # ---- replicated / small
    "layers": (),
    "head_dim": (),
    "state": (),
    "state_proj": (),
    "conv": (),
    "conv_ch": (),
    "frontend": (),
    "experts_unsharded": (),
    # ---- activations & caches
    "batch": ("pod", "data"),
    "kv_seq": ("model",),
    "enc_seq": (),
}


def merge_rules(base: Rules, override: Optional[Rules]) -> Rules:
    out = dict(base)
    if override:
        out.update(override)
    return out


def spec_for(shape: Tuple[int, ...], axes: Tuple[str, ...], mesh: Mesh,
             rules: Rules) -> PartitionSpec:
    """Resolve one tensor's PartitionSpec."""
    assert len(shape) == len(axes), (shape, axes)
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        pref = tuple(a for a in rules.get(name, ())
                     if a in mesh.shape and a not in used)
        # longest prefix whose product divides the dim
        chosen = None
        for k in range(len(pref), 0, -1):
            cand = pref[:k]
            prod = int(np.prod([mesh.shape[a] for a in cand]))
            if prod > 1 and dim % prod == 0:
                chosen = cand
                break
        if chosen:
            used.update(chosen)
            entries.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def tree_specs(shape_tree, axes_tree, mesh: Mesh,
               rules: Optional[Rules] = None):
    """(ShapeDtypeStruct tree, axes tree) → PartitionSpec tree."""
    rules = merge_rules(DEFAULT_RULES, rules) if rules is not None \
        else DEFAULT_RULES

    def one(sds, axes):
        return spec_for(tuple(sds.shape), axes, mesh, rules)

    return jax.tree_util.tree_map(one, shape_tree, axes_tree)


def tree_shardings(shape_tree, axes_tree, mesh: Mesh,
                   rules: Optional[Rules] = None):
    specs = tree_specs(shape_tree, axes_tree, mesh, rules)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        specs, is_leaf=lambda x: isinstance(x, PartitionSpec))


def bytes_per_device(shape_tree, axes_tree, mesh: Mesh,
                     rules: Optional[Rules] = None) -> int:
    """Analytic bytes/device of a sharded tree (sanity vs memory_analysis)."""
    specs = tree_specs(shape_tree, axes_tree, mesh, rules)
    total = 0

    def add(sds, spec):
        nonlocal total
        n = int(np.prod(sds.shape)) if sds.shape else 1
        div = 1
        for entry in spec:
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                div *= mesh.shape[a]
        total += n * sds.dtype.itemsize // max(div, 1)

    jax.tree_util.tree_map(add, shape_tree, specs,
                           is_leaf=lambda x: isinstance(x, PartitionSpec))
    return total
