from .sharding import (DEFAULT_RULES, bytes_per_device, merge_rules,
                       spec_for, tree_shardings, tree_specs)

__all__ = ["DEFAULT_RULES", "spec_for", "tree_specs", "tree_shardings",
           "bytes_per_device", "merge_rules"]
