"""Distributed-optimization collectives (beyond-paper features).

``compressed_allreduce`` — int8-quantized all-reduce with error feedback.
Per-tensor symmetric scale; the quantization residual is returned so the
caller can fold it into the next step's input (error feedback), which is
what preserves convergence.  Used by the train step when
``ParallelConfig.compress_grads`` is on; tested for convergence parity in
``tests/test_distrib.py``.

``sp_decode_attention`` — explicit 2-pass (max/sum) sequence-parallel
decode softmax over a sharded KV cache, as a ``shard_map`` alternative to
trusting GSPMD's partial-softmax rewrite.  Used in perf hillclimbing.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PS


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str):
    """int8 all-reduce of x over ``axis_name`` (call inside shard_map).

    Quantizes locally, psums the int8 payload widened to int32 (the wire
    cost modeled is the int8 payload; XLA's all-reduce of int32 here is
    the CPU-side stand-in), and dequantizes with the max scale.
    Returns (mean-reduced value, local quantization error for feedback).
    """
    q, scale = quantize_int8(x)
    n = jax.lax.psum(1, axis_name)
    scale_max = jax.lax.pmax(scale, axis_name)
    # re-quantize against the shared scale so the sum is coherent
    q_shared = jnp.clip(jnp.round(x / scale_max), -127, 127).astype(jnp.int8)
    err = x - q_shared.astype(jnp.float32) * scale_max
    summed = jax.lax.psum(q_shared.astype(jnp.int32), axis_name)
    out = summed.astype(jnp.float32) * scale_max / n
    return out, err


def compressed_allreduce_tree(tree, err_tree, mesh, axis_name: str,
                              token_spec):
    """Apply compressed mean-all-reduce to every leaf of ``tree`` (with
    error feedback from / into ``err_tree``), via one shard_map."""
    from jax.experimental.shard_map import shard_map

    flat, treedef = jax.tree_util.tree_flatten(tree)
    errs = jax.tree_util.tree_leaves(err_tree) if err_tree is not None \
        else [jnp.zeros_like(x) for x in flat]

    def fn(*args):
        half = len(args) // 2
        xs, es = args[:half], args[half:]
        outs, new_errs = [], []
        for x, e in zip(xs, es):
            o, ne = compressed_psum(x + e, axis_name)
            outs.append(o)
            new_errs.append(ne)
        return tuple(outs) + tuple(new_errs)

    specs = tuple(token_spec for _ in flat)
    res = shard_map(fn, mesh=mesh, in_specs=specs + specs,
                    out_specs=specs + specs, check_rep=False)(*flat, *errs)
    out = jax.tree_util.tree_unflatten(treedef, res[:len(flat)])
    new_err = jax.tree_util.tree_unflatten(treedef, res[len(flat):])
    return out, new_err


def sp_decode_attention(q, k, v, mesh, *, seq_axis: str = "model",
                        softcap: float = 0.0):
    """Explicit 2-pass sequence-parallel decode attention.

    q: (B,1,H,D) replicated over ``seq_axis``; k, v: (B,T,Hkv,D) with T
    sharded over ``seq_axis``.  Each shard computes its local partial
    (max, exp-sum, weighted value); one psum pair combines them — the
    collective payload is O(B·H·D), independent of T.
    """
    from jax.experimental.shard_map import shard_map

    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv

    def fn(q_l, k_l, v_l):
        qf = q_l.astype(jnp.float32).reshape(B, S, Hkv, rep, D)
        kf = k_l.astype(jnp.float32)
        vf = v_l.astype(jnp.float32)
        s = jnp.einsum("bsgrd,btgd->bsgrt", qf, kf) / np.sqrt(D)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        m_loc = jnp.max(s, axis=-1)
        m = jax.lax.pmax(m_loc, seq_axis)
        p = jnp.exp(s - m[..., None])
        l_loc = p.sum(-1)
        acc_loc = jnp.einsum("bsgrt,btgd->bsgrd", p, vf)
        l = jax.lax.psum(l_loc, seq_axis)
        acc = jax.lax.psum(acc_loc, seq_axis)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(B, S, Hq, D).astype(q_l.dtype)

    return shard_map(
        fn, mesh=mesh,
        in_specs=(PS(None, None, None, None),
                  PS(None, seq_axis, None, None),
                  PS(None, seq_axis, None, None)),
        out_specs=PS(None, None, None, None),
        check_rep=False)(q, k, v)
