"""Pipeline-parallel stage runner (GPipe schedule) via shard_map +
collective_permute.

Intended for the coarse ``pod`` axis, where DCN-like latency favors few
large stages over per-layer collectives.  Layers are split into
``n_stages`` contiguous stages; microbatches stream through with the
classic (n_micro + n_stages − 1)-step schedule.  Activations hop stages
with a single ``collective_permute`` per step — the only inter-stage
communication.

This is a config option (``ParallelConfig.pipeline_stages > 1``) rather
than the default path; it is validated in tests on a small host-device
mesh.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS


def pipeline_apply(stage_fn: Callable, stage_params, x_micro, mesh, *,
                   stage_axis: str = "stage"):
    """Run ``stage_fn(params_local, x) -> x`` over ``n_stages`` stages.

    stage_params: pytree whose leaves have a leading stage dim
                  (n_stages, ...) — sharded 1-per-device over stage_axis.
    x_micro:      (n_micro, mb, ...) microbatched input, replicated.
    Returns (n_micro, mb, ...) outputs (valid on every device).
    """
    from jax.experimental.shard_map import shard_map

    n_stages = mesh.shape[stage_axis]
    n_micro = x_micro.shape[0]
    T = n_micro + n_stages - 1
    fwd = [(i, i + 1) for i in range(n_stages - 1)]

    def fn(params_loc, xm):
        params_loc = jax.tree_util.tree_map(lambda p: p[0], params_loc)
        sid = jax.lax.axis_index(stage_axis)
        mb_shape = xm.shape[1:]
        carry_in = jnp.zeros(mb_shape, xm.dtype)
        outs = jnp.zeros_like(xm)

        def step(t, state):
            carry, outs = state
            # stage 0 ingests microbatch t (when valid)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            inject = jax.lax.dynamic_index_in_dim(xm, mb_idx, 0,
                                                  keepdims=False)
            inp = jnp.where(sid == 0, inject, carry)
            out = stage_fn(params_loc, inp)
            # last stage emits microbatch t-(n_stages-1)
            emit_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            valid = (t >= n_stages - 1) & (sid == n_stages - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out.astype(o.dtype), emit_idx, 0),
                lambda o: o, outs)
            carry = jax.lax.ppermute(out, stage_axis, fwd)
            return carry, outs

        _, outs = jax.lax.fori_loop(0, T, step, (carry_in, outs))
        # every device returns the last stage's buffer
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, 1.0, 0.0) * outs, stage_axis)
        return outs

    pspec = jax.tree_util.tree_map(
        lambda _: PS(stage_axis), stage_params)
    return shard_map(fn, mesh=mesh,
                     in_specs=(pspec, PS()),
                     out_specs=PS(),
                     check_rep=False)(stage_params, x_micro)
