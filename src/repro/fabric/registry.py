"""Service registry — the fabric's replicated name-resolution control
plane.

Instances of a named service register ``(service, address_set, capacity,
load)``; clients resolve a service name to the live instance set.  A
single monotonically increasing **epoch** covers the whole registry and
bumps whenever *membership* of any service changes (register, deregister,
expiry) — load reports deliberately do **not** bump it, so cached client
views stay valid while load churns and are refreshed cheaply via the
``fab.epoch`` poll.

**Replication** (DESIGN.md §8): the registry is one consumer of the
generic replicated control plane in :mod:`repro.fabric.replication` —
its instance table is a :class:`~repro.fabric.replication.ReplicatedTable`
hosted by a per-node :class:`~repro.fabric.replication.ReplicationCore`.
Pass ``peers=`` (the same ordered URI list on every node) and N
``RegistryService`` instances form a quorum: a deterministic **leader
lease** makes exactly one replica authoritative for writes and epoch
bumps; the leader **delta-gossips** per-entry changes — keyed by its
``(nonce, epoch)`` stream and per-entry version stamps — to the
followers over the fabric's own RPC layer (``fab.gossip``), falling
back to full snapshots for peers behind the tombstone horizon;
followers serve ``fab.resolve``/``fab.epoch`` reads from the mirrored
view and *proxy* writes to the leaseholder.  With
``serve_membership=True`` the node also hosts the membership service
(``mem.*``) as a second table on the *same* core — one lease, one
gossip stream, so member liveness and expiry reaps survive leaseholder
death exactly like instance registrations do.  Leadership failover
presents to clients as a nonce change, which
:class:`~repro.fabric.pool.ServicePool` already resyncs on.

Wire schema (all values plain pytree-of-scalars — see DESIGN.md §7/§8):

  fab.register    {service, uris, capacity?, load?, iid?, member_id?}
                  -> {iid, epoch}
  fab.deregister  {service, iid} -> {ok, epoch}
  fab.report      {service, iid, load} -> {epoch}          (heartbeat too)
  fab.resolve     {service} -> {epoch, nonce, instances: [{iid, uris,
                                                capacity, load, age}]}
  fab.services    {} -> {epoch, nonce, services: [name]}
  fab.epoch       {} -> {epoch, nonce, leader}
  fab.status      {} -> {role, leader, nonce, epoch, tables, gossip,
                         peers: [...], ...}
  fab.gossip      {from, leader, nonce, epochs, delta?, snapshot?}
                  -> {nonce, epochs, delta?, snapshot?}     (peers only)

The **nonce** identifies one authoritative epoch stream: epochs are only
comparable within one nonce.  A restarted registry resets its epoch to 0
and a failed-over leader starts a fresh stream, either of which a bare
``view.epoch < cached.epoch`` check would misread as a stale race
forever; clients (ServicePool) detect the nonce change and resync
instead.  Re-registering an existing ``iid`` with unchanged uris (the
``ServiceInstance._report_loop`` recovery path) does **not** bump the
epoch — membership did not change, and bumping would force full
``fab.resolve`` storms across every pool each time an instance recovers
from an expiry.
"""
from __future__ import annotations

import threading
import time
import uuid
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core.executor import Engine
from ..core.na.multi import parse_addr_set
from ..core.types import MercuryError, Ret
from .readcache import ReadCache
from .replication import (QuorumCaller, ReplicationCore,
                          parse_registry_uris)

# instance-table key separator: keys must be flat strings for the
# replicated-table wire format; \x1f (ASCII unit separator) cannot
# appear in a service name or a hex iid
_KEY_SEP = "\x1f"


def _key(service: str, iid: str) -> str:
    return f"{service}{_KEY_SEP}{iid}"


class RegistryService:
    """Hosts the ``fab.*`` RPCs on an engine.  Single-node by default;
    pass ``peers=`` (the same ordered list on every node — order is
    leadership priority) to run as one replica of a quorum.
    ``serve_membership=True`` co-hosts the membership service
    (``mem.*``) on the same replication core, with its member expiries
    reaping bound instances on whichever node holds the lease."""

    def __init__(self, engine: Engine, membership=None,
                 instance_ttl: float = 3.0, sweep_interval: float = 0.5,
                 peers: Optional[Sequence[str]] = None,
                 self_uri: Optional[str] = None,
                 lease_ttl: float = 1.0, gossip_interval: float = 0.25,
                 delta_gossip: bool = True,
                 serve_membership: bool = False,
                 heartbeat_timeout: float = 2.0):
        self.engine = engine
        self.ttl = instance_ttl
        # the core's sweep/gossip threads start only after every table
        # and handler is attached: a node must never elect, sweep, or
        # answer some of its RPCs while others are still being wired
        self.core = ReplicationCore(
            engine, peers=peers, self_uri=self_uri, lease_ttl=lease_ttl,
            gossip_interval=gossip_interval, sweep_interval=sweep_interval,
            delta_gossip=delta_gossip, autostart=False)
        self.table = self.core.table("instances", ttl=instance_ttl)
        # member ids whose expiry still awaits reaping (follower-hosted
        # MembershipServer; see _members_expired) -> forget-after stamp
        self._pending_reaps: Dict[str, float] = {}  #: guarded-by core._lock
        self.core.add_tick_hook(self._apply_pending_reaps)
        self.membership = None
        if serve_membership:
            # lazy import: fabric must not hard-depend on services at
            # module load (services already lazily imports fabric).
            # Done BEFORE any fab.* handler registers: importing the
            # services package is seconds-heavy (jax), and a node that
            # answers fab.register while mem.join is still seconds away
            # hands cold-boot clients hard NOENTRYs
            from ..services.membership import MembershipServer
            self.membership = MembershipServer(
                engine, heartbeat_timeout=heartbeat_timeout,
                sweep_interval=sweep_interval, core=self.core)
            self.membership.on_expire(self._members_expired)
        engine.register("fab.register", self._register)
        engine.register("fab.deregister", self._deregister)
        # fab.report proxies to the leader in quorum mode — a nested
        # blocking call, so it must not run inline on the progress thread
        engine.register("fab.report", self._report, inline=peers is None)
        engine.register("fab.resolve", self._resolve, inline=True)
        engine.register("fab.services", self._services, inline=True)
        engine.register("fab.epoch", self._epoch, inline=True)
        engine.register("fab.status", self._status)
        if membership is not None:
            # duck-typed MembershipServer: reap instances whose member died
            membership.on_expire(self._members_expired)
        self.core.start()

    # -- leadership / compat -------------------------------------------------
    @property
    def is_leader(self) -> bool:
        return self.core.is_leader

    @property
    def self_uri(self) -> str:
        return self.core.self_uri

    @property
    def tracker(self):
        return self.core.tracker

    @property
    def epoch(self) -> int:
        with self.core._lock:             # the table shares the core lock
            return self.table.epoch

    @property
    def nonce(self) -> str:
        with self.core._lock:
            return self.core.nonce

    # -- handlers ------------------------------------------------------------
    def _register(self, req):
        lead = self.core.leader_for_writes()
        if lead is not None:
            return self.core.proxy(lead, "fab.register", req)
        service = req["service"]
        uris = req["uris"]
        if isinstance(uris, str):
            uris = parse_addr_set(uris)
        iid = req.get("iid") or uuid.uuid4().hex[:12]
        key = _key(service, iid)
        uris = list(uris)
        with self.core._lock:
            prev = self.table.get(key)
            # membership changed if the instance is new, moved to
            # different addresses, or rebound to a different member — a
            # member_id rebind must ride the versioned (retransmitted)
            # stream, or a lost soft push would leave some mirror
            # reaping against a stale binding forever.  A same-everything
            # re-register (the report loop's recovery path) must NOT
            # bump the epoch, or every recovery forces a fab.resolve
            # storm across all pools
            if (prev is None or prev["uris"] != uris
                    or prev["member_id"] != req.get("member_id")):
                self.table.put(key, {
                    "service": service, "iid": iid, "uris": uris,
                    "capacity": int(req.get("capacity", 0)),
                    "load": float(req.get("load", 0.0)),
                    "member_id": req.get("member_id"),
                })
            else:
                self.table.update(key,
                                  capacity=int(req.get("capacity",
                                                       prev["capacity"])),
                                  load=float(req.get("load",
                                                     prev["load"])))
            return {"iid": iid, "epoch": self.table.epoch}

    def _deregister(self, req):
        lead = self.core.leader_for_writes()
        if lead is not None:
            return self.core.proxy(lead, "fab.deregister", req)
        with self.core._lock:
            ok = self.table.delete(_key(req["service"], req["iid"]))
            return {"ok": ok, "epoch": self.table.epoch}

    def _report(self, req):
        lead = self.core.leader_for_writes()
        if lead is not None:
            return self.core.proxy(lead, "fab.report", req)
        key = _key(req["service"], req["iid"])
        with self.core._lock:
            inst = self.table.get(key)
            if inst is None:
                # expired instance re-announcing: treat as a (re)register
                raise MercuryError(Ret.NOENTRY,
                                   f"unknown instance {req['iid']}; "
                                   f"re-register")
            fields = {"load": float(req.get("load", inst["load"]))}
            if "capacity" in req:
                fields["capacity"] = int(req["capacity"])
            self.table.update(key, **fields)
            return {"epoch": self.table.epoch}

    def _resolve(self, req):
        service = req["service"]
        now = time.monotonic()
        with self.core._lock:
            out = [{"iid": v["iid"], "uris": list(v["uris"]),
                    "capacity": v["capacity"], "load": v["load"],
                    "age": now - v["last"]}
                   for _, v in self.table.items()
                   if v["service"] == service]
            return {"epoch": self.table.epoch, "nonce": self.core.nonce,
                    "instances": out}

    def _services(self, _req):
        with self.core._lock:
            # carries the full (nonce, epoch) token so the client read
            # cache holds it authoritatively (evicted on epoch bump or
            # nonce change), not merely until the TTL lapses
            return {"epoch": self.table.epoch, "nonce": self.core.nonce,
                    "services": sorted({v["service"]
                                        for _, v in self.table.items()})}

    def _epoch(self, _req):
        with self.core._lock:
            out = {"epoch": self.table.epoch, "nonce": self.core.nonce}
        out["leader"] = (self.core.self_uri if self.core.tracker is None
                         else self.core.tracker.leader_uri())
        return out

    def _status(self, _req):
        """Operator observability (docs/OPERATIONS.md): role, believed
        leaseholder, per-peer liveness + last-acked replication state,
        per-table entry counts/epochs, and delta-vs-snapshot gossip
        counters."""
        st = self.core.status()
        with self.core._lock:
            st.update(epoch=self.table.epoch,
                      instances=len(self.table),
                      services=sorted({v["service"]
                                       for _, v in self.table.items()}))
        return st

    # -- liveness ------------------------------------------------------------
    def _members_expired(self, member_ids: List[str]) -> None:
        """Member-expiry hook (``MembershipServer.on_expire``).  The
        leaseholder reaps directly; a follower-hosted membership server
        queues the member ids as *pending reaps* that the gossip loop
        applies/forwards until the instances are gone — a one-shot
        forward would lose the reap forever if it raced gossip (mirror
        not yet carrying the instance) or hit a leadership hiccup."""
        now = time.monotonic()
        with self.core._lock:
            for m in member_ids:
                # bounded memory + no poisoning of a future legitimate
                # re-registration: forget the reap after 2x instance TTL
                self._pending_reaps[m] = now + 2 * self.ttl
        self.core.mark_dirty()            # reap/forward promptly
        if self.core.is_leader:
            self._apply_pending_reaps()

    def _apply_pending_reaps(self) -> None:
        """Reap instances of expired members: delete locally when
        leading, else forward as deregisters to the leaseholder.
        Called from the expiry hook and retried every gossip tick until
        no instance matches a pending member id."""
        with self.core._lock:
            if not self._pending_reaps:
                return
            now = time.monotonic()
            self._pending_reaps = {m: t for m, t
                                   in self._pending_reaps.items()
                                   if t > now}
            pending = set(self._pending_reaps)
            dead = [(k, v["service"], v["iid"])
                    for k, v in self.table.items()
                    if v["member_id"] in pending]
            if self.core.is_leader:
                for k, _, _ in dead:
                    self.table.delete(k)
                return
        if not dead:
            return
        try:
            lead = self.core.leader_for_writes()
        except MercuryError:
            return                        # unsettled: retried next tick
        for _, service, iid in dead:
            try:
                self.engine.call(lead, "fab.deregister",
                                 {"service": service, "iid": iid,
                                  "_proxied": True},
                                 timeout=self.core._proxy_timeout)
            except Exception:
                pass                      # retried next tick

    def close(self) -> None:
        """Stop and join the control-plane threads (idempotent)."""
        self.core.close()

    stop = close


class RegistryClient:
    """Origin-side wrapper over the ``fab.*`` RPCs with replica failover.

    ``registry_uri`` is a registry *address set*: one endpoint per
    replica (list, or one comma-separated string); the underlying
    :class:`~repro.fabric.replication.QuorumCaller` sticks to the
    endpoint that last answered and rotates on transport-class
    failures.

    ``cache_ttl > 0`` turns on the client-side idempotent read cache
    (DESIGN.md §9): ``fab.resolve``/``fab.epoch``/``fab.services`` hits
    within the TTL are served locally as long as the registry's
    ``(nonce, epoch)`` token has not advanced — every response and every
    write observes the token, so an epoch bump or a leader failover
    (nonce change) evicts immediately and no read is ever served from a
    superseded epoch stream.  ``fresh=True`` on a read bypasses the
    cached value for callers that must see the authority."""

    def __init__(self, engine: Engine, registry_uri, timeout: float = 10.0,
                 cache_ttl: float = 0.0):
        self.engine = engine
        self._caller = QuorumCaller(engine, registry_uri, timeout=timeout)
        self.uris = self._caller.uris
        self.timeout = timeout
        self.cache = ReadCache(ttl=cache_ttl)

    @property
    def registry(self) -> str:
        """The currently preferred endpoint (observability/tests)."""
        return self._caller.current

    def _call(self, name: str, req: dict):
        return self._caller.call(name, req)

    @staticmethod
    def _token_of(out: dict):
        return out.get("nonce"), out["epoch"]

    def register(self, service: str, uris, capacity: int = 0,
                 load: float = 0.0, iid: Optional[str] = None,
                 member_id: Optional[str] = None) -> str:
        out = self._call("fab.register", {
            "service": service, "uris": uris, "capacity": capacity,
            "load": load, "iid": iid, "member_id": member_id,
        })
        # read-your-writes: an epoch bumped by our own write evicts any
        # cached view immediately (no waiting out the TTL)
        self.cache.observe_epoch(out["epoch"])
        return out["iid"]

    def deregister(self, service: str, iid: str) -> bool:
        out = self._call("fab.deregister", {"service": service, "iid": iid})
        self.cache.observe_epoch(out["epoch"])
        return out["ok"]

    def report(self, service: str, iid: str, load: float,
               capacity: Optional[int] = None) -> int:
        req = {"service": service, "iid": iid, "load": load}
        if capacity is not None:
            req["capacity"] = capacity
        epoch = self._call("fab.report", req)["epoch"]
        self.cache.observe_epoch(epoch)
        return epoch

    def resolve(self, service: str, fresh: bool = False) -> dict:
        return self.cache.get_or_call(
            "fab.resolve", {"service": service},
            lambda: self._call("fab.resolve", {"service": service}),
            fresh=fresh, token_of=self._token_of)

    def services(self, fresh: bool = False) -> List[str]:
        return self.cache.get_or_call(
            "fab.services", {},
            lambda: self._call("fab.services", {}),
            fresh=fresh, token_of=self._token_of)["services"]

    def epoch(self, fresh: bool = False) -> int:
        return self.epoch_info(fresh=fresh)[0]

    def epoch_info(self, fresh: bool = False) -> Tuple[int, Optional[str]]:
        """(epoch, nonce) — the cheap staleness poll.  Epochs from
        different nonces are not comparable (registry restarted, or the
        lease failed over to a new leader)."""
        out = self.cache.get_or_call(
            "fab.epoch", {},
            lambda: self._call("fab.epoch", {}),
            fresh=fresh, token_of=self._token_of)
        return out["epoch"], out.get("nonce")

    def status(self) -> dict:
        """``fab.status`` of the currently preferred replica."""
        return self._call("fab.status", {})


def resolve_service_uris(engine: Engine, registry_uri, service: str,
                         timeout: float = 10.0) -> List[str]:
    """Resolve ``service`` to its instances' address sets (one
    semicolon-joined string per instance, registry order).  The thin
    entry point for clients that want name resolution without a full
    :class:`~repro.fabric.pool.ServicePool` (checkpoint/datafeed).
    ``registry_uri`` may name one registry endpoint, the whole replica
    set (see :class:`RegistryClient`), or a sharded control plane
    (``'|'``-separated shard quorums, DESIGN.md §12 — the lookup goes
    straight to the shard that owns ``service``)."""
    from .sharding import registry_client_for  # deferred: import cycle
    client = registry_client_for(engine, registry_uri, service=service,
                                 timeout=timeout)
    view = client.resolve(service)
    if not view["instances"]:
        raise MercuryError(Ret.NOENTRY,
                           f"no live instances of service {service!r}")
    return [";".join(inst["uris"]) for inst in view["instances"]]


class ServiceInstance:
    """Self-registration helper for servers: registers this engine's
    address set under ``service`` and keeps the registration alive with
    periodic ``fab.report`` heartbeats carrying a live load sample.

    ``registry_uri`` may be a single endpoint or the replica set (the
    underlying :class:`RegistryClient` fails over).  ``load_fn`` returns
    the instance's current load (any float; the convention used by the
    built-in services is *outstanding work items*, e.g. active slots +
    queued requests).  ``close(deregister=False)`` simulates a crash:
    the reporter stops but the registry only learns via TTL/membership
    expiry — exactly the path the pool's failover covers.
    """

    def __init__(self, engine: Engine, registry_uri, service: str,
                 capacity: int = 0,
                 load_fn: Optional[Callable[[], float]] = None,
                 report_interval: float = 0.5,
                 member_id: Optional[str] = None,
                 uris: Optional[List[str]] = None):
        from .sharding import registry_client_for  # deferred: import cycle
        # sharded specs bind the reporter to the owning shard; the
        # heartbeat/re-register loop below is oblivious to the map
        self.client = registry_client_for(engine, registry_uri,
                                          service=service)
        self.service = service
        self.load_fn = load_fn
        self.interval = report_interval
        self.uris = uris if uris is not None else engine.uri
        self.capacity = capacity
        self.member_id = member_id
        self._stop = threading.Event()
        # pre-generate the iid client-side: registration is then
        # idempotent, so a register retried after a lost response (or
        # re-proxied across a leader failover) can never mint a ghost
        # duplicate under a second iid
        self.iid = uuid.uuid4().hex[:12]
        self.client.register(
            service, self.uris, capacity=capacity, iid=self.iid,
            load=load_fn() if load_fn else 0.0, member_id=member_id)
        self._thread = threading.Thread(target=self._report_loop, daemon=True,
                                        name=f"fabric-report[{service}]")
        self._thread.start()

    def _report_loop(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                self.client.report(self.service, self.iid,
                                   self.load_fn() if self.load_fn else 0.0)
            except MercuryError:
                # registry expired us (e.g. long GC pause, or a leader
                # failover dropped state written during a partition):
                # re-register under the old iid
                try:
                    self.client.register(
                        self.service, self.uris, capacity=self.capacity,
                        load=self.load_fn() if self.load_fn else 0.0,
                        iid=self.iid, member_id=self.member_id)
                except Exception:
                    pass
            except Exception:
                pass            # registry briefly unreachable: keep trying

    def close(self, deregister: bool = True) -> None:
        if self._stop.is_set():
            return
        self._stop.set()
        self._thread.join(timeout=2.0)
        if deregister:
            try:
                self.client.deregister(self.service, self.iid)
            except Exception:
                pass
