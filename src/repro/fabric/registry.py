"""Service registry — the fabric's name-resolution control plane.

Instances of a named service register ``(service, address_set, capacity,
load)``; clients resolve a service name to the live instance set.  A
single monotonically increasing **epoch** covers the whole registry and
bumps whenever *membership* of any service changes (register, deregister,
expiry) — load reports deliberately do **not** bump it, so cached client
views stay valid while load churns and are refreshed cheaply via the
``fab.epoch`` poll.

Liveness is layered on the membership service's machinery rather than
reinvented: an instance's ``fab.report`` doubles as its heartbeat (TTL
sweep shares the registry's own sweeper), and when the registry is given
a :class:`~repro.services.membership.MembershipServer`, instances bound
to a ``member_id`` are also reaped the moment the member expires.

Wire schema (all values plain pytree-of-scalars — see DESIGN.md §7):

  fab.register    {service, uris, capacity?, load?, iid?, member_id?}
                  -> {iid, epoch}
  fab.deregister  {service, iid} -> {ok, epoch}
  fab.report      {service, iid, load} -> {epoch}          (heartbeat too)
  fab.resolve     {service} -> {epoch, nonce, instances: [{iid, uris,
                                                capacity, load, age}]}
  fab.services    {} -> {epoch, services: [name]}
  fab.epoch       {} -> {epoch, nonce}

The **nonce** is a per-registry-process random id: epochs are only
comparable within one nonce.  A restarted registry resets its epoch to 0,
which a bare ``view.epoch < cached.epoch`` check would misread as a stale
race forever; clients (ServicePool) detect the nonce change and resync
instead.  Re-registering an existing ``iid`` with unchanged uris (the
``ServiceInstance._report_loop`` recovery path) does **not** bump the
epoch — membership did not change, and bumping would force full
``fab.resolve`` storms across every pool each time an instance recovers
from an expiry.
"""
from __future__ import annotations

import threading
import time
import uuid
from typing import Callable, Dict, List, Optional, Tuple

from ..core.executor import Engine
from ..core.na.multi import parse_addr_set
from ..core.types import MercuryError, Ret


class RegistryService:
    """Hosts the ``fab.*`` RPCs on an engine (usually the same engine that
    runs the :class:`MembershipServer` — one control-plane node)."""

    def __init__(self, engine: Engine, membership=None,
                 instance_ttl: float = 3.0, sweep_interval: float = 0.5):
        self.engine = engine
        self.ttl = instance_ttl
        # (service, iid) -> {uris, capacity, load, member_id, last}
        self.instances: Dict[Tuple[str, str], dict] = {}
        self.epoch = 0
        # restart nonce: epochs are only comparable within one nonce (a
        # restarted registry restarts at epoch 0 — see module docstring)
        self.nonce = uuid.uuid4().hex[:12]
        self._lock = threading.Lock()
        self._stop = threading.Event()
        engine.register("fab.register", self._register)
        engine.register("fab.deregister", self._deregister)
        engine.register("fab.report", self._report, inline=True)
        engine.register("fab.resolve", self._resolve, inline=True)
        engine.register("fab.services", self._services, inline=True)
        engine.register("fab.epoch", self._epoch, inline=True)
        if membership is not None:
            # duck-typed MembershipServer: reap instances whose member died
            membership.on_expire(self._members_expired)
        self._sweeper = threading.Thread(
            target=self._sweep_loop, args=(sweep_interval,), daemon=True,
            name="fabric-registry-sweep")
        self._sweeper.start()

    # -- handlers ------------------------------------------------------------
    def _register(self, req):
        service = req["service"]
        uris = req["uris"]
        if isinstance(uris, str):
            uris = parse_addr_set(uris)
        iid = req.get("iid") or uuid.uuid4().hex[:12]
        with self._lock:
            prev = self.instances.get((service, iid))
            self.instances[(service, iid)] = {
                "uris": list(uris),
                "capacity": int(req.get("capacity", 0)),
                "load": float(req.get("load", 0.0)),
                "member_id": req.get("member_id"),
                "last": time.monotonic(),
            }
            # membership changed only if the instance is new or moved to
            # different addresses; a same-uris re-register (the report
            # loop's recovery path) must NOT bump the epoch, or every
            # recovery forces a fab.resolve storm across all pools
            if prev is None or prev["uris"] != list(uris):
                self.epoch += 1
            return {"iid": iid, "epoch": self.epoch}

    def _deregister(self, req):
        with self._lock:
            ok = self.instances.pop((req["service"], req["iid"]), None)
            if ok is not None:
                self.epoch += 1
            return {"ok": ok is not None, "epoch": self.epoch}

    def _report(self, req):
        with self._lock:
            inst = self.instances.get((req["service"], req["iid"]))
            if inst is None:
                # expired instance re-announcing: treat as a (re)register
                raise MercuryError(Ret.NOENTRY,
                                   f"unknown instance {req['iid']}; "
                                   f"re-register")
            inst["load"] = float(req.get("load", inst["load"]))
            if "capacity" in req:
                inst["capacity"] = int(req["capacity"])
            inst["last"] = time.monotonic()
            return {"epoch": self.epoch}

    def _resolve(self, req):
        service = req["service"]
        now = time.monotonic()
        with self._lock:
            out = [{"iid": iid, "uris": list(v["uris"]),
                    "capacity": v["capacity"], "load": v["load"],
                    "age": now - v["last"]}
                   for (s, iid), v in self.instances.items() if s == service]
            return {"epoch": self.epoch, "nonce": self.nonce,
                    "instances": out}

    def _services(self, _req):
        with self._lock:
            return {"epoch": self.epoch,
                    "services": sorted({s for (s, _) in self.instances})}

    def _epoch(self, _req):
        with self._lock:
            return {"epoch": self.epoch, "nonce": self.nonce}

    # -- liveness ------------------------------------------------------------
    def _members_expired(self, member_ids: List[str]) -> None:
        gone = set(member_ids)
        with self._lock:
            dead = [k for k, v in self.instances.items()
                    if v["member_id"] in gone]
            for k in dead:
                del self.instances[k]
            if dead:
                self.epoch += 1

    def _sweep_loop(self, interval: float) -> None:
        while not self._stop.wait(interval):
            now = time.monotonic()
            with self._lock:
                dead = [k for k, v in self.instances.items()
                        if now - v["last"] > self.ttl]
                for k in dead:
                    del self.instances[k]
                if dead:
                    self.epoch += 1

    def close(self) -> None:
        """Stop and join the sweeper (idempotent)."""
        self._stop.set()
        if self._sweeper.is_alive():
            self._sweeper.join(timeout=2.0)

    stop = close


class RegistryClient:
    """Thin origin-side wrapper over the ``fab.*`` RPCs."""

    def __init__(self, engine: Engine, registry_uri: str,
                 timeout: float = 10.0):
        self.engine = engine
        self.registry = registry_uri
        self.timeout = timeout

    def register(self, service: str, uris, capacity: int = 0,
                 load: float = 0.0, iid: Optional[str] = None,
                 member_id: Optional[str] = None) -> str:
        out = self.engine.call(self.registry, "fab.register", {
            "service": service, "uris": uris, "capacity": capacity,
            "load": load, "iid": iid, "member_id": member_id,
        }, timeout=self.timeout)
        return out["iid"]

    def deregister(self, service: str, iid: str) -> bool:
        return self.engine.call(self.registry, "fab.deregister",
                                {"service": service, "iid": iid},
                                timeout=self.timeout)["ok"]

    def report(self, service: str, iid: str, load: float,
               capacity: Optional[int] = None) -> int:
        req = {"service": service, "iid": iid, "load": load}
        if capacity is not None:
            req["capacity"] = capacity
        return self.engine.call(self.registry, "fab.report", req,
                                timeout=self.timeout)["epoch"]

    def resolve(self, service: str) -> dict:
        return self.engine.call(self.registry, "fab.resolve",
                                {"service": service}, timeout=self.timeout)

    def services(self) -> List[str]:
        return self.engine.call(self.registry, "fab.services", {},
                                timeout=self.timeout)["services"]

    def epoch(self) -> int:
        return self.engine.call(self.registry, "fab.epoch", {},
                                timeout=self.timeout)["epoch"]

    def epoch_info(self) -> Tuple[int, Optional[str]]:
        """(epoch, nonce) — the cheap staleness poll.  Epochs from
        different nonces are not comparable (registry restarted)."""
        out = self.engine.call(self.registry, "fab.epoch", {},
                               timeout=self.timeout)
        return out["epoch"], out.get("nonce")


def resolve_service_uris(engine: Engine, registry_uri: str, service: str,
                         timeout: float = 10.0) -> List[str]:
    """Resolve ``service`` to its instances' address sets (one
    semicolon-joined string per instance, registry order).  The thin
    entry point for clients that want name resolution without a full
    :class:`~repro.fabric.pool.ServicePool` (checkpoint/datafeed)."""
    view = RegistryClient(engine, registry_uri, timeout).resolve(service)
    if not view["instances"]:
        raise MercuryError(Ret.NOENTRY,
                           f"no live instances of service {service!r}")
    return [";".join(inst["uris"]) for inst in view["instances"]]


class ServiceInstance:
    """Self-registration helper for servers: registers this engine's
    address set under ``service`` and keeps the registration alive with
    periodic ``fab.report`` heartbeats carrying a live load sample.

    ``load_fn`` returns the instance's current load (any float; the
    convention used by the built-in services is *outstanding work items*,
    e.g. active slots + queued requests).  ``close(deregister=False)``
    simulates a crash: the reporter stops but the registry only learns via
    TTL/membership expiry — exactly the path the pool's failover covers.
    """

    def __init__(self, engine: Engine, registry_uri: str, service: str,
                 capacity: int = 0,
                 load_fn: Optional[Callable[[], float]] = None,
                 report_interval: float = 0.5,
                 member_id: Optional[str] = None,
                 uris: Optional[List[str]] = None):
        self.client = RegistryClient(engine, registry_uri)
        self.service = service
        self.load_fn = load_fn
        self.interval = report_interval
        self.uris = uris if uris is not None else engine.uri
        self.capacity = capacity
        self.member_id = member_id
        self._stop = threading.Event()
        self.iid = self.client.register(
            service, self.uris, capacity=capacity,
            load=load_fn() if load_fn else 0.0, member_id=member_id)
        self._thread = threading.Thread(target=self._report_loop, daemon=True,
                                        name=f"fabric-report[{service}]")
        self._thread.start()

    def _report_loop(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                self.client.report(self.service, self.iid,
                                   self.load_fn() if self.load_fn else 0.0)
            except MercuryError:
                # registry expired us (e.g. long GC pause): re-register
                try:
                    self.client.register(
                        self.service, self.uris, capacity=self.capacity,
                        load=self.load_fn() if self.load_fn else 0.0,
                        iid=self.iid, member_id=self.member_id)
                except Exception:
                    pass
            except Exception:
                pass            # registry briefly unreachable: keep trying

    def close(self, deregister: bool = True) -> None:
        if self._stop.is_set():
            return
        self._stop.set()
        self._thread.join(timeout=2.0)
        if deregister:
            try:
                self.client.deregister(self.service, self.iid)
            except Exception:
                pass
