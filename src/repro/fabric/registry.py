"""Service registry — the fabric's replicated name-resolution control
plane.

Instances of a named service register ``(service, address_set, capacity,
load)``; clients resolve a service name to the live instance set.  A
single monotonically increasing **epoch** covers the whole registry and
bumps whenever *membership* of any service changes (register, deregister,
expiry) — load reports deliberately do **not** bump it, so cached client
views stay valid while load churns and are refreshed cheaply via the
``fab.epoch`` poll.

Liveness is layered on the membership service's machinery rather than
reinvented: an instance's ``fab.report`` doubles as its heartbeat (TTL
sweep shares the registry's own sweeper), and when the registry is given
a :class:`~repro.services.membership.MembershipServer`, instances bound
to a ``member_id`` are also reaped the moment the member expires.

**Replication** (DESIGN.md §8): the registry is no longer a singleton.
Pass ``peers=`` (the same ordered URI list on every node) and N
``RegistryService`` instances form a quorum: a deterministic **leader
lease** (lowest-rank live peer, tracked by
:class:`~repro.fabric.replication.PeerTracker`) makes exactly one
replica authoritative for epoch bumps; the leader **gossips** the full
``fab.*`` table — keyed by its ``(epoch, nonce)`` stream — to the
followers over the fabric's own RPC layer (``fab.gossip``); followers
serve ``fab.resolve``/``fab.epoch`` reads from the mirrored view and
*proxy* writes to the leaseholder.  A partitioned or restarted replica
reconciles by nonce/epoch comparison exactly like the pools do: it
adopts any acting leader's snapshot instead of serving its stale (or
empty) view.  Leadership failover presents to clients as a nonce change,
which :class:`~repro.fabric.pool.ServicePool` already resyncs on.

Wire schema (all values plain pytree-of-scalars — see DESIGN.md §7/§8):

  fab.register    {service, uris, capacity?, load?, iid?, member_id?}
                  -> {iid, epoch}
  fab.deregister  {service, iid} -> {ok, epoch}
  fab.report      {service, iid, load} -> {epoch}          (heartbeat too)
  fab.resolve     {service} -> {epoch, nonce, instances: [{iid, uris,
                                                capacity, load, age}]}
  fab.services    {} -> {epoch, services: [name]}
  fab.epoch       {} -> {epoch, nonce, leader}
  fab.status      {} -> {role, leader, nonce, epoch, peers: [...], ...}
  fab.gossip      {from, leader, nonce, epoch, snapshot?}
                  -> {nonce, epoch, snapshot?}              (peers only)

The **nonce** identifies one authoritative epoch stream: epochs are only
comparable within one nonce.  A restarted registry resets its epoch to 0
and a failed-over leader starts a fresh stream, either of which a bare
``view.epoch < cached.epoch`` check would misread as a stale race
forever; clients (ServicePool) detect the nonce change and resync
instead.  Re-registering an existing ``iid`` with unchanged uris (the
``ServiceInstance._report_loop`` recovery path) does **not** bump the
epoch — membership did not change, and bumping would force full
``fab.resolve`` storms across every pool each time an instance recovers
from an expiry.
"""
from __future__ import annotations

import threading
import time
import uuid
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core.executor import Engine
from ..core.na.multi import parse_addr_set
from ..core.types import MercuryError, Ret
from .replication import PeerTracker, parse_registry_uris

# transport-class failures that mean "this registry endpoint (or the
# proxy path behind it) is unreachable/unsettled — try another replica";
# application errors (NOENTRY from fab.report, INVALID_ARG, ...) must
# pass through: the handler ran.
_FAILOVER_RETS = {Ret.TIMEOUT, Ret.DISCONNECT, Ret.AGAIN, Ret.CANCELED,
                  Ret.PROTOCOL_ERROR}


class RegistryService:
    """Hosts the ``fab.*`` RPCs on an engine.  Single-node by default;
    pass ``peers=`` (the same ordered list on every node — order is
    leadership priority) to run as one replica of a quorum."""

    def __init__(self, engine: Engine, membership=None,
                 instance_ttl: float = 3.0, sweep_interval: float = 0.5,
                 peers: Optional[Sequence[str]] = None,
                 self_uri: Optional[str] = None,
                 lease_ttl: float = 1.0, gossip_interval: float = 0.25):
        self.engine = engine
        self.ttl = instance_ttl
        # (service, iid) -> {uris, capacity, load, member_id, last}
        self.instances: Dict[Tuple[str, str], dict] = {}
        self.epoch = 0
        # stream nonce: epochs are only comparable within one nonce (a
        # restarted registry restarts at epoch 0 and a failed-over
        # leader starts a fresh stream — see module docstring)
        self.nonce = uuid.uuid4().hex[:12]
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._dirty = threading.Event()   # membership moved: push now
        # member ids whose expiry still awaits reaping (follower-hosted
        # MembershipServer; see _members_expired) -> forget-after stamp
        self._pending_reaps: Dict[str, float] = {}
        self.gossip_interval = gossip_interval
        if peers is not None:
            peer_list = list(peers)
            su = self_uri or (engine.uri if engine.uri in peer_list
                              else None)
            if su is None:
                raise ValueError(
                    f"engine uri {engine.uri!r} is not in peers "
                    f"{peer_list!r}; pass self_uri= explicitly")
            self.tracker: Optional[PeerTracker] = PeerTracker(
                peer_list, su, lease_ttl=lease_ttl)
            self.self_uri = su
            self._leading = False         # elected by the gossip loop
        else:
            self.tracker = None
            self.self_uri = engine.uri
            self._leading = True          # single node: always the leader
        self._proxy_timeout = max(0.5, min(2.0, lease_ttl))
        # gossip probes must resolve well inside the lease: a black-holed
        # peer burning a full proxy_timeout per tick would starve contact
        # with live peers and flap leadership
        self._gossip_timeout = max(0.2, min(self._proxy_timeout,
                                            lease_ttl / 2))
        # full-snapshot push cadence when nothing is dirty (keeps
        # mirrored load reports fresh without shipping the table on
        # every heartbeat; membership changes push immediately)
        self._full_push_every = max(1.0, gossip_interval)
        self._next_full_push = 0.0
        engine.register("fab.register", self._register)
        engine.register("fab.deregister", self._deregister)
        # fab.report proxies to the leader in quorum mode — a nested
        # blocking call, so it must not run inline on the progress thread
        engine.register("fab.report", self._report, inline=peers is None)
        engine.register("fab.resolve", self._resolve, inline=True)
        engine.register("fab.services", self._services, inline=True)
        engine.register("fab.epoch", self._epoch, inline=True)
        engine.register("fab.status", self._status)
        engine.register("fab.gossip", self._gossip)
        if membership is not None:
            # duck-typed MembershipServer: reap instances whose member died
            membership.on_expire(self._members_expired)
        self._sweeper = threading.Thread(
            target=self._sweep_loop, args=(sweep_interval,), daemon=True,
            name="fabric-registry-sweep")
        self._sweeper.start()
        self._gossiper: Optional[threading.Thread] = None
        if self.tracker is not None:
            self._gossiper = threading.Thread(
                target=self._gossip_loop, daemon=True,
                name="fabric-registry-gossip")
            self._gossiper.start()

    # -- leadership ----------------------------------------------------------
    @property
    def is_leader(self) -> bool:
        return self._leading

    def _leader_for_writes(self) -> Optional[str]:
        """None if this replica may apply writes locally; otherwise the
        leaseholder to proxy to.  Raises ``AGAIN`` while leadership is
        unsettled (boot grace / takeover pending) — retryable:
        ``RegistryClient`` keeps re-probing the quorum within its own
        timeout budget until the lease settles."""
        if self.tracker is None or self._leading:
            return None
        lead = self.tracker.leader_uri()
        if lead is None or lead == self.self_uri:
            raise MercuryError(Ret.AGAIN,
                               "registry leadership unsettled; retry")
        return lead

    def _proxy(self, leader: str, name: str, req: dict):
        """Forward a write to the leaseholder (one hop only: a proxied
        write that lands on another follower fails fast with AGAIN
        rather than bouncing around a partitioned quorum)."""
        if req.get("_proxied"):
            raise MercuryError(Ret.AGAIN,
                               "registry leadership unsettled; retry")
        try:
            return self.engine.call(leader, name, dict(req, _proxied=True),
                                    timeout=self._proxy_timeout)
        except MercuryError as e:
            if e.ret in _FAILOVER_RETS:
                raise MercuryError(
                    Ret.AGAIN, f"registry leader {leader} unreachable "
                    f"({e.ret.name}); retry") from e
            raise                         # application error: handler ran

    def _take_over(self) -> None:
        """Become the leaseholder: start a fresh epoch stream (new nonce
        → every pool resyncs) and refresh all instance heartbeats so the
        takeover itself cannot mass-expire instances that could not
        report while the old leader was dead."""
        with self._lock:
            self._leading = True
            self.nonce = uuid.uuid4().hex[:12]
            self.epoch += 1
            now = time.monotonic()
            for v in self.instances.values():
                v["last"] = now
        self._dirty.set()                 # announce the new stream now

    # -- handlers ------------------------------------------------------------
    def _register(self, req):
        lead = self._leader_for_writes()
        if lead is not None:
            return self._proxy(lead, "fab.register", req)
        service = req["service"]
        uris = req["uris"]
        if isinstance(uris, str):
            uris = parse_addr_set(uris)
        iid = req.get("iid") or uuid.uuid4().hex[:12]
        with self._lock:
            prev = self.instances.get((service, iid))
            self.instances[(service, iid)] = {
                "uris": list(uris),
                "capacity": int(req.get("capacity", 0)),
                "load": float(req.get("load", 0.0)),
                "member_id": req.get("member_id"),
                "last": time.monotonic(),
            }
            # membership changed only if the instance is new or moved to
            # different addresses; a same-uris re-register (the report
            # loop's recovery path) must NOT bump the epoch, or every
            # recovery forces a fab.resolve storm across all pools
            if prev is None or prev["uris"] != list(uris):
                self.epoch += 1
                self._dirty.set()
            return {"iid": iid, "epoch": self.epoch}

    def _deregister(self, req):
        lead = self._leader_for_writes()
        if lead is not None:
            return self._proxy(lead, "fab.deregister", req)
        with self._lock:
            ok = self.instances.pop((req["service"], req["iid"]), None)
            if ok is not None:
                self.epoch += 1
                self._dirty.set()
            return {"ok": ok is not None, "epoch": self.epoch}

    def _report(self, req):
        lead = self._leader_for_writes()
        if lead is not None:
            return self._proxy(lead, "fab.report", req)
        with self._lock:
            inst = self.instances.get((req["service"], req["iid"]))
            if inst is None:
                # expired instance re-announcing: treat as a (re)register
                raise MercuryError(Ret.NOENTRY,
                                   f"unknown instance {req['iid']}; "
                                   f"re-register")
            inst["load"] = float(req.get("load", inst["load"]))
            if "capacity" in req:
                inst["capacity"] = int(req["capacity"])
            inst["last"] = time.monotonic()
            return {"epoch": self.epoch}

    def _resolve(self, req):
        service = req["service"]
        now = time.monotonic()
        with self._lock:
            out = [{"iid": iid, "uris": list(v["uris"]),
                    "capacity": v["capacity"], "load": v["load"],
                    "age": now - v["last"]}
                   for (s, iid), v in self.instances.items() if s == service]
            return {"epoch": self.epoch, "nonce": self.nonce,
                    "instances": out}

    def _services(self, _req):
        with self._lock:
            return {"epoch": self.epoch,
                    "services": sorted({s for (s, _) in self.instances})}

    def _epoch(self, _req):
        with self._lock:
            out = {"epoch": self.epoch, "nonce": self.nonce}
        out["leader"] = (self.self_uri if self.tracker is None
                         else self.tracker.leader_uri())
        return out

    def _status(self, _req):
        """Operator observability (docs/OPERATIONS.md): role, believed
        leaseholder, per-peer liveness, and table size."""
        with self._lock:
            base = {"self": self.self_uri, "nonce": self.nonce,
                    "epoch": self.epoch,
                    "instances": len(self.instances),
                    "services": sorted({s for (s, _) in self.instances})}
        if self.tracker is None:
            return dict(base, role="single", leader=self.self_uri,
                        peers=[])
        role = ("leader" if self._leading
                else "booting" if self.tracker.in_grace() else "follower")
        return dict(base, role=role, leader=self.tracker.leader_uri(),
                    peers=self.tracker.peer_stats())

    # -- gossip --------------------------------------------------------------
    def _snapshot_locked(self) -> dict:
        now = time.monotonic()
        return {"nonce": self.nonce, "epoch": self.epoch,
                "instances": [
                    {"service": s, "iid": iid, "uris": list(v["uris"]),
                     "capacity": v["capacity"], "load": v["load"],
                     "member_id": v["member_id"],
                     "age": now - v["last"]}
                    for (s, iid), v in self.instances.items()]}

    def _maybe_adopt(self, frm: str, snap: dict) -> None:
        """Adopt an acting leader's snapshot: full-state overwrite keyed
        by (nonce, epoch).  Adopted from lower-rank (higher-priority)
        peers always — that is also how a deposed leader steps down —
        and from *any* acting leader during boot grace, so a restarted
        high-priority replica resyncs before it reclaims the lease."""
        tr = self.tracker
        if tr is None:
            return
        if not (tr.in_grace() or tr.rank.get(frm, 99) <
                tr.rank[self.self_uri]):
            return
        with self._lock:
            if snap["nonce"] == self.nonce and snap["epoch"] < self.epoch:
                return                    # stale push of our own stream
            self._leading = False
            self.nonce = snap["nonce"]
            self.epoch = snap["epoch"]
            now = time.monotonic()
            self.instances = {
                (i["service"], i["iid"]): {
                    "uris": list(i["uris"]),
                    "capacity": int(i.get("capacity", 0)),
                    "load": float(i.get("load", 0.0)),
                    "member_id": i.get("member_id"),
                    "last": now - float(i.get("age", 0.0)),
                } for i in snap["instances"]}
        tr.mark_synced()

    def _gossip(self, req):
        """Peer-to-peer state exchange.  Leaders push full snapshots;
        followers heartbeat with their mirrored (nonce, epoch) and are
        answered with a snapshot whenever they are behind."""
        frm = req.get("from")
        if self.tracker is None or frm not in self.tracker.rank:
            raise MercuryError(Ret.INVALID_ARG,
                               f"gossip from unknown peer {frm!r}")
        self.tracker.note(frm)
        snap = req.get("snapshot")
        if snap is not None:
            self._maybe_adopt(frm, snap)
        with self._lock:
            resp = {"nonce": self.nonce, "epoch": self.epoch}
            if self._leading and (req.get("nonce") != self.nonce
                                  or req.get("epoch") != self.epoch):
                resp["snapshot"] = self._snapshot_locked()
        return resp

    def _gossip_loop(self) -> None:
        while not self._stop.is_set():
            dirty = self._dirty.wait(self.gossip_interval)
            self._dirty.clear()
            if self._stop.is_set():
                return
            try:
                self._gossip_tick(dirty)
            except Exception:
                pass                      # gossip must never die

    def _gossip_tick(self, dirty: bool = False) -> None:
        # Leadership changes hands in exactly two places: here (the
        # lease says every higher-priority peer is dead, or — after boot
        # grace — that we are the highest-priority survivor), and in
        # _maybe_adopt (a higher-priority peer's push deposes us).  An
        # acting leader does NOT step down merely because a
        # higher-priority peer reappeared: it keeps serving until that
        # peer has adopted its snapshot and taken over — otherwise a
        # restarted rank-0 replica could seize the lease with an empty
        # table before it resynced.
        if (self.tracker.leader_uri() == self.self_uri
                and not self._leading):
            self._take_over()
            dirty = True
        self._apply_pending_reaps()
        now = time.monotonic()
        with self._lock:
            payload = {"from": self.self_uri, "leader": self._leading,
                       "nonce": self.nonce, "epoch": self.epoch}
            # snapshot rides membership changes immediately and a slow
            # periodic cadence otherwise (mirrored loads stay fresh);
            # clean heartbeats carry only (nonce, epoch) — a follower
            # that is behind pulls a snapshot via the response path
            if self._leading and (dirty or now >= self._next_full_push):
                payload["snapshot"] = self._snapshot_locked()
                self._next_full_push = now + self._full_push_every
        # parallel fan-out, bounded well inside the lease: one
        # black-holed peer must not delay contact with live peers past
        # lease_ttl (serialized full-timeout probes would flap leases)
        futs = []
        for peer in self.tracker.others():
            try:
                futs.append((peer, self.engine.call_async(
                    peer, "fab.gossip", payload,
                    timeout=self._gossip_timeout)))
            except Exception:
                continue
        for peer, fut in futs:
            try:
                resp = fut.result(timeout=self._gossip_timeout + 0.25)
            except Exception:
                continue                  # lease decays on silence
            self.tracker.note(peer)
            snap = resp.get("snapshot") if isinstance(resp, dict) else None
            if snap is not None:
                self._maybe_adopt(peer, snap)

    # -- liveness ------------------------------------------------------------
    def _members_expired(self, member_ids: List[str]) -> None:
        """Member-expiry hook (``MembershipServer.on_expire``).  The
        leaseholder reaps directly; a follower-hosted membership server
        queues the member ids as *pending reaps* that the gossip loop
        applies/forwards until the instances are gone — a one-shot
        forward would lose the reap forever if it raced gossip (mirror
        not yet carrying the instance) or hit a leadership hiccup."""
        now = time.monotonic()
        with self._lock:
            for m in member_ids:
                # bounded memory + no poisoning of a future legitimate
                # re-registration: forget the reap after 2x instance TTL
                self._pending_reaps[m] = now + 2 * self.ttl
        self._dirty.set()                 # reap/forward promptly
        if self._leading:
            self._apply_pending_reaps()

    def _apply_pending_reaps(self) -> None:
        """Reap instances of expired members: delete locally when
        leading, else forward as deregisters to the leaseholder.
        Called from the expiry hook and retried every gossip tick until
        no instance matches a pending member id."""
        with self._lock:
            if not self._pending_reaps:
                return
            now = time.monotonic()
            self._pending_reaps = {m: t for m, t
                                   in self._pending_reaps.items()
                                   if t > now}
            pending = set(self._pending_reaps)
            dead = [k for k, v in self.instances.items()
                    if v["member_id"] in pending]
            if self._leading:
                for k in dead:
                    del self.instances[k]
                if dead:
                    self.epoch += 1
                    self._dirty.set()
                return
        if not dead:
            return
        try:
            lead = self._leader_for_writes()
        except MercuryError:
            return                        # unsettled: retried next tick
        for service, iid in dead:
            try:
                self.engine.call(lead, "fab.deregister",
                                 {"service": service, "iid": iid,
                                  "_proxied": True},
                                 timeout=self._proxy_timeout)
            except Exception:
                pass                      # retried next tick

    def _sweep_loop(self, interval: float) -> None:
        while not self._stop.wait(interval):
            if not self._leading:
                continue                  # followers mirror; only the
            now = time.monotonic()        # leaseholder expires instances
            with self._lock:
                dead = [k for k, v in self.instances.items()
                        if now - v["last"] > self.ttl]
                for k in dead:
                    del self.instances[k]
                if dead:
                    self.epoch += 1
                    self._dirty.set()

    def close(self) -> None:
        """Stop and join the sweeper and gossip threads (idempotent)."""
        self._stop.set()
        self._dirty.set()                 # wake a parked gossip loop
        if self._sweeper.is_alive():
            self._sweeper.join(timeout=2.0)
        if self._gossiper is not None and self._gossiper.is_alive():
            self._gossiper.join(timeout=2.0)

    stop = close


class RegistryClient:
    """Origin-side wrapper over the ``fab.*`` RPCs with replica failover.

    ``registry_uri`` is a registry *address set*: one endpoint per
    replica (list, or one comma-separated string).  Calls stick to the
    endpoint that last answered and rotate to the next replica on
    transport-class failures (dead peer, unsettled leadership) — any
    live replica can serve reads and proxies writes to the leaseholder,
    so the client never needs to know who leads.  Worst case a call
    probes every endpoint once (``len(uris) × timeout``)."""

    def __init__(self, engine: Engine, registry_uri, timeout: float = 10.0):
        self.engine = engine
        self.uris = parse_registry_uris(registry_uri)
        self.timeout = timeout
        self._idx = 0
        self._idx_lock = threading.Lock()

    @property
    def registry(self) -> str:
        """The currently preferred endpoint (observability/tests)."""
        with self._idx_lock:
            return self.uris[self._idx]

    def _call(self, name: str, req: dict):
        # One rotation over the endpoints; if every replica answered
        # AGAIN (leadership unsettled: cold-quorum boot grace, or the
        # lease mid-failover) the quorum is alive but momentarily
        # unwritable, so keep retrying within the call's own timeout
        # budget rather than surfacing a transient to the caller —
        # ServiceInstance/ServingGateway constructors race quorum
        # startup in any real deployment.
        deadline = time.monotonic() + self.timeout
        while True:
            with self._idx_lock:
                start = self._idx
            last: Optional[MercuryError] = None
            all_again = True
            for k in range(len(self.uris)):
                i = (start + k) % len(self.uris)
                try:
                    out = self.engine.call(self.uris[i], name, req,
                                           timeout=self.timeout)
                except MercuryError as e:
                    if e.ret not in _FAILOVER_RETS:
                        raise             # application error: surfaced
                    last = e
                    all_again = all_again and e.ret == Ret.AGAIN
                    continue
                with self._idx_lock:
                    self._idx = i         # sticky: keep the live replica
                return out
            if last is None:
                raise MercuryError(Ret.NOENTRY,
                                   "empty registry address set")
            if not all_again or time.monotonic() + 0.1 >= deadline:
                raise last
            time.sleep(0.1)               # unsettled leadership: re-probe

    def register(self, service: str, uris, capacity: int = 0,
                 load: float = 0.0, iid: Optional[str] = None,
                 member_id: Optional[str] = None) -> str:
        out = self._call("fab.register", {
            "service": service, "uris": uris, "capacity": capacity,
            "load": load, "iid": iid, "member_id": member_id,
        })
        return out["iid"]

    def deregister(self, service: str, iid: str) -> bool:
        return self._call("fab.deregister",
                          {"service": service, "iid": iid})["ok"]

    def report(self, service: str, iid: str, load: float,
               capacity: Optional[int] = None) -> int:
        req = {"service": service, "iid": iid, "load": load}
        if capacity is not None:
            req["capacity"] = capacity
        return self._call("fab.report", req)["epoch"]

    def resolve(self, service: str) -> dict:
        return self._call("fab.resolve", {"service": service})

    def services(self) -> List[str]:
        return self._call("fab.services", {})["services"]

    def epoch(self) -> int:
        return self._call("fab.epoch", {})["epoch"]

    def epoch_info(self) -> Tuple[int, Optional[str]]:
        """(epoch, nonce) — the cheap staleness poll.  Epochs from
        different nonces are not comparable (registry restarted, or the
        lease failed over to a new leader)."""
        out = self._call("fab.epoch", {})
        return out["epoch"], out.get("nonce")

    def status(self) -> dict:
        """``fab.status`` of the currently preferred replica."""
        return self._call("fab.status", {})


def resolve_service_uris(engine: Engine, registry_uri, service: str,
                         timeout: float = 10.0) -> List[str]:
    """Resolve ``service`` to its instances' address sets (one
    semicolon-joined string per instance, registry order).  The thin
    entry point for clients that want name resolution without a full
    :class:`~repro.fabric.pool.ServicePool` (checkpoint/datafeed).
    ``registry_uri`` may name one registry endpoint or the whole
    replica set (see :class:`RegistryClient`)."""
    view = RegistryClient(engine, registry_uri, timeout).resolve(service)
    if not view["instances"]:
        raise MercuryError(Ret.NOENTRY,
                           f"no live instances of service {service!r}")
    return [";".join(inst["uris"]) for inst in view["instances"]]


class ServiceInstance:
    """Self-registration helper for servers: registers this engine's
    address set under ``service`` and keeps the registration alive with
    periodic ``fab.report`` heartbeats carrying a live load sample.

    ``registry_uri`` may be a single endpoint or the replica set (the
    underlying :class:`RegistryClient` fails over).  ``load_fn`` returns
    the instance's current load (any float; the convention used by the
    built-in services is *outstanding work items*, e.g. active slots +
    queued requests).  ``close(deregister=False)`` simulates a crash:
    the reporter stops but the registry only learns via TTL/membership
    expiry — exactly the path the pool's failover covers.
    """

    def __init__(self, engine: Engine, registry_uri, service: str,
                 capacity: int = 0,
                 load_fn: Optional[Callable[[], float]] = None,
                 report_interval: float = 0.5,
                 member_id: Optional[str] = None,
                 uris: Optional[List[str]] = None):
        self.client = RegistryClient(engine, registry_uri)
        self.service = service
        self.load_fn = load_fn
        self.interval = report_interval
        self.uris = uris if uris is not None else engine.uri
        self.capacity = capacity
        self.member_id = member_id
        self._stop = threading.Event()
        # pre-generate the iid client-side: registration is then
        # idempotent, so a register retried after a lost response (or
        # re-proxied across a leader failover) can never mint a ghost
        # duplicate under a second iid
        self.iid = uuid.uuid4().hex[:12]
        self.client.register(
            service, self.uris, capacity=capacity, iid=self.iid,
            load=load_fn() if load_fn else 0.0, member_id=member_id)
        self._thread = threading.Thread(target=self._report_loop, daemon=True,
                                        name=f"fabric-report[{service}]")
        self._thread.start()

    def _report_loop(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                self.client.report(self.service, self.iid,
                                   self.load_fn() if self.load_fn else 0.0)
            except MercuryError:
                # registry expired us (e.g. long GC pause, or a leader
                # failover dropped state written during a partition):
                # re-register under the old iid
                try:
                    self.client.register(
                        self.service, self.uris, capacity=self.capacity,
                        load=self.load_fn() if self.load_fn else 0.0,
                        iid=self.iid, member_id=self.member_id)
                except Exception:
                    pass
            except Exception:
                pass            # registry briefly unreachable: keep trying

    def close(self, deregister: bool = True) -> None:
        if self._stop.is_set():
            return
        self._stop.set()
        self._thread.join(timeout=2.0)
        if deregister:
            try:
                self.client.deregister(self.service, self.iid)
            except Exception:
                pass
