"""Credit-based per-target flow control.

Each replica a :class:`~repro.fabric.pool.ServicePool` talks to gets a
:class:`CreditGate`: a fixed number of credits, one consumed per in-flight
RPC and returned on completion (success, failure, or cancel).  A slow
replica therefore saturates its credits and *sheds load into
backpressure* — callers either wait (bounded by their deadline), route to
another replica, or fail with a backpressure error — instead of queueing
unboundedly inside the transport.  The gate's occupancy doubles as a
live load signal for the balancers.
"""
from __future__ import annotations

import threading
import time
from typing import Dict


class CreditGate:
    """A counting gate with wait-with-timeout and observable occupancy
    (``threading.Semaphore`` hides its count, which the balancer needs)."""

    def __init__(self, credits: int):
        if credits < 1:
            raise ValueError(f"credits must be >= 1, got {credits}")
        self.credits = credits
        self._avail = credits
        self._waiting = 0
        self._cv = threading.Condition()
        # cumulative counters for pool stats
        self.acquired_total = 0
        self.backpressured_total = 0   # acquires that had to wait
        self.rejected_total = 0        # acquires that timed out

    # -- acquire / release ---------------------------------------------------
    def try_acquire(self) -> bool:
        with self._cv:
            if self._avail <= 0:
                return False
            self._avail -= 1
            self.acquired_total += 1
            return True

    def acquire(self, timeout: float) -> bool:
        """Take a credit, waiting up to ``timeout`` seconds.  Returns False
        on timeout (the caller should reroute or surface backpressure)."""
        with self._cv:
            if self._avail <= 0:
                self.backpressured_total += 1
                deadline = time.monotonic() + timeout
                self._waiting += 1
                try:
                    while self._avail <= 0:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0 or not self._cv.wait(remaining):
                            if self._avail > 0:
                                break
                            self.rejected_total += 1
                            return False
                finally:
                    self._waiting -= 1
            self._avail -= 1
            self.acquired_total += 1
            return True

    def release(self) -> None:
        with self._cv:
            if self._avail >= self.credits:
                raise RuntimeError("credit released more times than acquired")
            self._avail += 1
            self._cv.notify()

    # -- observability -------------------------------------------------------
    @property
    def inflight(self) -> int:
        with self._cv:
            return self.credits - self._avail

    @property
    def available(self) -> int:
        with self._cv:
            return self._avail

    @property
    def waiting(self) -> int:
        with self._cv:
            return self._waiting

    def stats(self) -> Dict[str, int]:
        with self._cv:
            return {"credits": self.credits,
                    "inflight": self.credits - self._avail,
                    "waiting": self._waiting,
                    "acquired": self.acquired_total,
                    "backpressured": self.backpressured_total,
                    "rejected": self.rejected_total}

    def __repr__(self):
        return (f"<CreditGate {self.credits - self._avail}"
                f"/{self.credits} in flight>")
