"""Credit-based per-target flow control.

Each replica a :class:`~repro.fabric.pool.ServicePool` talks to gets a
credit gate: a bounded number of credits, one consumed per in-flight RPC
and returned on completion (success, failure, or cancel).  A slow
replica therefore saturates its credits and *sheds load into
backpressure* — callers either wait (bounded by their deadline), route to
another replica, or fail with a backpressure error — instead of queueing
unboundedly inside the transport.  The gate's occupancy doubles as a
live load signal for the balancers.

Two gates:

  * :class:`CreditGate` — fixed limit (the PR-2 design).
  * :class:`AdaptiveCreditGate` — the limit itself is a control loop
    (Swift/BBR-style AIMD on EWMA latency): completions faster than the
    latency target grow the limit additively (~ +gain per limit's worth
    of completions, i.e. one credit per "RTT"), completions slower than
    the target shrink it multiplicatively (rate-limited to once per
    EWMA-latency window, so a single burst cannot collapse the window),
    and hard failures shrink it the same way.  The target defaults to
    ``headroom ×`` a decaying-minimum base latency, so each replica
    learns its own uncongested floor: fast replicas absorb more
    in-flight work, slow ones backpressure sooner, and a replica whose
    latency degrades mid-run gives credits back.

Invariants (pinned by tests/test_fabric_flow.py):

  * the limit never leaves ``[min_credits, max_credits]``;
  * acquires and releases balance: ``inflight == acquired - released``
    and every release had a matching acquire, whatever interleaving of
    completions, cancels and limit changes happens;
  * shrinking the limit below the current in-flight count never strands
    a credit — in-flight calls complete and release normally, new
    acquires just wait until occupancy drops below the limit again.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

from ..telemetry import metrics as _metrics

# unified metrics: process-wide totals across every gate instance (the
# per-gate view stays in stats(); fab.metrics exports these).  Gates are
# per-replica and ephemeral, so per-instance label cardinality would be
# unbounded — totals are the stable export.
_M_ACQUIRED = _metrics.counter("fabric.gate.acquired")
_M_BACKPRESSURED = _metrics.counter("fabric.gate.backpressured")
_M_REJECTED = _metrics.counter("fabric.gate.rejected")
_M_GROWN = _metrics.counter("fabric.gate.grown")
_M_SHRUNK = _metrics.counter("fabric.gate.shrunk")


class CreditGate:
    """A counting gate with wait-with-timeout and observable occupancy
    (``threading.Semaphore`` hides its count, which the balancer needs).

    Tracks *occupancy* (in-flight count) against a limit rather than a
    free-credit count, so subclasses may move the limit while calls are
    in flight without any bookkeeping debt."""

    def __init__(self, credits: int):
        if credits < 1:
            raise ValueError(f"credits must be >= 1, got {credits}")
        self._limit = float(credits)  #: guarded-by _cv
        self._inflight = 0  #: guarded-by _cv
        self._waiting = 0  #: guarded-by _cv
        self._cv = threading.Condition()
        # cumulative counters for pool stats
        self.acquired_total = 0  #: guarded-by _cv
        self.released_total = 0  #: guarded-by _cv
        self.backpressured_total = 0  #: guarded-by _cv
        self.rejected_total = 0  #: guarded-by _cv

    @property
    def credits(self) -> int:
        """The current integer credit limit."""
        with self._cv:
            return int(self._limit)

    # -- acquire / release ---------------------------------------------------
    def try_acquire(self) -> bool:
        with self._cv:
            if self._inflight >= int(self._limit):
                return False
            self._inflight += 1
            self.acquired_total += 1
            _M_ACQUIRED.inc()
            return True

    def acquire(self, timeout: float) -> bool:
        """Take a credit, waiting up to ``timeout`` seconds.  Returns False
        on timeout (the caller should reroute or surface backpressure)."""
        with self._cv:
            if self._inflight >= int(self._limit):
                self.backpressured_total += 1
                _M_BACKPRESSURED.inc()
                deadline = time.monotonic() + timeout
                self._waiting += 1
                try:
                    while self._inflight >= int(self._limit):
                        remaining = deadline - time.monotonic()
                        if remaining <= 0 or not self._cv.wait(remaining):
                            if self._inflight < int(self._limit):
                                break
                            self.rejected_total += 1
                            _M_REJECTED.inc()
                            return False
                finally:
                    self._waiting -= 1
            self._inflight += 1
            self.acquired_total += 1
            _M_ACQUIRED.inc()
            return True

    def release(self) -> None:
        with self._cv:
            if self._inflight <= 0:
                raise RuntimeError("credit released more times than acquired")
            self._inflight -= 1
            self.released_total += 1
            self._cv.notify()

    # -- observability -------------------------------------------------------
    @property
    def inflight(self) -> int:
        with self._cv:
            return self._inflight

    @property
    def available(self) -> int:
        with self._cv:
            return max(int(self._limit) - self._inflight, 0)

    @property
    def waiting(self) -> int:
        with self._cv:
            return self._waiting

    def stats(self) -> Dict[str, int]:
        with self._cv:
            return {"credits": int(self._limit),
                    "inflight": self._inflight,
                    "waiting": self._waiting,
                    "acquired": self.acquired_total,
                    "released": self.released_total,
                    "backpressured": self.backpressured_total,
                    "rejected": self.rejected_total}

    def __repr__(self):
        with self._cv:
            return (f"<{type(self).__name__} {self._inflight}"
                    f"/{int(self._limit)} in flight>")


class AdaptiveCreditGate(CreditGate):
    """A :class:`CreditGate` whose limit is driven by observed latency.

    AIMD on EWMA latency vs. a target (see the module docstring for the
    control law).  ``target_latency=None`` derives the target from a
    decaying minimum of observed latency (``headroom ×`` the learned
    uncongested floor); pass an explicit target to pin it (e.g. an SLO).
    """

    def __init__(self, credits: int, min_credits: int = 1,
                 max_credits: int = 64,
                 target_latency: Optional[float] = None,
                 headroom: float = 2.0, gain: float = 1.0,
                 decrease: float = 0.7, ewma_alpha: float = 0.3):
        if not 1 <= min_credits <= max_credits:
            raise ValueError(f"need 1 <= min_credits <= max_credits, got "
                             f"[{min_credits}, {max_credits}]")
        if not 0.0 < decrease < 1.0:
            raise ValueError(f"decrease must be in (0, 1), got {decrease}")
        super().__init__(min(max(credits, min_credits), max_credits))
        self.min_credits = min_credits
        self.max_credits = max_credits
        self.target_latency = target_latency
        self.headroom = headroom
        self.gain = gain
        self.decrease = decrease
        self.ewma_alpha = ewma_alpha
        self.ema = 0.0  #: guarded-by _cv       (EWMA completion latency, s)
        self.base: Optional[float] = None  #: guarded-by _cv (decaying-min floor)
        self.grown_total = 0  #: guarded-by _cv
        self.shrunk_total = 0  #: guarded-by _cv
        self._last_shrink = 0.0  #: guarded-by _cv

    # -- control law ---------------------------------------------------------
    def _target_locked(self) -> Optional[float]:
        if self.target_latency is not None:
            return self.target_latency
        return None if self.base is None else self.base * self.headroom

    def record_latency(self, dt: float,
                       now: Optional[float] = None) -> None:
        """Feed one successful-completion latency into the control loop."""
        if dt < 0:
            return
        now = time.monotonic() if now is None else now
        with self._cv:
            a = self.ewma_alpha
            self.ema = dt if not self.ema else a * dt + (1 - a) * self.ema
            # decaying min: snaps down on a new floor, drifts up slowly so
            # a permanently-degraded replica re-learns its baseline
            self.base = dt if self.base is None else \
                min(dt, self.base + 0.02 * max(dt - self.base, 0.0))
            target = self._target_locked()
            if target is None:
                return
            if self.ema <= target:
                before = int(self._limit)
                self._limit = min(self._limit + self.gain /
                                  max(self._limit, 1.0),
                                  float(self.max_credits))
                if int(self._limit) > before:
                    self.grown_total += 1
                    _M_GROWN.inc()
                    self._cv.notify_all()    # waiters may fit now
            else:
                self._shrink_locked(now)

    def record_failure(self, now: Optional[float] = None) -> None:
        """A hard failure (timeout, disconnect, overload shed) is the
        strongest congestion signal there is: multiplicative decrease."""
        now = time.monotonic() if now is None else now
        with self._cv:
            self._shrink_locked(now)

    def _shrink_locked(self, now: float) -> None:
        # at most one multiplicative decrease per EWMA-latency window —
        # a burst of late completions is ONE congestion event, not many
        if now - self._last_shrink < max(self.ema, 1e-3):
            return
        before = int(self._limit)
        self._limit = max(self._limit * self.decrease,
                          float(self.min_credits))
        self._last_shrink = now
        if int(self._limit) < before:
            self.shrunk_total += 1
            _M_SHRUNK.inc()

    def stats(self) -> Dict[str, int]:
        out = super().stats()
        with self._cv:
            target = self._target_locked()
            out.update(limit=round(self._limit, 2),
                       min_credits=self.min_credits,
                       max_credits=self.max_credits,
                       ema_ms=round(self.ema * 1e3, 3),
                       target_ms=(None if target is None
                                  else round(target * 1e3, 3)),
                       grown=self.grown_total, shrunk=self.shrunk_total)
        return out
