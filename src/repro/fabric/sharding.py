"""Sharded control plane: partition the instance table across ``M``
independent registry quorums by service-name hash (DESIGN.md §12).

The replicated registry (§8) removes the single-*node* ceiling but
still funnels every write through one leaseholder.  Sharding removes
the single-*quorum* ceiling: the name space is split across ``M``
independent :class:`~repro.fabric.replication.ReplicationCore` quorums,
each owning the full lifecycle (register / report / resolve / expiry)
of the services that hash to it.  Shards share nothing — no cross-shard
replication, no global epoch — so aggregate write throughput scales
with ``M`` and a failover on one shard never stalls the others.

The shard map is *static config*: a ``|``-separated list of address
sets, one per shard quorum::

    tcp://a:7700,tcp://b:7700|tcp://a:7701,tcp://b:7701

Placement is rendezvous (highest-random-weight) hashing over the shard
*indices*: every name scores each shard with a keyed blake2b digest and
lives on the highest scorer.  Growing the map from ``M`` to ``M+1``
shards only introduces a new candidate, so a name either stays put or
moves to the new shard — ~``1/(M+1)`` of names remap, never a full
reshuffle (tests/test_sharding.py proves stability, balance and
minimal movement as properties).

Token discipline: each shard is its own ``(nonce, epoch)`` authority.
:class:`ShardedRegistryClient` therefore keeps one
:class:`~repro.fabric.registry.RegistryClient` — and hence one
:class:`~repro.fabric.readcache.ReadCache` with its own token — per
shard, so a restart or failover on shard ``k`` evicts exactly shard
``k``'s cached reads and the other shards' caches stay authoritative
(never compare epochs across shards: they are independent counters
under independent nonces).
"""
from __future__ import annotations

import hashlib
import re
from typing import List, Optional, Sequence, Tuple, Union

from ..core.executor import Engine
from ..telemetry import metrics as _metrics
from .registry import RegistryClient

__all__ = [
    "SHARD_SEP", "shard_of", "parse_shard_spec", "format_shard_spec",
    "is_sharded", "membership_home", "shard_addr",
    "ShardedRegistryClient", "registry_client_for",
]

# Shard separator inside a registry address spec.  Each shard is a
# normal registry address set (comma-separated replica endpoints, each
# possibly ';'-joined multi-transport), so '|' is the only level left.
SHARD_SEP = "|"


def _score(service: str, shard: int) -> int:
    """Rendezvous weight of ``service`` on shard index ``shard``.

    Keyed blake2b — *not* Python's salted ``hash()`` — so the map is
    identical across processes, hosts and interpreter restarts.
    """
    h = hashlib.blake2b(f"{service}\x1fshard-{shard}".encode(),
                        digest_size=8)
    return int.from_bytes(h.digest(), "big")


def shard_of(service: str, shards: Union[int, Sequence]) -> int:
    """Owning shard index of ``service`` under an ``M``-shard map.

    ``shards`` is the shard count or any sized shard config (e.g. the
    list from :func:`parse_shard_spec`).  Deterministic across
    processes, balanced to ~1/M per shard, and monotone under growth:
    adding shard ``M`` only ever moves names *to* shard ``M``.

    >>> shard_of("embedder", 4) == shard_of("embedder", 4)
    True
    >>> shard_of("embedder", 1)
    0
    >>> all(shard_of(f"svc-{i}", 4) in range(4) for i in range(32))
    True
    """
    n = shards if isinstance(shards, int) else len(shards)
    if n < 1:
        raise ValueError("shard map must have at least one shard")
    if n == 1:
        return 0
    best, best_score = 0, -1
    for i in range(n):
        s = _score(service, i)
        if s > best_score:          # strict: ties break to lowest index
            best, best_score = i, s
    return best


def is_sharded(registry_uri) -> bool:
    """True if ``registry_uri`` is a multi-shard spec (contains '|')."""
    return isinstance(registry_uri, str) and SHARD_SEP in registry_uri


def parse_shard_spec(spec) -> List[str]:
    """Split a shard spec into per-shard address-set strings.

    Accepts a ``|``-separated string, a list of address-set strings, or
    a single unsharded address set (one-element result).
    """
    if isinstance(spec, str):
        parts = [p.strip() for p in spec.split(SHARD_SEP)]
    else:
        parts = [p if isinstance(p, str) else ",".join(p) for p in spec]
    parts = [p for p in parts if p]
    if not parts:
        raise ValueError(f"empty shard spec: {spec!r}")
    return parts


def format_shard_spec(shards: Sequence) -> str:
    """Inverse of :func:`parse_shard_spec`."""
    return SHARD_SEP.join(parse_shard_spec(shards))


def shard_addr(addr: str, k: int) -> str:
    """Shard ``k``'s address derived from a base address.

    The co-hosting convention shared by ``launch.registry --shards``,
    the scale benchmark and the operations guide: port-carrying
    endpoints get ``port + k``; name-based endpoints (``sm://`` /
    ``self://``) get a ``-k`` suffix.  Shard 0 is the base address
    itself.  Multi-transport (``;``-joined) sets offset each leg.

    >>> shard_addr("tcp://10.0.0.1:7700", 2)
    'tcp://10.0.0.1:7702'
    >>> shard_addr("sm://ctrl", 1)
    'sm://ctrl-1'
    >>> shard_addr("tcp://h:7700", 0)
    'tcp://h:7700'
    """
    if k == 0:
        return addr
    legs = []
    for leg in addr.split(";"):
        m = re.search(r":(\d+)$", leg)
        if m:
            legs.append(f"{leg[:m.start()]}:{int(m.group(1)) + k}")
        else:
            legs.append(f"{leg}-{k}")
    return ";".join(legs)


def membership_home(registry_uri) -> str:
    """The address set that hosts the membership table.

    Membership is *not* sharded — the member table describes hosts, not
    services, and stays far smaller than the instance table — so by
    convention it rides shard 0's quorum.  Unsharded specs (plain
    strings or endpoint lists) pass through unchanged, so callers can
    apply this unconditionally.
    """
    if not is_sharded(registry_uri):
        return registry_uri
    return parse_shard_spec(registry_uri)[0]


class ShardedRegistryClient:
    """Client for a sharded registry: fans ``fab.*`` calls to the
    owning shard and merges the cross-shard reads.

    Duck-type compatible with :class:`~repro.fabric.registry.
    RegistryClient` for every per-service operation (``register`` /
    ``deregister`` / ``report`` / ``resolve``), which route to the one
    shard that owns the service name.  ``services()`` fans out to all
    shards and returns the sorted union; ``status()`` / ``epoch_info``
    report per shard, because there is no global epoch to pretend to.

    Caching: one :class:`RegistryClient` (one read cache, one
    ``(nonce, epoch)`` token) per shard — see the module docstring for
    the token rules.
    """

    def __init__(self, engine: Engine, registry_uri, timeout: float = 10.0,
                 cache_ttl: float = 0.0):
        self.engine = engine
        self.shard_uris = parse_shard_spec(registry_uri)
        self.clients: List[RegistryClient] = [
            RegistryClient(engine, uris, timeout=timeout,
                           cache_ttl=cache_ttl)
            for uris in self.shard_uris
        ]
        self.timeout = timeout
        # per-shard call counters: 'shard' is bounded by the static map
        # size, well inside the cardinality policy (DESIGN.md §10)
        self._m_calls = [_metrics.counter("fabric.shard.calls", shard=i)
                         for i in range(len(self.clients))]

    # -- shard map ---------------------------------------------------------

    @property
    def nshards(self) -> int:
        return len(self.clients)

    def shard_of(self, service: str) -> int:
        """Owning shard index for ``service`` under this map."""
        return shard_of(service, self.clients)

    def client_for(self, service: str) -> RegistryClient:
        """The owning shard's plain client (single-shard callers such
        as :class:`~repro.fabric.pool.ServicePool` bind to this once
        and keep their whole refresh/token path unchanged)."""
        return self.clients[self.shard_of(service)]

    def _route(self, service: str) -> RegistryClient:
        shard = self.shard_of(service)
        self._m_calls[shard].inc()
        return self.clients[shard]

    # -- per-service ops: route to the owning shard ------------------------

    def register(self, service: str, uris, capacity: int = 0,
                 load: float = 0.0, iid: Optional[str] = None,
                 member_id: Optional[str] = None) -> str:
        return self._route(service).register(
            service, uris, capacity=capacity, load=load, iid=iid,
            member_id=member_id)

    def deregister(self, service: str, iid: str) -> bool:
        return self._route(service).deregister(service, iid)

    def report(self, service: str, iid: str, load: float,
               capacity: Optional[int] = None) -> int:
        return self._route(service).report(service, iid, load,
                                           capacity=capacity)

    def resolve(self, service: str, fresh: bool = False) -> dict:
        return self._route(service).resolve(service, fresh=fresh)

    # -- cross-shard reads -------------------------------------------------

    def services(self, fresh: bool = False) -> List[str]:
        """Sorted union of every shard's service list.

        Each shard's slice is fetched under that shard's own cache
        token, so the merge is a union of per-shard authoritative
        views — there is no cross-shard snapshot point (§12).
        """
        names = set()
        for i, client in enumerate(self.clients):
            self._m_calls[i].inc()
            names.update(client.services(fresh=fresh))
        return sorted(names)

    def epoch_info(self, fresh: bool = False
                   ) -> List[Tuple[int, Optional[str]]]:
        """Per-shard ``(epoch, nonce)`` list, shard order.  Tokens from
        different shards are never comparable with one another."""
        return [c.epoch_info(fresh=fresh) for c in self.clients]

    def status(self) -> dict:
        """``fab.status`` of every shard's preferred replica."""
        return {"shards": [c.status() for c in self.clients]}

    # -- cache plumbing ----------------------------------------------------

    def invalidate(self) -> None:
        """Drop every shard's cached reads (tokens survive)."""
        for c in self.clients:
            c.cache.invalidate()


def registry_client_for(engine: Engine, registry_uri,
                        service: Optional[str] = None,
                        timeout: float = 10.0, cache_ttl: float = 0.0):
    """Build the right registry client for an address spec.

    Unsharded specs get a plain :class:`RegistryClient`.  Sharded specs
    (``'|'`` present) get a :class:`ShardedRegistryClient` — unless
    ``service`` is given, in which case the caller only ever talks
    about one name and gets the *owning shard's* plain client directly:
    this is how :class:`~repro.fabric.pool.ServicePool` and
    :class:`~repro.fabric.registry.ServiceInstance` route through a
    sharded control plane with their epoch-poll and token logic
    untouched.
    """
    if not is_sharded(registry_uri):
        return RegistryClient(engine, registry_uri, timeout=timeout,
                              cache_ttl=cache_ttl)
    shards = parse_shard_spec(registry_uri)
    if service is not None:
        return RegistryClient(engine, shards[shard_of(service, shards)],
                              timeout=timeout, cache_ttl=cache_ttl)
    return ShardedRegistryClient(engine, shards, timeout=timeout,
                                 cache_ttl=cache_ttl)
