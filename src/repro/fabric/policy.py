"""Retry / deadline / hedging policy — the pure half of the service
fabric's call path.

The budget machinery is deliberately separated from transports and
threads: :func:`call_with_budget` drives attempts against an injected
``attempt_fn`` using injected ``clock``/``sleep``/``rand``, so the pool
uses it with the real clock while the property tests replay random
latency schedules on a simulated one (tests/test_fabric_policy.py).

Invariants the driver guarantees (and the property test checks):

  * at most ``policy.attempts`` attempts are ever issued;
  * every attempt's transport timeout is clamped to the time remaining
    until the caller's deadline, so the call returns (success or
    :class:`DeadlineExceeded`) no later than ``deadline`` — strictly
    tighter than the "deadline + one RPC timeout" bound a non-clamping
    design would give;
  * backoff sleeps never extend past the deadline.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, replace
from typing import Any, Callable, Optional

from ..core.types import MercuryError, Ret
from ..telemetry import metrics as _metrics

# unified metrics: budget-loop outcomes across every pool/caller (the
# loop itself stays pure — counters are clock-free)
_M_RETRIES = _metrics.counter("fabric.retry.retries")
_M_FAST_FAILOVERS = _metrics.counter("fabric.retry.fast_failovers")
_M_DEADLINE_EXCEEDED = _metrics.counter("fabric.retry.deadline_exceeded")
_M_BUDGET_EXHAUSTED = _metrics.counter("fabric.retry.budget_exhausted")


class FabricError(MercuryError):
    """Base for fabric call-path failures; carries the last per-attempt
    error (if any) as ``cause``."""

    def __init__(self, ret: Ret, detail: str = "",
                 cause: Optional[BaseException] = None):
        super().__init__(ret, detail)
        self.cause = cause


class DeadlineExceeded(FabricError):
    def __init__(self, detail: str = "", cause=None):
        super().__init__(Ret.TIMEOUT, detail, cause)


class BudgetExhausted(FabricError):
    """All budgeted attempts failed (each with a retryable error)."""

    def __init__(self, detail: str = "", cause=None):
        super().__init__(Ret.AGAIN, detail, cause)


class NonRetryable(Exception):
    """Wrap an attempt error to stop the retry loop immediately (the
    application handler faulted / rejected the call: retrying would
    re-execute non-idempotent work for the same result)."""

    def __init__(self, cause: BaseException):
        super().__init__(str(cause))
        self.cause = cause


@dataclass(frozen=True)
class RetryPolicy:
    """Per-call budget: attempts, per-attempt transport timeout, jittered
    exponential backoff, and optional request hedging."""

    attempts: int = 3            # total tries, including the first
    rpc_timeout: float = 5.0     # per-attempt transport timeout cap (s)
    backoff_base: float = 0.05   # first backoff (s)
    backoff_factor: float = 2.0
    backoff_max: float = 1.0
    jitter: float = 0.5          # fraction of the backoff randomized away
    hedge_after: Optional[float] = None   # issue a 2nd replica's attempt
                                          # if no reply within this (s)
    # errors that skip the backoff sleep entirely: an admission-control
    # shed (OVERLOAD) is a sub-millisecond fast-fail whose remedy is a
    # *different replica*, not a later retry against the same one —
    # backing off would burn exactly the deadline budget the shed was
    # protecting.  (The attempt budget still applies.)
    fast_rets: frozenset = frozenset({Ret.OVERLOAD})

    def with_(self, **kw) -> "RetryPolicy":
        return replace(self, **kw)

    def attempt_timeout(self, now: float, deadline: float) -> float:
        """Transport timeout for an attempt starting at ``now``: the cap,
        clamped to the time remaining before the caller's deadline."""
        return max(min(self.rpc_timeout, deadline - now), 0.0)

    def backoff(self, attempt: int, rand: float) -> float:
        """Backoff before attempt ``attempt`` (1-based retry index), with
        ``rand`` in [0, 1) supplying the jitter."""
        raw = min(self.backoff_base * (self.backoff_factor ** (attempt - 1)),
                  self.backoff_max)
        return raw * (1.0 - self.jitter * rand)


def call_with_budget(policy: RetryPolicy, deadline: float,
                     attempt_fn: Callable[[int, float], Any],
                     clock: Callable[[], float] = time.monotonic,
                     sleep: Callable[[float], None] = time.sleep,
                     rand: Callable[[], float] = random.random) -> Any:
    """Run ``attempt_fn(attempt_index, timeout)`` under the policy's
    budget.  ``attempt_fn`` returns the call's value or raises; a raised
    :class:`NonRetryable` aborts immediately with its cause, anything
    else consumes one attempt from the budget.
    """
    last: Optional[BaseException] = None
    for attempt in range(policy.attempts):
        now = clock()
        timeout = policy.attempt_timeout(now, deadline)
        if timeout <= 0:
            _M_DEADLINE_EXCEEDED.inc()
            raise DeadlineExceeded(
                f"deadline expired before attempt {attempt + 1}", last)
        try:
            return attempt_fn(attempt, timeout)
        except NonRetryable as e:
            raise e.cause
        except Exception as e:        # KeyboardInterrupt etc. propagate
            last = e
        if attempt + 1 >= policy.attempts:
            break
        _M_RETRIES.inc()
        if getattr(last, "ret", None) in policy.fast_rets:
            _M_FAST_FAILOVERS.inc()
            continue                  # fast failover: re-rank immediately
        pause = min(policy.backoff(attempt + 1, rand()),
                    max(deadline - clock(), 0.0))
        if pause > 0:
            sleep(pause)
    _M_BUDGET_EXHAUSTED.inc()
    raise BudgetExhausted(
        f"all {policy.attempts} attempts failed: {last}", last)
