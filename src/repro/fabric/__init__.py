"""Service fabric (paper §"extreme-scale services"): registry-backed
service pools with load-balanced, locality-aware routing, per-call
deadlines/retries/hedging, credit-based flow control, and a unified
replicated control plane — a generic replicated-table core (leader
lease + delta gossip) hosting the registry's instance table and the
membership service's member table on every quorum node.

See DESIGN.md §7 for the registry schema, the balancer contract and the
credit/flow-control state machine, and §8 for the replication protocol;
docs/OPERATIONS.md is the operator's guide.
"""
from .affinity import SessionAffinity
from .balancer import (BALANCERS, Balancer, EwmaWeighted, LeastLoaded,
                       LocalityAware, RoundRobin, make_balancer,
                       prefer_instance)
from .flow import AdaptiveCreditGate, CreditGate
from .policy import (BudgetExhausted, DeadlineExceeded, FabricError,
                     NonRetryable, RetryPolicy, call_with_budget)
from .pool import PoolError, Replica, ServicePool
from .readcache import ReadCache, args_digest
from .registry import (RegistryClient, RegistryService, ServiceInstance,
                       resolve_service_uris)
from .replication import (PeerTracker, QuorumCaller, ReplicatedTable,
                          ReplicationCore, parse_registry_uris)
from .sharding import (ShardedRegistryClient, membership_home,
                       parse_shard_spec, registry_client_for, shard_of)

__all__ = [
    "Balancer", "BALANCERS", "RoundRobin", "LeastLoaded", "LocalityAware",
    "EwmaWeighted", "make_balancer", "prefer_instance", "SessionAffinity",
    "CreditGate", "AdaptiveCreditGate",
    "RetryPolicy", "call_with_budget",
    "FabricError", "DeadlineExceeded", "BudgetExhausted", "NonRetryable",
    "ServicePool", "PoolError", "Replica", "RegistryService",
    "RegistryClient", "ServiceInstance", "resolve_service_uris",
    "PeerTracker", "QuorumCaller", "ReplicatedTable", "ReplicationCore",
    "parse_registry_uris", "ReadCache", "args_digest",
    "shard_of", "parse_shard_spec", "membership_home",
    "ShardedRegistryClient", "registry_client_for",
]
