"""Client-side idempotent read cache (DESIGN.md §9).

Control-plane reads — ``fab.resolve``, ``fab.epoch``, ``mem.view``,
``ckpt.list`` — are *declared idempotent*: within one authoritative
``(nonce, epoch)`` token they always return the same answer, so a
client that issues them in a hot loop (every pool refresh tick, every
hedged attempt) is paying registry round-trips for bytes it already
holds.  :class:`ReadCache` collapses those calls:

  * entries are keyed ``(method, args-digest)`` where the digest is the
    proc encoding of the arguments — the same canonical form the wire
    would carry, so two calls that would serialize identically share an
    entry;
  * an entry is valid only while (a) its ``(nonce, epoch)`` token
    matches the last token observed from the authority and (b) its TTL
    has not lapsed.  Epoch bumps, nonce changes (registry restart,
    leader failover) and TTL expiry each evict — there is no path that
    serves a read from a superseded epoch stream;
  * concurrent misses on one key **singleflight**: the first caller
    runs the fetch, everyone else waits on its future.  Only a
    *successful* result populates the cache — a fetch that fails (or is
    canceled, e.g. a hedged loser) propagates to its waiters and caches
    nothing, so a canceled loser can never poison later reads.

The cache is deliberately a dumb value store: invalidation is driven
entirely by the token its owner feeds via :meth:`observe` (clients call
it with every epoch they learn — from ``fab.epoch`` polls *and* from
write responses, so a client observes its own writes immediately).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from hashlib import blake2b
from typing import Any, Callable, Dict, Optional, Tuple

from ..core import proc as hg_proc
from ..telemetry import metrics as _metrics

# (nonce, epoch) pair identifying one point in one authoritative stream
Token = Tuple[Optional[str], int]

# unified metrics: process-wide totals across every cache instance
# (per-instance detail stays in stats(); fab.metrics exports these)
_M_HITS = _metrics.counter("fabric.readcache.hits")
_M_MISSES = _metrics.counter("fabric.readcache.misses")
_M_EVICTIONS = _metrics.counter("fabric.readcache.evictions")


def args_digest(method: str, args: Any) -> bytes:
    """Canonical cache key for an RPC read: digest of the proc encoding
    of ``(method, args)`` — exactly what the wire would carry."""
    enc = hg_proc.encode(hg_proc.proc_any, {"m": method, "a": args})
    return blake2b(bytes(enc), digest_size=16).digest()


class ReadCache:
    """TTL + token keyed cache with singleflight collapse.

    ``ttl`` bounds how long a hit may be served without re-checking the
    authority even when no invalidation arrived (the freshness bound for
    staleness the token cannot see, e.g. load values that do not bump
    the epoch).  ``ttl=0`` disables caching entirely (every read goes
    through) while keeping singleflight collapse for concurrent misses.
    """

    def __init__(self, ttl: float = 0.25, max_entries: int = 256):
        self.ttl = ttl
        self.max_entries = max_entries
        self._lock = threading.Lock()
        self._token: Token = (None, -1)  #: guarded-by _lock
        # key -> (token, expires_at, value)
        self._entries: Dict[bytes, Tuple[Token, float, Any]] = {}  #: guarded-by _lock
        self._inflight: Dict[bytes, Future] = {}  #: guarded-by _lock
        self._hits = 0  #: guarded-by _lock
        self._misses = 0  #: guarded-by _lock
        self._evictions = 0  #: guarded-by _lock

    # -- invalidation --------------------------------------------------------
    def observe(self, nonce: Optional[str], epoch: int) -> bool:
        """Feed the latest authoritative ``(nonce, epoch)``.  Advancing
        the token (new nonce, or higher epoch on the same nonce) evicts
        every cached entry; returns True if it did.  A *lower* epoch on
        the same nonce is a stale read racing a newer one — ignored."""
        with self._lock:
            cur = self._token
            if nonce == cur[0] and epoch <= cur[1]:
                return False
            self._token = (nonce, epoch)
            if self._entries:
                self._evictions += len(self._entries)
                _M_EVICTIONS.inc(len(self._entries))
                self._entries.clear()
            return True

    def observe_epoch(self, epoch: int) -> bool:
        """Observe an epoch on the *current* nonce (write responses
        carry the epoch but not the nonce)."""
        with self._lock:
            nonce = self._token[0]
        return self.observe(nonce, epoch)

    def invalidate(self) -> None:
        """Drop every entry without advancing the token (e.g. a client
        that just wrote through a path whose new epoch it cannot see)."""
        with self._lock:
            self._evictions += len(self._entries)
            _M_EVICTIONS.inc(len(self._entries))
            self._entries.clear()

    # -- lookup --------------------------------------------------------------
    def get_or_call(self, method: str, args: Any,
                    fetch: Callable[[], Any], fresh: bool = False,
                    token_of: Optional[Callable[[Any], Token]] = None) -> Any:
        """Serve ``(method, args)`` from cache, or run ``fetch()`` once
        (singleflighted across threads) and cache its result under the
        current token.  ``fresh=True`` bypasses the cached value but
        still populates (and still collapses concurrent fetches).

        ``token_of(value)`` extracts the authoritative ``(nonce,
        epoch)`` carried *in the response* (e.g. ``fab.resolve`` returns
        both): the result is observed — advancing the cache token and
        evicting anything older — and then cached under its own token,
        so a read that itself reveals an epoch bump both invalidates the
        stale view and seeds the fresh one."""
        key = args_digest(method, args)
        while True:
            with self._lock:
                if not fresh and self.ttl > 0:
                    ent = self._entries.get(key)
                    if ent is not None:
                        token, expires, value = ent
                        if token == self._token and time.monotonic() < expires:
                            self._hits += 1
                            _M_HITS.inc()
                            return value
                        self._entries.pop(key, None)
                        self._evictions += 1
                        _M_EVICTIONS.inc()
                fut = self._inflight.get(key)
                if fut is None:
                    fut = Future()
                    self._inflight[key] = fut
                    owner = True
                else:
                    owner = False
                token = self._token
            if not owner:
                # another thread is fetching this key: ride its result.
                # Its failure propagates here too — both callers see the
                # same error, neither caches it.
                return fut.result()
            try:
                value = fetch()
            except BaseException as e:
                with self._lock:
                    self._inflight.pop(key, None)
                fut.set_exception(e)
                raise
            if token_of is not None:
                token = token_of(value)
                self.observe(*token)
            with self._lock:
                self._inflight.pop(key, None)
                # populate only under the *current* token — a result
                # raced by a newer invalidation may be from either side
                # of the bump, so it must not stick
                if self.ttl > 0 and token == self._token:
                    if len(self._entries) >= self.max_entries:
                        self._entries.pop(next(iter(self._entries)))
                        self._evictions += 1
                        _M_EVICTIONS.inc()
                    self._entries[key] = (token, time.monotonic() + self.ttl,
                                          value)
                self._misses += 1
                _M_MISSES.inc()
            fut.set_result(value)
            return value

    # -- observability -------------------------------------------------------
    def token(self) -> Token:
        """The current ``(nonce, epoch)`` authority token.  One token
        per cache instance: sharded clients hold one cache per shard
        precisely so these never mix (DESIGN.md §12)."""
        with self._lock:
            return self._token

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self._hits, "misses": self._misses,
                    "evictions": self._evictions,
                    "entries": len(self._entries),
                    "token": {"nonce": self._token[0],
                              "epoch": self._token[1]}}

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)
