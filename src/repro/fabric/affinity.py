"""Session-affine routing over a :class:`~repro.fabric.pool.ServicePool`.

Serving replicas keep per-conversation state worth returning to: the
engine pins a finished request's KV cache under its ``session_id``
(serve/engine.py), so a follow-up turn that lands on the *same* replica
re-prefills only the new tokens.  :class:`SessionAffinity` is the client
half of that contract — a small LRU map ``session_id → iid`` layered
over ``call_routed``:

  * **first turn**: no mapping — the pool's balancer routes normally and
    the winning iid is remembered;
  * **follow-up**: the remembered iid is passed as ``prefer=`` (soft
    affinity: front of the candidate ranking, NOT a pin);
  * **fallback**: if the preferred replica is dead, deregistered, shed
    the call, or lost the race to a hedge, the call lands wherever the
    balancer sends it — the serve there misses its session cache and
    does a fresh full prefill.  Correct, just slower; the map is then
    updated to the new home (a recorded ``move``).

Affinity is an *optimization hint* end to end: the engine never trusts a
hit (it verifies the cached token prefix), and this layer never insists
on a replica.  Losing every mapping (client restart, LRU overflow) costs
re-prefills, not errors.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Dict, Optional

from ..telemetry import metrics as _metrics
from .pool import ServicePool

_M_HITS = _metrics.counter("fabric.affinity.hits")
_M_MISSES = _metrics.counter("fabric.affinity.misses")
_M_MOVES = _metrics.counter("fabric.affinity.moves")


class SessionAffinity:
    """LRU ``session_id → iid`` map steering follow-up calls back to the
    replica that holds the session's KV cache."""

    def __init__(self, pool: ServicePool, capacity: int = 4096):
        self.pool = pool
        self.capacity = capacity
        self._map: "OrderedDict[str, str]" = OrderedDict()  #: guarded-by _lock
        self._lock = threading.Lock()
        self.hits = 0     #: guarded-by _lock
        self.misses = 0   #: guarded-by _lock
        self.moves = 0    #: guarded-by _lock — follow-up served elsewhere

    def lookup(self, session_id: str) -> Optional[str]:
        with self._lock:
            iid = self._map.get(session_id)
            if iid is not None:
                self._map.move_to_end(session_id)
        return iid

    def _record(self, session_id: str, prefer: Optional[str],
                iid: Optional[str]) -> None:
        if iid is None:
            return
        with self._lock:
            if prefer is None:
                self.misses += 1
                _M_MISSES.inc()
            elif prefer == iid:
                self.hits += 1
                _M_HITS.inc()
            else:
                self.moves += 1          # preferred replica unavailable:
                _M_MOVES.inc()           # session re-homed, fresh prefill
            self._map[session_id] = iid
            self._map.move_to_end(session_id)
            while len(self._map) > self.capacity:
                self._map.popitem(last=False)

    def call_routed(self, session_id: str, rpc: str, arg: Any = None,
                    **kw) -> tuple:
        """Affine :meth:`ServicePool.call_routed`: returns
        ``(value, iid)`` and updates the session's home to ``iid``."""
        prefer = self.lookup(session_id)
        value, iid = self.pool.call_routed(rpc, arg, prefer=prefer, **kw)
        self._record(session_id, prefer, iid)
        return value, iid

    def call(self, session_id: str, rpc: str, arg: Any = None, **kw) -> Any:
        return self.call_routed(session_id, rpc, arg, **kw)[0]

    def forget(self, session_id: str) -> None:
        """Drop a mapping (conversation ended / server reported the
        session evicted) — the next turn routes by the balancer."""
        with self._lock:
            self._map.pop(session_id, None)

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"sessions": len(self._map), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "moves": self.moves}
