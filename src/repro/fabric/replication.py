"""Replication machinery for the registry control plane.

The replicated registry (DESIGN.md §8) runs N :class:`RegistryService`
instances over a **static, ordered peer list** shared by every node —
list order *is* leadership priority.  This module holds the pure
bookkeeping half of the protocol:

  * :class:`PeerTracker` — deterministic leader-lease state.  A peer is
    *live* while it was heard from within ``lease_ttl`` seconds; the
    leader is the live peer with the lowest rank.  Liveness starts
    optimistic (every peer is assumed alive at boot) so a restarting
    replica never steals leadership before the incumbent's lease had a
    chance to renew, and a **boot grace** window defers self-election
    until the newcomer has either adopted a snapshot from an acting
    leader or waited a full lease out — a restarted rank-0 replica
    therefore *resyncs before it leads* instead of resurrecting with an
    empty table.
  * :func:`parse_registry_uris` — the registry *address set* parser
    shared by :class:`~repro.fabric.registry.RegistryClient` and the
    launchers: one endpoint per replica, comma-separated (each endpoint
    may itself be a ``;``-joined multi-transport address set, see
    DESIGN.md §2).

The wire half (``fab.gossip`` push/pull, write proxying, snapshot
adoption) lives in :mod:`repro.fabric.registry`, which drives this
tracker from its gossip loop.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Sequence


def parse_registry_uris(spec) -> List[str]:
    """Parse a registry address set: a sequence of endpoint URIs, or one
    comma-separated string (``"tcp://a:7700,tcp://b:7700"``).  Each
    endpoint may itself be a ``;``-joined multi-transport address set.

    >>> parse_registry_uris("tcp://a:7700, tcp://b:7700")
    ['tcp://a:7700', 'tcp://b:7700']
    >>> parse_registry_uris(["sm://reg0;tcp://a:7700"])
    ['sm://reg0;tcp://a:7700']
    """
    if isinstance(spec, str):
        parts = [p.strip() for p in spec.split(",")]
    else:
        parts = [str(p).strip() for p in spec]
    uris = [p for p in parts if p]
    if not uris:
        raise ValueError(f"empty registry address set: {spec!r}")
    return uris


class PeerTracker:
    """Deterministic leader-lease state over a static ordered peer list.

    Thread-safe; all times come from the injected ``clock`` (monotonic)
    so tests can drive the lease deterministically.
    """

    def __init__(self, peers: Sequence[str], self_uri: str,
                 lease_ttl: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        peers = list(peers)
        if self_uri not in peers:
            raise ValueError(f"self_uri {self_uri!r} is not in the peer "
                             f"list {peers!r} — every replica must be "
                             f"started with the same ordered --peers list "
                             f"and its own entry as --listen/--self")
        if len(set(peers)) != len(peers):
            raise ValueError(f"duplicate entries in peer list {peers!r}")
        self.peers = peers
        self.self_uri = self_uri
        self.rank: Dict[str, int] = {u: i for i, u in enumerate(peers)}
        self.lease_ttl = lease_ttl
        self._clock = clock
        now = clock()
        # optimistic start: assume everyone is alive until a full lease
        # passes without contact (prevents takeover storms at boot)
        self._last_heard: Dict[str, float] = {
            u: now for u in peers if u != self_uri}
        # boot grace: do not self-elect until we either adopted a
        # snapshot from an acting leader or waited one lease out
        self._boot_until = now + lease_ttl
        self._synced = False
        self._lock = threading.Lock()

    # -- liveness ------------------------------------------------------------
    def note(self, uri: str) -> None:
        """Record contact with ``uri`` (either direction of gossip)."""
        with self._lock:
            if uri in self._last_heard:
                self._last_heard[uri] = self._clock()

    def mark_synced(self) -> None:
        """We adopted an acting leader's snapshot: boot grace is over."""
        with self._lock:
            self._synced = True

    def in_grace(self) -> bool:
        with self._lock:
            return not self._synced and self._clock() < self._boot_until

    def others(self) -> List[str]:
        return [u for u in self.peers if u != self.self_uri]

    # -- leadership ----------------------------------------------------------
    def leader_uri(self):
        """The current leaseholder: the lowest-rank live peer.  ``None``
        while we are still in boot grace and every lower-rank peer looks
        dead (leadership is unknowable until the grace resolves)."""
        now = self._clock()
        grace = self.in_grace()
        with self._lock:
            for uri in self.peers:
                if uri == self.self_uri:
                    if grace:
                        continue          # defer: an acting leader may exist
                    return uri
                if now - self._last_heard[uri] <= self.lease_ttl:
                    return uri
            return None if grace else self.self_uri

    def peer_stats(self) -> List[dict]:
        now = self._clock()
        with self._lock:
            out = []
            for uri in self.peers:
                if uri == self.self_uri:
                    out.append({"uri": uri, "self": True, "alive": True,
                                "age_s": 0.0})
                else:
                    age = now - self._last_heard[uri]
                    out.append({"uri": uri, "self": False,
                                "alive": age <= self.lease_ttl,
                                "age_s": round(age, 3)})
            return out
