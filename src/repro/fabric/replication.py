"""Replication machinery for the fabric's control plane.

The replicated control plane (DESIGN.md §8) runs N engines over a
**static, ordered peer list** shared by every node — list order *is*
leadership priority.  PR 4 built the protocol for the registry's
instance table; this module is the table-agnostic extraction, so the
registry's instance table and the membership service's member table are
now just two :class:`ReplicatedTable` instances hosted by one
:class:`ReplicationCore` per node:

  * :class:`PeerTracker` — deterministic leader-lease state.  A peer is
    *live* while it was heard from within ``lease_ttl`` seconds; the
    leader is the live peer with the lowest rank.  Liveness starts
    optimistic (every peer is assumed alive at boot) so a restarting
    replica never steals leadership before the incumbent's lease had a
    chance to renew, and a **boot grace** window defers self-election
    until the newcomer has either adopted a snapshot from an acting
    leader or waited a full lease out — a restarted rank-0 replica
    therefore *resyncs before it leads* instead of resurrecting with an
    empty table.
  * :class:`ReplicatedTable` — one named, versioned ``key -> record``
    table.  Every membership-meaningful mutation (put/delete/expiry)
    stamps the entry with the table's next **version** (the version
    counter *is* the table epoch), and deletions leave tombstones so a
    leader can ship **deltas**: only the entries whose version exceeds
    what a peer last acknowledged.  Load/liveness updates are *soft*
    state: they bump no version (no client resolve storms, no delta
    churn) and ride gossip only when a value actually changed.
  * :class:`ReplicationCore` — hosts the tables on one engine and keeps
    them replicated: leader lease (via the tracker), delta gossip with
    automatic full-snapshot fallback, one-hop write proxying, takeover
    (fresh nonce + liveness refresh so failover never mass-expires),
    and the single TTL sweeper that expires stale entries *on the
    leaseholder only* and fires each table's expiry hooks there.
    With ``peers=None`` the core degrades to a single-node control
    plane: always leading, no gossip thread, same table API.
  * :class:`QuorumCaller` — client-side sticky failover over a
    control-plane *address set* (one endpoint per replica), shared by
    :class:`~repro.fabric.registry.RegistryClient` and
    :class:`~repro.services.membership.MembershipClient`.
  * :func:`parse_registry_uris` — the address-set parser (one endpoint
    per replica, comma-separated; each endpoint may itself be a
    ``;``-joined multi-transport address set, see DESIGN.md §2).

The wire half (``fab.*`` / ``mem.*`` request schemas) lives with the
services that own each table (:mod:`repro.fabric.registry`,
:mod:`repro.services.membership`); the shared ``fab.gossip`` stream is
driven entirely by the core.
"""
from __future__ import annotations

import threading
import time
import uuid
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core import proc as hg_proc
from ..core.types import MercuryError, Ret
from ..telemetry import trace as _trace

# transport-class failures that mean "this control-plane endpoint (or
# the proxy path behind it) is unreachable/unsettled — try another
# replica"; application errors (NOENTRY from fab.report, INVALID_ARG,
# ...) must pass through: the handler ran.
FAILOVER_RETS = {Ret.TIMEOUT, Ret.DISCONNECT, Ret.AGAIN, Ret.CANCELED,
                 Ret.PROTOCOL_ERROR}


def parse_registry_uris(spec) -> List[str]:
    """Parse a control-plane address set: a sequence of endpoint URIs,
    or one comma-separated string (``"tcp://a:7700,tcp://b:7700"``).
    Each endpoint may itself be a ``;``-joined multi-transport address
    set.

    >>> parse_registry_uris("tcp://a:7700, tcp://b:7700")
    ['tcp://a:7700', 'tcp://b:7700']
    >>> parse_registry_uris(["sm://reg0;tcp://a:7700"])
    ['sm://reg0;tcp://a:7700']
    """
    if isinstance(spec, str):
        parts = [p.strip() for p in spec.split(",")]
    else:
        parts = [str(p).strip() for p in spec]
    uris = [p for p in parts if p]
    if not uris:
        raise ValueError(f"empty registry address set: {spec!r}")
    return uris


class PeerTracker:
    """Deterministic leader-lease state over a static ordered peer list.

    Thread-safe; all times come from the injected ``clock`` (monotonic)
    so tests can drive the lease deterministically.
    """

    def __init__(self, peers: Sequence[str], self_uri: str,
                 lease_ttl: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        peers = list(peers)
        if self_uri not in peers:
            raise ValueError(f"self_uri {self_uri!r} is not in the peer "
                             f"list {peers!r} — every replica must be "
                             f"started with the same ordered --peers list "
                             f"and its own entry as --listen/--self")
        if len(set(peers)) != len(peers):
            raise ValueError(f"duplicate entries in peer list {peers!r}")
        self.peers = peers
        self.self_uri = self_uri
        self.rank: Dict[str, int] = {u: i for i, u in enumerate(peers)}
        self.lease_ttl = lease_ttl
        self._clock = clock
        now = clock()
        # optimistic start: assume everyone is alive until a full lease
        # passes without contact (prevents takeover storms at boot)
        self._last_heard: Dict[str, float] = {
            u: now for u in peers if u != self_uri}  #: guarded-by _lock
        # boot grace: do not self-elect until we either adopted a
        # snapshot from an acting leader or waited one lease out
        self._boot_until = now + lease_ttl  #: guarded-by _lock
        self._synced = False  #: guarded-by _lock
        self._lock = threading.Lock()

    # -- liveness ------------------------------------------------------------
    def note(self, uri: str) -> None:
        """Record contact with ``uri`` (either direction of gossip)."""
        with self._lock:
            if uri in self._last_heard:
                self._last_heard[uri] = self._clock()

    def mark_synced(self) -> None:
        """We adopted an acting leader's snapshot: boot grace is over."""
        with self._lock:
            self._synced = True

    def in_grace(self) -> bool:
        with self._lock:
            return not self._synced and self._clock() < self._boot_until

    def is_alive(self, uri: str) -> bool:
        """Lease check for one peer (self is always alive)."""
        with self._lock:
            if uri not in self._last_heard:
                return uri == self.self_uri
            return self._clock() - self._last_heard[uri] <= self.lease_ttl

    def others(self) -> List[str]:
        return [u for u in self.peers if u != self.self_uri]

    # -- leadership ----------------------------------------------------------
    def leader_uri(self):
        """The current leaseholder: the lowest-rank live peer.  ``None``
        while we are still in boot grace and every lower-rank peer looks
        dead (leadership is unknowable until the grace resolves)."""
        now = self._clock()
        grace = self.in_grace()
        with self._lock:
            for uri in self.peers:
                if uri == self.self_uri:
                    if grace:
                        continue          # defer: an acting leader may exist
                    return uri
                if now - self._last_heard[uri] <= self.lease_ttl:
                    return uri
            return None if grace else self.self_uri

    def peer_stats(self) -> List[dict]:
        now = self._clock()
        with self._lock:
            out = []
            for uri in self.peers:
                if uri == self.self_uri:
                    out.append({"uri": uri, "self": True, "alive": True,
                                "age_s": 0.0})
                else:
                    age = now - self._last_heard[uri]
                    out.append({"uri": uri, "self": False,
                                "alive": age <= self.lease_ttl,
                                "age_s": round(age, 3)})
            return out


class ReplicatedTable:
    """One replicated ``key -> record`` table (DESIGN.md §8).

    Records are plain dicts; the table owns one bookkeeping field,
    ``last`` (monotonic stamp of the last liveness touch — shipped as
    ``age`` on the wire so mirrored stamps survive clock domains).

    **Version stamps**: the table epoch is a per-table version counter.
    ``put``/``delete`` (and TTL expiry) assign the entry the next
    version; a leader can therefore answer "what changed since version
    v" exactly — the **delta** — as long as every deletion with version
    > v is still held as a tombstone.  Tombstones are garbage-collected
    after ``tombstone_ttl``; the *horizon* records the newest GC'd
    deletion, and a delta request from before the horizon returns
    ``None`` — the caller must fall back to a full snapshot.

    ``update`` is the *soft* path: load/liveness refreshes that must
    not bump the epoch (clients would resolve-storm) and must not
    create delta traffic unless a value actually changed.

    Mutators are leader-only by contract; followers converge via
    :meth:`install` (snapshot) and :meth:`apply_delta`, both driven by
    the :class:`ReplicationCore` gossip.  All methods take the lock the
    core shared at construction (reentrant — handlers may compose
    read-modify-write sequences under the same lock).
    """

    def __init__(self, name: str, lock: threading.RLock,
                 ttl: Optional[float] = None, tombstone_ttl: float = 30.0,
                 dirty_cb: Optional[Callable[[], None]] = None):
        self.name = name
        self._lock = lock
        self.ttl = ttl
        self.tombstone_ttl = tombstone_ttl
        self._dirty_cb = dirty_cb or (lambda: None)
        self.entries: Dict[str, dict] = {}  #: guarded-by _lock
        self.vers: Dict[str, int] = {}  #: guarded-by _lock
        self.epoch = 0  #: guarded-by _lock  (version counter)
        self._tombs: Dict[str, Tuple[int, float]] = {}  #: guarded-by _lock
        self._horizon = 0  #: guarded-by _lock  (newest GC'd deletion ver)
        self._soft_dirty: set = set()  #: guarded-by _lock
        self._expire_cbs: List[Callable[[List[str]], None]] = []

    # -- reads ---------------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self.entries)

    def get(self, key: str) -> Optional[dict]:
        with self._lock:
            return self.entries.get(key)

    def items(self) -> List[Tuple[str, dict]]:
        with self._lock:
            return list(self.entries.items())

    # -- leader-side mutators ------------------------------------------------
    def put(self, key: str, rec: dict) -> int:
        """Versioned write: (re)place ``rec`` under ``key`` and stamp it
        with the next version.  Returns the new epoch."""
        with self._lock:
            rec = dict(rec)
            rec.setdefault("last", time.monotonic())
            self.epoch += 1
            self.entries[key] = rec
            self.vers[key] = self.epoch
            self._tombs.pop(key, None)
            self._soft_dirty.discard(key)
            self._dirty_cb()
            return self.epoch

    def update(self, key: str, **fields) -> bool:
        """Soft write: refresh liveness and update ``fields`` in place
        with *no* version bump.  Marks the entry delta-dirty only when a
        value actually changed (idle heartbeats cost zero gossip bytes).
        False if the key is unknown (expired: caller should re-put)."""
        with self._lock:
            rec = self.entries.get(key)
            if rec is None:
                return False
            rec["last"] = time.monotonic()
            changed = any(rec.get(f) != v for f, v in fields.items())
            rec.update(fields)
            if changed:
                self._soft_dirty.add(key)
            return True

    def delete(self, key: str) -> bool:
        """Versioned delete: tombstoned so deltas can replicate it."""
        with self._lock:
            if key not in self.entries:
                return False
            del self.entries[key]
            self.vers.pop(key, None)
            self.epoch += 1
            self._tombs[key] = (self.epoch,
                                time.monotonic() + self.tombstone_ttl)
            self._soft_dirty.discard(key)
            self._dirty_cb()
            return True

    def expire(self, now: float) -> List[str]:
        """Delete every entry whose liveness stamp is older than
        ``ttl``; returns the expired keys (leader's sweeper only)."""
        with self._lock:
            if self.ttl is None:
                return []
            dead = [k for k, v in self.entries.items()
                    if now - v["last"] > self.ttl]
            for k in dead:
                del self.entries[k]
                self.vers.pop(k, None)
                self.epoch += 1
                self._tombs[k] = (self.epoch,
                                  time.monotonic() + self.tombstone_ttl)
                self._soft_dirty.discard(k)
            if dead:
                self._dirty_cb()
            return dead

    def refresh_liveness(self, now: float) -> None:
        """Stamp every entry live *now* — the takeover rule: entries
        that could not heartbeat while the old leader was dying must
        not be mass-expired the moment the lease moves."""
        with self._lock:
            for rec in self.entries.values():
                rec["last"] = now

    def bump(self) -> int:
        """Advance the epoch without touching entries (takeover marker:
        pools watching the epoch see the stream move)."""
        with self._lock:
            self.epoch += 1
            return self.epoch

    # -- expiry hooks --------------------------------------------------------
    def on_expire(self, cb: Callable[[List[str]], None]) -> None:
        """Register ``cb(expired_keys)``; the core fires it (outside the
        lock, leaseholder only) after a sweep or an explicit delete."""
        self._expire_cbs.append(cb)

    def fire_expired(self, keys: List[str]) -> None:
        for cb in self._expire_cbs:
            try:
                cb(keys)
            except Exception:
                pass                      # hooks must not kill the sweeper

    # -- wire ----------------------------------------------------------------
    @staticmethod
    def _wire_rec(rec: dict, now: float) -> dict:
        out = {k: v for k, v in rec.items() if k != "last"}
        out["age"] = round(now - rec.get("last", now), 3)
        return out

    @staticmethod
    def _unwire_rec(rec: dict, now: float) -> dict:
        out = {k: v for k, v in rec.items() if k != "age"}
        out["last"] = now - float(rec.get("age", 0.0))
        return out

    def snapshot(self, now: float) -> dict:
        with self._lock:
            return {"epoch": self.epoch,
                    "entries": [{"k": k, "ver": self.vers[k],
                                 "rec": self._wire_rec(v, now)}
                                for k, v in self.entries.items()]}

    def install(self, snap: dict, now: float) -> None:
        """Full-state overwrite from a snapshot (follower resync)."""
        with self._lock:
            self.entries = {e["k"]: self._unwire_rec(e["rec"], now)
                            for e in snap["entries"]}
            self.vers = {e["k"]: int(e["ver"]) for e in snap["entries"]}
            self.epoch = int(snap["epoch"])
            self._tombs.clear()
            # a freshly installed mirror has no deletion history: it can
            # only produce deltas for peers at or past this epoch
            self._horizon = self.epoch
            self._soft_dirty.clear()

    #: requires _lock
    def _gc_tombs(self, now: float) -> None:
        dead = [k for k, (_, drop) in self._tombs.items() if drop <= now]
        for k in dead:
            ver, _ = self._tombs.pop(k)
            self._horizon = max(self._horizon, ver)

    def delta_since(self, base: int, now: float) -> Optional[dict]:
        """Changes with version > ``base``; ``None`` when ``base`` is
        behind the tombstone horizon (or ahead of us) — the caller must
        send a full snapshot instead."""
        with self._lock:
            self._gc_tombs(now)
            if base < self._horizon or base > self.epoch:
                return None
            return {
                "base": base, "epoch": self.epoch,
                "put": [{"k": k, "ver": self.vers[k],
                         "rec": self._wire_rec(self.entries[k], now)}
                        for k in self.entries if self.vers[k] > base],
                "del": [[k, ver] for k, (ver, _) in self._tombs.items()
                        if ver > base],
            }

    def take_soft(self, now: float) -> List[dict]:
        """Drain the soft-dirty set as wire records (coalesced: one
        entry per key however many heartbeats touched it this round)."""
        with self._lock:
            out = [{"k": k, "rec": self._wire_rec(self.entries[k], now)}
                   for k in self._soft_dirty if k in self.entries]
            self._soft_dirty.clear()
            return out

    def apply_delta(self, delta: dict, now: float) -> bool:
        """Apply a leader's delta to this mirror.  False when the delta
        does not connect to our state (its base is past our epoch —
        we missed deletions in between): the caller's next heartbeat
        advertises our epoch and the leader answers with a snapshot."""
        with self._lock:
            if int(delta["base"]) > self.epoch:
                return False
            for e in delta.get("put", ()):
                ver = int(e["ver"])
                if self.vers.get(e["k"], 0) < ver:
                    self.entries[e["k"]] = self._unwire_rec(e["rec"], now)
                    self.vers[e["k"]] = ver
            for k, ver in delta.get("del", ()):
                if self.vers.get(k, 0) <= int(ver):
                    self.entries.pop(k, None)
                    self.vers.pop(k, None)
            self.epoch = max(self.epoch, int(delta["epoch"]))
            return True

    def apply_soft(self, soft: List[dict], now: float) -> None:
        """Merge soft (load/liveness) records into the mirror; unknown
        keys are skipped (the versioned stream owns membership)."""
        with self._lock:
            for e in soft:
                if e["k"] in self.entries:
                    self.entries[e["k"]] = self._unwire_rec(e["rec"], now)

    def status(self) -> dict:
        with self._lock:
            return {"epoch": self.epoch, "entries": len(self.entries),
                    "tombstones": len(self._tombs),
                    "horizon": self._horizon}


def _payload_bytes(payload: dict) -> int:
    """Wire size of a gossip payload (the same proc the RPC layer
    uses) — feeds the delta-vs-snapshot byte counters in fab.status and
    the ``gossip_churn`` benchmark."""
    try:
        return len(hg_proc.encode(hg_proc.proc_any, payload))
    except Exception:
        return 0


class ReplicationCore:
    """Hosts named :class:`ReplicatedTable`\\ s on one engine and keeps
    them replicated across a static quorum (DESIGN.md §8).

    One core per node carries *all* control-plane tables — the registry
    instance table and the membership member table share one leader
    lease, one gossip stream (``fab.gossip``), one nonce, and one TTL
    sweeper.  With ``peers=None`` the core is a single-node control
    plane: always leading, no gossip, same API.

    **Delta gossip** (default): the leader tracks, per peer, the last
    acknowledged ``(nonce, per-table epoch)`` — acks arrive both as
    responses to its pushes and as the followers' own heartbeats — and
    pushes only entries versioned past the ack, plus coalesced soft
    (load/liveness) records that actually changed.  A peer whose ack is
    missing, carries a different nonce, or falls behind a table's
    tombstone horizon is resynced with a **full snapshot** instead
    (rate-limited per peer so a dead peer does not cost a snapshot
    encode per tick).  ``delta_gossip=False`` restores the PR-4
    full-state protocol (snapshot on membership change + periodic
    cadence) — kept as the comparison baseline for the
    ``gossip_churn`` benchmark and as an operational escape hatch.
    """

    def __init__(self, engine, peers: Optional[Sequence[str]] = None,
                 self_uri: Optional[str] = None, lease_ttl: float = 1.0,
                 gossip_interval: float = 0.25,
                 sweep_interval: float = 0.5,
                 rpc_name: str = "fab.gossip",
                 delta_gossip: bool = True,
                 tombstone_ttl: Optional[float] = None,
                 autostart: bool = True):
        self.engine = engine
        self.rpc_name = rpc_name
        self.delta_gossip = delta_gossip
        self.gossip_interval = gossip_interval
        self._lock = threading.RLock()
        self.tables: Dict[str, ReplicatedTable] = {}  #: guarded-by _lock
        # stream nonce: epochs are only comparable within one nonce (a
        # restarted node restarts at epoch 0 and a failed-over leader
        # starts a fresh stream — see DESIGN.md §8)
        self.nonce = uuid.uuid4().hex[:12]  #: guarded-by _lock
        self._stop = threading.Event()
        self._dirty = threading.Event()   # membership moved: push now
        self._tick_hooks: List[Callable[[], None]] = []
        # per-peer replication ack: peer -> {"nonce", "epochs"}
        self._acks: Dict[str, dict] = {}  #: guarded-by _lock
        self._next_snap_push: Dict[str, float] = {}  #: guarded-by _lock
        self.stats: Dict[str, int] = {  #: guarded-by _lock
            "rounds": 0, "delta_pushes": 0, "delta_bytes": 0,
            "snapshot_pushes": 0, "snapshot_bytes": 0,
            "heartbeat_pushes": 0, "heartbeat_bytes": 0,
            "pull_deltas": 0, "pull_snapshots": 0}
        # tombstones must comfortably outlive the reconciliation window
        # (a follower that missed a few gossip rounds catches up by
        # delta, not snapshot); only a long partition falls behind the
        # horizon
        self.tombstone_ttl = (tombstone_ttl if tombstone_ttl is not None
                              else max(30.0, 20 * lease_ttl))
        if peers is not None:
            peer_list = list(peers)
            su = self_uri or (engine.uri if engine.uri in peer_list
                              else None)
            if su is None:
                raise ValueError(
                    f"engine uri {engine.uri!r} is not in peers "
                    f"{peer_list!r}; pass self_uri= explicitly")
            self.tracker: Optional[PeerTracker] = PeerTracker(
                peer_list, su, lease_ttl=lease_ttl)
            self.self_uri = su
            self._leading = False  #: guarded-by _lock (elected by gossip)
        else:
            self.tracker = None
            self.self_uri = engine.uri
            self._leading = True          # single node: always the leader
        self._proxy_timeout = max(0.5, min(2.0, lease_ttl))
        # gossip probes must resolve well inside the lease: a black-holed
        # peer burning a full proxy_timeout per tick would starve contact
        # with live peers and flap leadership
        self._gossip_timeout = max(0.2, min(self._proxy_timeout,
                                            lease_ttl / 2))
        # snapshot cadence: the full-state mode's periodic push, and the
        # delta mode's per-peer rate limit for unacked (dead or cold)
        # peers
        self._full_push_every = max(1.0, gossip_interval)
        self._next_full_push = 0.0  #: guarded-by _lock
        self._sweep_interval = sweep_interval
        self._sweeper = threading.Thread(
            target=self._sweep_loop, args=(sweep_interval,), daemon=True,
            name="fabric-ctrl-sweep")
        self._gossiper: Optional[threading.Thread] = None
        if self.tracker is not None:
            engine.register(rpc_name, self._gossip)
            self._gossiper = threading.Thread(
                target=self._gossip_loop, daemon=True,
                name="fabric-ctrl-gossip")
        self._started = False
        if autostart:
            self.start()

    def start(self) -> None:
        """Start the sweeper (and, in quorum mode, the gossip loop).
        Separated from construction so a host service can finish
        attaching its tables and wire handlers *before* the node begins
        sweeping/electing — with ``autostart=False`` nothing runs until
        everything the quorum replicates is in place (idempotent)."""
        if self._started:
            return
        self._started = True
        self._sweeper.start()
        if self._gossiper is not None:
            self._gossiper.start()

    # -- tables --------------------------------------------------------------
    def table(self, name: str, ttl: Optional[float] = None
              ) -> ReplicatedTable:
        """Get-or-create the named table.  A table may be auto-created
        earlier by gossip (a peer replicated it before the local service
        attached); attaching sets its TTL."""
        with self._lock:
            t = self.tables.get(name)
            if t is None:
                t = ReplicatedTable(name, self._lock, ttl=ttl,
                                    tombstone_ttl=self.tombstone_ttl,
                                    dirty_cb=self._dirty.set)
                self.tables[name] = t
            elif ttl is not None:
                t.ttl = ttl
            return t

    def add_tick_hook(self, cb: Callable[[], None]) -> None:
        """Run ``cb`` at the top of every gossip tick (quorum mode) —
        the retry loop for cross-node bookkeeping like pending reaps."""
        self._tick_hooks.append(cb)

    def mark_dirty(self) -> None:
        self._dirty.set()

    # -- leadership ----------------------------------------------------------
    @property
    def is_leader(self) -> bool:
        with self._lock:
            return self._leading

    def leader_for_writes(self) -> Optional[str]:
        """None if this replica may apply writes locally; otherwise the
        leaseholder to proxy to.  Raises ``AGAIN`` while leadership is
        unsettled (boot grace / takeover pending) — retryable:
        :class:`QuorumCaller` keeps re-probing the quorum within its own
        timeout budget until the lease settles."""
        if self.tracker is None or self.is_leader:
            return None
        lead = self.tracker.leader_uri()
        if lead is None or lead == self.self_uri:
            raise MercuryError(Ret.AGAIN,
                               "control-plane leadership unsettled; retry")
        return lead

    def proxy(self, leader: str, name: str, req: dict):
        """Forward a write to the leaseholder (one hop only: a proxied
        write that lands on another follower fails fast with AGAIN
        rather than bouncing around a partitioned quorum)."""
        if req.get("_proxied"):
            raise MercuryError(Ret.AGAIN,
                               "control-plane leadership unsettled; retry")
        # child of the ambient server span (the handler that received the
        # client's write): the trace shows follower hop -> leader hop
        span = _trace.start_span(f"proxy.{name}", _trace.current(),
                                 leader=leader)
        try:
            with _trace.use(span.ctx):
                out = self.engine.call(leader, name,
                                       dict(req, _proxied=True),
                                       timeout=self._proxy_timeout)
            span.finish("OK")
            return out
        except MercuryError as e:
            span.finish(e.ret.name)
            if e.ret in FAILOVER_RETS:
                raise MercuryError(
                    Ret.AGAIN, f"control-plane leader {leader} unreachable "
                    f"({e.ret.name}); retry") from e
            raise                         # application error: handler ran
        except Exception as e:
            span.finish(type(e).__name__)
            raise

    def _take_over(self) -> None:
        """Become the leaseholder: start a fresh epoch stream (new nonce
        → every client resyncs) and refresh all liveness stamps so the
        takeover itself cannot mass-expire entries that could not
        heartbeat while the old leader was dead."""
        now = time.monotonic()
        with self._lock:
            self._leading = True
            self.nonce = uuid.uuid4().hex[:12]
            self._acks.clear()
            for t in self.tables.values():
                t.bump()
                t.refresh_liveness(now)
        self._dirty.set()                 # announce the new stream now

    # -- reconciliation ------------------------------------------------------
    def _may_adopt(self, frm: str) -> bool:
        """Adopted from lower-rank (higher-priority) peers always — that
        is also how a deposed leader steps down — and from *any* acting
        leader during boot grace, so a restarted high-priority replica
        resyncs before it reclaims the lease."""
        tr = self.tracker
        return tr is not None and (
            tr.in_grace()
            or tr.rank.get(frm, 99) < tr.rank[self.self_uri])

    def _adopt_snapshot(self, frm: str, nonce: str,
                        snaps: Dict[str, dict]) -> None:
        """Full-state overwrite keyed by (nonce, epoch)."""
        if not self._may_adopt(frm):
            return
        now = time.monotonic()
        with self._lock:
            if nonce == self.nonce and any(
                    int(s["epoch"]) < self.tables[n].epoch
                    for n, s in snaps.items() if n in self.tables):
                return                    # stale push of our own stream
            # equal-epoch snapshots of our own stream ARE adopted: in
            # full-gossip mode the leader's periodic snapshot is how
            # mirrored soft state (loads, liveness ages) stays fresh
            # between membership changes
            self._leading = False
            self.nonce = nonce
            for name, snap in snaps.items():
                self.table(name).install(snap, now)
        self.tracker.mark_synced()

    def _apply_deltas(self, frm: str, nonce: str,
                      deltas: Dict[str, dict]) -> None:
        """Apply a leader's per-table deltas.  Only connects within one
        stream (same nonce); a gap (delta base past our epoch) is left
        unapplied — our next heartbeat advertises the low epoch and the
        leader answers with a snapshot."""
        if not self._may_adopt(frm):
            return
        now = time.monotonic()
        with self._lock:
            if nonce != self.nonce or self._leading:
                return
            for name, d in deltas.items():
                t = self.table(name)
                if t.apply_delta(d, now):
                    t.apply_soft(d.get("soft", ()), now)

    # -- gossip wire ---------------------------------------------------------
    def _epochs_locked(self) -> Dict[str, int]:
        return {n: t.epoch for n, t in self.tables.items()}

    def _snapshots_locked(self, now: float) -> Dict[str, dict]:
        return {n: t.snapshot(now) for n, t in self.tables.items()}

    def _catchup_locked(self, peer_nonce, peer_epochs: dict,
                        now: float) -> Tuple[str, dict]:
        """Build what a behind peer needs: ``("delta", {...})`` when its
        acked epochs connect to our tombstone history, else
        ``("snapshot", {...})``."""
        if self.delta_gossip and peer_nonce == self.nonce:
            deltas = {}
            for name, t in self.tables.items():
                base = int((peer_epochs or {}).get(name, 0))
                if base == t.epoch:
                    continue
                d = t.delta_since(base, now)
                if d is None:             # behind the horizon: resync
                    return "snapshot", self._snapshots_locked(now)
                deltas[name] = d
            return "delta", deltas
        return "snapshot", self._snapshots_locked(now)

    def _gossip(self, req):
        """Peer-to-peer state exchange.  The leader pushes deltas (or
        snapshots for unsynced peers); followers heartbeat with their
        mirrored (nonce, epochs) and are answered with a catch-up
        payload whenever they are behind."""
        frm = req.get("from")
        if self.tracker is None or frm not in self.tracker.rank:
            raise MercuryError(Ret.INVALID_ARG,
                               f"gossip from unknown peer {frm!r}")
        self.tracker.note(frm)
        if req.get("snapshot") is not None:
            self._adopt_snapshot(frm, req["nonce"], req["snapshot"])
        if req.get("delta") is not None:
            self._apply_deltas(frm, req["nonce"], req["delta"])
        now = time.monotonic()
        with self._lock:
            resp = {"nonce": self.nonce, "epochs": self._epochs_locked()}
            if self._leading:
                # the requester's heartbeat doubles as its ack
                self._acks[frm] = {"nonce": req.get("nonce"),
                                   "epochs": dict(req.get("epochs") or {})}
                behind = (req.get("nonce") != self.nonce
                          or any(int((req.get("epochs") or {}).get(n, 0))
                                 < t.epoch
                                 for n, t in self.tables.items()))
                if behind:
                    kind, pay = self._catchup_locked(
                        req.get("nonce"), req.get("epochs"), now)
                    if pay:
                        resp[kind] = pay
                        self.stats["pull_deltas" if kind == "delta"
                                   else "pull_snapshots"] += 1
        return resp

    def _gossip_loop(self) -> None:
        while not self._stop.is_set():
            dirty = self._dirty.wait(self.gossip_interval)
            self._dirty.clear()
            if self._stop.is_set():
                return
            try:
                self._gossip_tick(dirty)
            except Exception:
                pass                      # gossip must never die

    def _build_pushes_locked(self, dirty: bool, now: float
                             ) -> List[Tuple[str, dict]]:
        """One payload per peer.  Followers always send the bare
        heartbeat; the leader attaches per-peer deltas / rate-limited
        snapshots as each peer's ack requires."""
        base = {"from": self.self_uri, "leader": self._leading,
                "nonce": self.nonce, "epochs": self._epochs_locked()}
        peers = self.tracker.others()
        if not self._leading:
            return [(p, base) for p in peers]
        if not self.delta_gossip:
            # PR-4 full-state protocol: snapshot rides membership
            # changes immediately and a slow periodic cadence otherwise
            payload = dict(base)
            if dirty or now >= self._next_full_push:
                payload["snapshot"] = self._snapshots_locked(now)
                self._next_full_push = now + self._full_push_every
            return [(p, payload) for p in peers]
        # delta mode: coalesced soft records (shared across peers) +
        # per-peer versioned deltas from each acked epoch
        soft = {n: t.take_soft(now) for n, t in self.tables.items()}
        soft = {n: s for n, s in soft.items() if s}
        out = []
        snaps = None
        for peer in peers:
            ack = self._acks.get(peer)
            if (ack is None or ack.get("nonce") != self.nonce
                    or not self.tracker.is_alive(peer)):
                # unsynced (cold or restarted) or lease-dead peer: full
                # snapshot, rate-limited so a dead peer does not cost a
                # snapshot (or ever-growing delta) encode every tick —
                # a dead peer's last ack is frozen, so without the
                # is_alive check it would ride the catch-up path below
                # on every tick forever.  A *live* cold peer is caught
                # up faster via the pull path anyway
                if now >= self._next_snap_push.get(peer, 0.0):
                    if snaps is None:
                        snaps = self._snapshots_locked(now)
                    out.append((peer, dict(base, snapshot=snaps)))
                    self._next_snap_push[peer] = (now
                                                  + self._full_push_every)
                else:
                    out.append((peer, base))
                continue
            kind, pay = self._catchup_locked(ack["nonce"], ack["epochs"],
                                             now)
            if kind == "snapshot":
                out.append((peer, dict(base, snapshot=pay)))
                continue
            deltas = pay
            for name, s in soft.items():
                d = deltas.setdefault(
                    name, {"base": self.tables[name].epoch,
                           "epoch": self.tables[name].epoch,
                           "put": [], "del": []})
                d["soft"] = s
            if deltas:
                out.append((peer, dict(base, delta=deltas)))
            else:
                out.append((peer, base))
        return out

    def _gossip_tick(self, dirty: bool = False) -> None:
        # Leadership changes hands in exactly two places: here (the
        # lease says every higher-priority peer is dead, or — after boot
        # grace — that we are the highest-priority survivor), and in
        # _adopt_snapshot (a higher-priority peer's push deposes us).
        # An acting leader does NOT step down merely because a
        # higher-priority peer reappeared: it keeps serving until that
        # peer has adopted its snapshot and taken over — otherwise a
        # restarted rank-0 replica could seize the lease with an empty
        # table before it resynced.
        if (self.tracker.leader_uri() == self.self_uri
                and not self.is_leader):
            self._take_over()
            dirty = True
        for hook in self._tick_hooks:
            try:
                hook()
            except Exception:
                pass
        now = time.monotonic()
        with self._lock:
            pushes = self._build_pushes_locked(dirty, now)
        # size/classify the payloads OUTSIDE the lock: the stats encode
        # of a large snapshot would otherwise stall every inline read
        # handler (fab.resolve/fab.epoch/mem.view) contending on it
        sized = []
        for _, payload in pushes:
            kind = ("snapshot" if "snapshot" in payload
                    else "delta" if "delta" in payload
                    else "heartbeat")
            sized.append((kind, _payload_bytes(payload)))
        with self._lock:
            self.stats["rounds"] += 1
            for kind, nbytes in sized:
                self.stats[f"{kind}_pushes"] += 1
                self.stats[f"{kind}_bytes"] += nbytes
        # parallel fan-out, bounded well inside the lease: one
        # black-holed peer must not delay contact with live peers past
        # lease_ttl (serialized full-timeout probes would flap leases)
        futs = []
        for peer, payload in pushes:
            try:
                futs.append((peer, self.engine.call_async(
                    peer, self.rpc_name, payload,
                    timeout=self._gossip_timeout)))
            except Exception:
                continue
        for peer, fut in futs:
            try:
                resp = fut.result(timeout=self._gossip_timeout + 0.25)
            except Exception:
                continue                  # lease decays on silence
            self.tracker.note(peer)
            if not isinstance(resp, dict):
                continue
            if resp.get("snapshot") is not None:
                self._adopt_snapshot(peer, resp["nonce"], resp["snapshot"])
            if resp.get("delta") is not None:
                self._apply_deltas(peer, resp["nonce"], resp["delta"])
            with self._lock:
                if self._leading:
                    self._acks[peer] = {
                        "nonce": resp.get("nonce"),
                        "epochs": dict(resp.get("epochs") or {})}

    # -- sweeping ------------------------------------------------------------
    def _sweep_loop(self, interval: float) -> None:
        while not self._stop.wait(interval):
            now = time.monotonic()
            with self._lock:
                if not self._leading:
                    continue              # followers mirror; only the
                tables = list(self.tables.values())  # leaseholder expires
            for t in tables:
                dead = t.expire(now)
                if dead:
                    t.fire_expired(dead)  # outside the core lock

    # -- observability -------------------------------------------------------
    def status(self) -> dict:
        """Control-plane health: role, believed leaseholder, per-table
        entry counts/epochs, gossip delta-vs-snapshot counters, and the
        last acked (nonce, epochs) per peer (docs/OPERATIONS.md)."""
        with self._lock:
            base = {"self": self.self_uri, "nonce": self.nonce,
                    "tables": {n: t.status()
                               for n, t in self.tables.items()},
                    "gossip": dict(self.stats)}
            acks = {p: dict(a) for p, a in self._acks.items()}
            leading = self._leading
        if self.tracker is None:
            return dict(base, role="single", leader=self.self_uri,
                        peers=[])
        role = ("leader" if leading
                else "booting" if self.tracker.in_grace() else "follower")
        peers = []
        for p in self.tracker.peer_stats():
            ack = acks.get(p["uri"])
            if ack is not None:
                p = dict(p, acked_nonce=ack.get("nonce"),
                         acked=ack.get("epochs") or {})
            peers.append(p)
        return dict(base, role=role, leader=self.tracker.leader_uri(),
                    peers=peers)

    def close(self) -> None:
        """Stop and join the sweeper and gossip threads (idempotent)."""
        self._stop.set()
        self._dirty.set()                 # wake a parked gossip loop
        if self._started and self._sweeper.is_alive():
            self._sweeper.join(timeout=2.0)
        if (self._started and self._gossiper is not None
                and self._gossiper.is_alive()):
            self._gossiper.join(timeout=2.0)

    stop = close


class QuorumCaller:
    """Sticky-failover RPC calls over a control-plane address set.

    ``uris`` is one endpoint per replica (list, or one comma-separated
    string).  Calls stick to the endpoint that last answered and rotate
    to the next replica on transport-class failures (dead peer,
    unsettled leadership) — any live replica can serve reads and proxies
    writes to the leaseholder, so the caller never needs to know who
    leads.  Worst case a call probes every endpoint once
    (``len(uris) × timeout``)."""

    def __init__(self, engine, uris, timeout: float = 10.0):
        self.engine = engine
        self.uris = parse_registry_uris(uris)
        self.timeout = timeout
        self._idx = 0
        self._idx_lock = threading.Lock()

    @property
    def current(self) -> str:
        """The currently preferred endpoint (observability/tests)."""
        with self._idx_lock:
            return self.uris[self._idx]

    def call(self, name: str, req: dict):
        # One rotation over the endpoints; if every replica answered
        # AGAIN (leadership unsettled: cold-quorum boot grace, or the
        # lease mid-failover) the quorum is alive but momentarily
        # unwritable, so keep retrying within the call's own timeout
        # budget rather than surfacing a transient to the caller —
        # ServiceInstance/ServingGateway constructors race quorum
        # startup in any real deployment.
        deadline = time.monotonic() + self.timeout
        while True:
            with self._idx_lock:
                start = self._idx
            last: Optional[MercuryError] = None
            all_again = True
            for k in range(len(self.uris)):
                i = (start + k) % len(self.uris)
                try:
                    out = self.engine.call(self.uris[i], name, req,
                                           timeout=self.timeout)
                except MercuryError as e:
                    if e.ret not in FAILOVER_RETS:
                        raise             # application error: surfaced
                    last = e
                    all_again = all_again and e.ret == Ret.AGAIN
                    continue
                with self._idx_lock:
                    self._idx = i         # sticky: keep the live replica
                return out
            if last is None:
                raise MercuryError(Ret.NOENTRY,
                                   "empty control-plane address set")
            if not all_again or time.monotonic() + 0.1 >= deadline:
                raise last
            time.sleep(0.1)               # unsettled leadership: re-probe
