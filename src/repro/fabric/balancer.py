"""Pluggable load balancers for :class:`~repro.fabric.pool.ServicePool`.

Contract (see DESIGN.md §7): a balancer is given the pool's live
:class:`Replica` views and returns them **ordered best-first**.  The pool
walks the ranking and places the call on the first replica that admits it
(credit available / reachable); retries continue down the list.  Ranking
instead of picking one replica is what lets flow control, retries and
hedging compose with any policy: the balancer never needs to know why a
candidate was rejected.

Balancers must be cheap and thread-safe — they run on every call.

  * ``rr``        round-robin over the replica set (stable under view
                  refreshes: position keyed by a monotonically advancing
                  counter, not list order)
  * ``least``     least-loaded first, using piggybacked registry load
                  reports combined with the pool's own live in-flight
                  counts (local counts lead, reports trail)
  * ``locality``  cheapest transport tier first (self < sm < tcp — the
                  NotNets argument: keep co-located traffic off the
                  network stack), least-loaded within a tier
  * ``weighted``  expected-wait ranking: ``ema_latency × (inflight + 1)
                  / capacity`` — client-side EWMA latency (fed from
                  ``Replica.record``) times queue occupancy (local
                  in-flight + the server's piggybacked ``fab.report``
                  load), normalized by capacity.  Unlike the strict
                  tier/load sort this trades tiers off against observed
                  speed, so a slow-but-local replica loses to a
                  fast-but-remote one once the latency gap exceeds the
                  transport gap
"""
from __future__ import annotations

import abc
import itertools
import threading
from typing import Dict, List, Sequence, Type


class Balancer(abc.ABC):
    @abc.abstractmethod
    def rank(self, replicas: Sequence["Replica"]) -> List["Replica"]:
        """Return ``replicas`` ordered best-first (must not mutate)."""

    @property
    def name(self) -> str:
        return type(self).__name__


class RoundRobin(Balancer):
    def __init__(self):
        self._counter = itertools.count()  #: guarded-by _lock
        self._lock = threading.Lock()

    def rank(self, replicas):
        if not replicas:
            return []
        with self._lock:
            n = next(self._counter)
        order = sorted(replicas, key=lambda r: r.iid)   # stable base order
        k = n % len(order)
        return order[k:] + order[:k]


def _effective_load(r) -> float:
    """Piggybacked registry load + what *we* currently have in flight
    there (the local signal is fresher than the last report)."""
    cap = max(r.capacity, 1)
    return (r.load + r.gate.inflight) / cap


def _rotate_ties(ordered: List["Replica"], keyfn, n: int) -> List["Replica"]:
    """Rotate the leading equal-cost group by ``n`` so replicas that are
    indistinguishable under ``keyfn`` share traffic instead of the
    deterministic sort funnelling every idle-period call to one of them."""
    if len(ordered) < 2:
        return ordered
    k0 = keyfn(ordered[0])
    i = 1
    while i < len(ordered) and keyfn(ordered[i]) == k0:
        i += 1
    k = n % i
    return ordered[k:i] + ordered[:k] + ordered[i:]


class LeastLoaded(Balancer):
    def __init__(self):
        self._counter = itertools.count()  #: guarded-by _lock
        self._lock = threading.Lock()

    def rank(self, replicas):
        key = _effective_load
        base = sorted(replicas, key=lambda r: (key(r), r.iid))
        with self._lock:
            n = next(self._counter)
        return _rotate_ties(base, key, n)


class LocalityAware(Balancer):
    """Prefer cheaper transport tiers; break ties by load.  A replica
    whose cheap tier was demoted (stale sm segment, dead self peer)
    naturally sinks in the ranking because its resolved tier rose."""

    def __init__(self):
        self._counter = itertools.count()  #: guarded-by _lock
        self._lock = threading.Lock()

    def rank(self, replicas):
        def key(r):
            return (r.tier, _effective_load(r))
        base = sorted(replicas, key=lambda r: (key(r), r.iid))
        with self._lock:
            n = next(self._counter)
        return _rotate_ties(base, key, n)


class EwmaWeighted(Balancer):
    """Rank by expected wait: client-observed EWMA latency × occupancy
    (local in-flight leads, the server's piggybacked load report trails)
    / capacity.  Replicas with no latency sample yet rank *first* (their
    score term is the set's minimum observed EWMA, occupancy-scaled), so
    new/recovered replicas get probed instead of starved."""

    def __init__(self):
        self._counter = itertools.count()  #: guarded-by _lock
        self._lock = threading.Lock()

    def rank(self, replicas):
        if not replicas:
            return []
        sampled = [r.ema_latency for r in replicas if r.ema_latency > 0.0]
        floor = min(sampled) if sampled else 1.0

        def key(r):
            lat = r.ema_latency if r.ema_latency > 0.0 else floor
            occupancy = r.gate.inflight + max(r.load, 0.0) + 1.0
            return lat * occupancy / max(r.capacity, 1)
        base = sorted(replicas, key=lambda r: (key(r), r.iid))
        with self._lock:
            n = next(self._counter)
        return _rotate_ties(base, key, n)


def prefer_instance(ranked: List["Replica"],
                    iid: str | None) -> List["Replica"]:
    """Soft-affinity reorder: move the replica with ``iid`` to the front
    of an already-ranked candidate list, keeping the balancer's order for
    everyone else (they are the fallback path).  A ``iid`` that is not in
    the list — dead, deregistered, or filtered as already-failed — leaves
    the ranking untouched, which is exactly the affinity contract: prefer
    the KV-holding replica, never *depend* on it."""
    if iid is None:
        return ranked
    for i, r in enumerate(ranked):
        if r.iid == iid:
            return [r] + list(ranked[:i]) + list(ranked[i + 1:])
    return ranked


BALANCERS: Dict[str, Type[Balancer]] = {
    "rr": RoundRobin,
    "least": LeastLoaded,
    "locality": LocalityAware,
    "weighted": EwmaWeighted,
}


def make_balancer(spec) -> Balancer:
    if isinstance(spec, Balancer):
        return spec
    cls = BALANCERS.get(spec)
    if cls is None:
        raise ValueError(f"unknown balancer {spec!r}; "
                         f"choose from {sorted(BALANCERS)}")
    return cls()
